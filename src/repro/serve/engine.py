"""Phase-split serving engine: batch-1 prefill + fixed-width decode.

Bit-exactness contract
----------------------
XLA GEMMs are *not* batch-size invariant (an M=1 and an M=3 matmul may
differ in the last ulp), so the scheduler never compares runs at
different widths.  Instead both serving modes share one structural
shape:

* every prompt prefills alone at batch 1 (bucket-padded to a small set
  of lengths so prefill traces are reused), and
* every decode step runs at the engine's fixed slot width ``n_slots``
  with a per-lane ``(B,)`` cache position vector (free lanes idle at
  position 0).

Lane *i*'s decode result depends only on lane *i*'s cache row and
position (verified bit-identical to a solo scalar-position decode), so
one-shot serving (concurrency 1 on the same engine) and continuous
batching produce identical per-request token ids.

Phase-specialized plans
-----------------------
The engine holds an optional prefill/decode :class:`ExecutionPlan` pair.
Each phase's calls run under :func:`repro.nn.plan_context` with its own
plan and inside :func:`repro.plan.execution_stream`, so the execution
log records which plan actually traced each contraction.  Plans are
validated against the model config (and their ``phase`` stamp) at
construction — a swapped or wrong-arch pair is rejected before any step
runs.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, api
from repro.nn import plan_context
from repro.plan import ExecutionPlan, execution_stream
from repro.plan.compiler import check_plan_for_config


class ServeEngine:
    """Model + plan pair + jitted phase kernels behind the scheduler.

    ``n_slots`` is the fixed decode width; ``prompt_bucket`` rounds
    prompt lengths up to a multiple (token 0 padding — safe for
    attention families because padded K/V sits beyond the per-lane valid
    horizon and is progressively overwritten; recurrent-state families
    force a bucket of 1 since junk tokens would advance their state).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        n_slots: int,
        max_seq: int,
        prompt_bucket: int = 8,
        prefill_plan: Optional[ExecutionPlan] = None,
        decode_plan: Optional[ExecutionPlan] = None,
        arch: str = "",
        plan_backend: Optional[str] = None,
    ) -> None:
        if cfg.family == "encdec":
            raise ValueError(
                "serve scheduler is causal-LM only: encdec runs its own "
                "scalar-position decoder (use launch.serve --schedule oneshot "
                "semantics via the legacy prefill/decode steps)")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1 (got {n_slots})")
        if prompt_bucket < 1:
            raise ValueError(f"prompt_bucket must be >= 1 (got {prompt_bucket})")
        if arch:
            for plan, phase in ((prefill_plan, "prefill"),
                                (decode_plan, "decode")):
                if plan is None:
                    continue
                problems = check_plan_for_config(plan, arch, cfg, phase=phase)
                if problems:
                    raise ValueError(
                        f"{phase} plan rejected for arch {arch!r}:\n  "
                        + "\n  ".join(problems))
        # v4 factorizations set parameter *shapes*: both phases contract
        # the same params, so a searched decomposition must be identical
        # across the pair and present on both halves
        fact = {
            phase: {lp.name: lp.factorization.triple
                    for lp in plan.layers if lp.factorization is not None}
            for plan, phase in ((prefill_plan, "prefill"),
                                (decode_plan, "decode"))
            if plan is not None
        }
        if any(fact.values()):
            if prefill_plan is None or decode_plan is None:
                raise ValueError(
                    "a plan with searched factorizations (schema v4) must "
                    "install for BOTH phases: the unplanned phase would "
                    "contract default-decomposition networks over "
                    "factorized params")
            if fact["prefill"] != fact["decode"]:
                diff = sorted(
                    set(fact["prefill"].items())
                    ^ set(fact["decode"].items()))
                raise ValueError(
                    "prefill/decode plans carry different factorizations "
                    f"({[n for n, _ in diff]}); a serving pair shares one "
                    "decomposition (it defines the parameter shapes)")
        self.cfg = cfg
        self.params = params
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        # junk prompt padding advances recurrent state — exact lengths only
        self.prompt_bucket = 1 if cfg.supports_long_context else int(prompt_bucket)
        self.prefill_plan = prefill_plan
        self.decode_plan = decode_plan
        self._plan_backend = plan_backend
        self._m = api(cfg)  # leaves any globally installed plan untouched

        def _prefill(params, toks, last_idx):
            logits, caches = self._m.prefill_full(params, {"tokens": toks},
                                                  self.max_seq)
            last = jax.lax.dynamic_index_in_dim(logits, last_idx, axis=1,
                                                keepdims=False)
            return last, caches

        self._prefill_fn = jax.jit(_prefill)
        self._decode_fn = jax.jit(
            lambda p, t, c, pos: self._m.decode_step(p, t, c, pos),
            donate_argnums=(2,))
        # write batch-1 caches into slot `slot` of the width-n_slots tree
        # (every stacked cache leaf carries batch on axis 1)
        self._admit_fn = jax.jit(
            lambda big, small, slot: jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_index_in_dim(
                    b, s[:, 0], slot, axis=1),
                big, small),
            donate_argnums=(0,))

    # -- phase kernels -------------------------------------------------

    def padded_len(self, prompt_len: int) -> int:
        b = self.prompt_bucket
        return -(-prompt_len // b) * b

    def prefill_request(self, prompt: Sequence[int]):
        """Prefill one prompt at batch 1 under the prefill plan.

        Returns ``(last_logits (V,) np.ndarray, batch-1 caches)`` where
        the logits are taken at the last *real* token of the
        bucket-padded prompt.
        """
        p = len(prompt)
        pp = self.padded_len(p)
        if pp > self.max_seq:
            raise ValueError(
                f"padded prompt length {pp} exceeds max_seq {self.max_seq}")
        toks = np.zeros((1, pp), np.int32)
        toks[0, :p] = np.asarray(prompt, np.int32)
        with plan_context(self.prefill_plan,
                          force_backend=self._plan_backend):
            with execution_stream("prefill"):
                last, caches = self._prefill_fn(
                    self.params, jnp.asarray(toks),
                    jnp.asarray(p - 1, jnp.int32))
        return np.asarray(last[0]), caches

    def fresh_caches(self):
        """A zeroed width-``n_slots`` decode cache tree."""
        return self._m.init_caches(self.n_slots, self.max_seq)

    def admit(self, caches, small, slot: int):
        """Copy a prefilled batch-1 cache tree into decode lane ``slot``.

        Donates ``caches`` — the caller must use the returned tree.
        """
        return self._admit_fn(caches, small, jnp.asarray(slot, jnp.int32))

    def decode(self, tok: np.ndarray, pos: np.ndarray, caches):
        """One fixed-width decode step under the decode plan.

        ``tok``/``pos`` are ``(n_slots,)`` host arrays (free lanes pass
        0).  Returns ``(logits (n_slots, V) np.ndarray, new caches)``;
        donates ``caches``.
        """
        with plan_context(self.decode_plan,
                          force_backend=self._plan_backend):
            with execution_stream("decode"):
                logits, caches = self._decode_fn(
                    self.params,
                    jnp.asarray(tok, jnp.int32)[:, None],
                    caches,
                    jnp.asarray(pos, jnp.int32))
        return np.asarray(logits), caches
