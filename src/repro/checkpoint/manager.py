"""Atomic, async, retention-policied checkpointing with elastic restore.

Layout: ``<dir>/step_<k>/`` holding one ``.npy`` per leaf plus a JSON
manifest (pytree structure + dtypes).  Writes go to ``step_<k>.tmp`` and
are ``os.rename``d only after fsync — a crash mid-save never corrupts the
latest checkpoint.  ``save_async`` runs the serialization on a worker
thread so the train loop isn't blocked (the arrays are first fetched to
host inside the caller's step to keep a consistent snapshot).

Restore is *mesh-independent*: leaves come back as host numpy arrays and
are ``jax.device_put`` with whatever sharding the (possibly different)
target mesh prescribes — elastic scaling across restarts.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 et al. with numpy
import numpy as np

_NATIVE_KINDS = set("fiubc")


def _to_native(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """(.npy-safe array, true dtype name): exotic dtypes round-trip as uints."""
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr, str(arr.dtype)
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}")), str(arr.dtype)


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    return arr.view(np.dtype(dtype_name))


def _flatten_with_names(tree: Any) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    named = []
    for (path, leaf) in paths:
        key = "/".join(str(p) for p in path).replace("'", "")
        key = re.sub(r"[^A-Za-z0-9_./\[\]-]", "_", key) or "leaf"
        named.append((key, np.asarray(leaf)))
    return named, treedef


def save_tree(tree: Any, directory: str) -> None:
    """Synchronous atomic save of a pytree to ``directory``."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named, _ = _flatten_with_names(tree)
    manifest = []
    for i, (key, arr) in enumerate(named):
        fname = f"leaf_{i:05d}.npy"
        safe, dtype_name = _to_native(arr)
        np.save(os.path.join(tmp, fname), safe)
        manifest.append({"key": key, "file": fname, "dtype": dtype_name,
                         "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_tree(template: Any, directory: str, shardings: Any = None) -> Any:
    """Restore into the structure of ``template``.

    ``shardings``: optional tree of jax.sharding.Sharding — leaves are
    device_put with them (elastic reshard onto the current mesh).
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(template)
    if len(manifest) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(manifest)} leaves, template {len(leaves)}")
    arrays = [
        _from_native(np.load(os.path.join(directory, m["file"])), m["dtype"])
        for m in manifest
    ]
    restored = treedef.unflatten(arrays)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored


class CheckpointManager:
    """Step-indexed checkpoints with retention + async save."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and not d.endswith(".tmp"):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(np.asarray, tree)   # consistent snapshot
        save_tree(host_tree, self._step_dir(step))
        self._enforce_retention()

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()                                   # one in flight
        host_tree = jax.tree.map(np.asarray, tree)    # snapshot NOW

        def work():
            save_tree(host_tree, self._step_dir(step))
            self._enforce_retention()

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return step, restore_tree(template, self._step_dir(step), shardings)

    def _enforce_retention(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
