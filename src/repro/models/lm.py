"""Unified decoder-only LM: dense / MoE / hybrid (Zamba2) / RWKV6 / VLM.

Layer parameters are stacked on a leading L axis and executed with
``lax.scan`` (HLO size independent of depth); ``scan_layers=False``
unrolls — used by the dry-run's L=1/L=2 cost-extrapolation variants.
``remat`` wraps the scan body with ``jax.checkpoint``.

The hybrid family scans *groups* of ``attn_every`` Mamba layers with the
shared attention block applied once per group inside the scan body —
the parameter set is closed over (not scanned), giving Zamba2's
parameter-sharing semantics for free.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn import (
    EmbeddingSpec,
    LinearSpec,
    embedding_apply,
    embedding_init,
    head_apply,
    init_kv_cache,
    init_rwkv_state,
    init_ssm_state,
    linear_apply,
    linear_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.sharding import shard
from .blocks import (
    attn_spec,
    block_apply,
    block_init,
    rwkv_spec,
    shared_attn_apply,
    shared_attn_init,
    ssm_spec,
)
from .config import ModelConfig


def embed_spec(cfg: ModelConfig) -> EmbeddingSpec:
    return EmbeddingSpec("embed", cfg.vocab, cfg.d_model, cfg.tt)


def head_spec(cfg: ModelConfig) -> LinearSpec:
    return LinearSpec("head", cfg.d_model, cfg.vocab, False, "head", cfg.tt)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _hybrid_groups(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, remainder_layers) for the hybrid family."""
    g = cfg.attn_every if cfg.attn_every else cfg.n_layers
    return cfg.n_layers // g, cfg.n_layers % g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_b, k_h, k_s = jax.random.split(rng, 4)
    params: dict[str, Any] = {
        "embed": embedding_init(k_e, embed_spec(cfg), dtype),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = linear_init(k_h, head_spec(cfg), dtype)
    keys = jax.random.split(k_b, cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: block_init(k, cfg, dtype))(keys)
    if cfg.family == "hybrid":
        params["shared_attn"] = shared_attn_init(k_s, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked per-layer decode caches (family-specific)."""
    def stack(make, n):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if cfg.family in ("dense", "moe", "vlm"):
        return stack(lambda: init_kv_cache(attn_spec(cfg), batch, max_seq, dtype),
                     cfg.n_layers)
    if cfg.family == "hybrid":
        n_groups, rem = _hybrid_groups(cfg)
        return {
            "ssm": stack(lambda: init_ssm_state(ssm_spec(cfg), batch, dtype),
                         cfg.n_layers),
            "attn": stack(lambda: init_kv_cache(attn_spec(cfg), batch, max_seq, dtype),
                          n_groups),
        }
    if cfg.family == "rwkv":
        return stack(lambda: init_rwkv_state(rwkv_spec(cfg), batch, dtype),
                     cfg.n_layers)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run_blocks(cfg, params, x, positions, caches, cache_pos):
    """Scan/unroll the stacked blocks.  Returns (x, new_caches, aux)."""
    has_cache = caches is not None

    def body(carry, inp):
        x, aux = carry
        p_l, cache_l = inp
        x, new_cache, a = block_apply(cfg, p_l, x, positions, cache_l, cache_pos)
        return (x, aux + a), new_cache

    body = _remat(cfg, body)

    if cfg.family == "hybrid":
        return _run_hybrid(cfg, params, x, positions, caches, cache_pos, body)

    blocks = params["blocks"]
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (blocks, caches) if has_cache else (blocks, None),
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        new_list = []
        for l in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[l], blocks)
            c_l = jax.tree.map(lambda a: a[l], caches) if has_cache else None
            (x, aux), nc = body((x, aux), (p_l, c_l))
            new_list.append(nc)
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if has_cache else None
        )
    return x, (new_caches if has_cache else None), aux


def _run_hybrid(cfg, params, x, positions, caches, cache_pos, body):
    """Groups of ``attn_every`` Mamba layers + shared attention per group."""
    n_groups, rem = _hybrid_groups(cfg)
    g = cfg.attn_every if cfg.attn_every else cfg.n_layers
    blocks = params["blocks"]
    has_cache = caches is not None
    main = jax.tree.map(lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]),
                        blocks)
    tail = jax.tree.map(lambda a: a[n_groups * g :], blocks)
    ssm_caches = caches["ssm"] if has_cache else None
    attn_caches = caches["attn"] if has_cache else None
    main_c = (
        jax.tree.map(lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]),
                     ssm_caches) if has_cache else None
    )
    tail_c = jax.tree.map(lambda a: a[n_groups * g :], ssm_caches) if has_cache else None

    def group_body(carry, inp):
        x, aux = carry
        gp, gc_ssm, gc_attn = inp
        (x, aux), new_ssm = jax.lax.scan(
            body, (x, aux), (gp, gc_ssm) if has_cache else (gp, None)
        )
        x, new_attn = shared_attn_apply(
            cfg, params["shared_attn"], x, positions, gc_attn, cache_pos
        )
        return (x, aux), (new_ssm, new_attn)

    group_body = _remat(cfg, group_body)

    if cfg.scan_layers:
        (x, aux), (new_main_ssm, new_attn) = jax.lax.scan(
            group_body, (x, jnp.zeros((), jnp.float32)),
            (main, main_c, attn_caches) if has_cache else (main, None, None),
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        ssm_list, attn_list = [], []
        for gi in range(n_groups):
            gp = jax.tree.map(lambda a: a[gi], main)
            gc_s = jax.tree.map(lambda a: a[gi], main_c) if has_cache else None
            gc_a = jax.tree.map(lambda a: a[gi], attn_caches) if has_cache else None
            (x, aux), (ns, na) = group_body((x, aux), (gp, gc_s, gc_a))
            ssm_list.append(ns)
            attn_list.append(na)
        new_main_ssm = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_list) if has_cache else None
        )
        new_attn = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *attn_list) if has_cache else None
        )

    new_tail = None
    if rem:
        (x, aux), new_tail = jax.lax.scan(
            body, (x, aux), (tail, tail_c) if has_cache else (tail, None)
        )

    new_caches = None
    if has_cache:
        flat_ssm = jax.tree.map(
            lambda a: a.reshape((n_groups * g,) + a.shape[2:]), new_main_ssm
        )
        if rem:
            flat_ssm = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), flat_ssm, new_tail
            )
        new_caches = {"ssm": flat_ssm, "attn": new_attn}
    return x, new_caches, aux


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,                       # (B, S) int32
    frontend: Optional[jax.Array] = None,    # (B, P, D) patch/frame embeddings
    caches=None,
    cache_pos=None,
    return_hidden: bool = False,
) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (logits, new_caches, aux_loss).

    Train/prefill: ``caches=None``/given, full sequence.  Decode: S == 1.
    VLM: ``frontend`` prefix tokens are prepended (prefill only).
    ``return_hidden`` skips the LM head (chunked-loss path).
    """
    x = embedding_apply(embed_spec(cfg), params["embed"], tokens)
    n_prefix = 0
    if cfg.family == "vlm" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        n_prefix = frontend.shape[1]
    x = shard(x, "batch", "seq", None)
    b, s, _ = x.shape
    base = cache_pos if cache_pos is not None else 0
    base = jnp.asarray(base)
    if base.ndim == 1:
        # per-lane decode offsets (continuous batching): each slot of the
        # fixed-width batch sits at its own sequence position
        positions = base[:, None] + jnp.arange(s)[None, :]
    else:
        positions = base + jnp.arange(s)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    x, new_caches, aux = _run_blocks(cfg, params, x, positions, caches, cache_pos)

    x = rmsnorm(params["ln_f"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    if return_hidden:
        return x, new_caches, aux
    logits = apply_head(cfg, params, x)
    return logits, new_caches, aux


def apply_head(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = head_apply(embed_spec(cfg), params["embed"], x)
    else:
        logits = linear_apply(head_spec(cfg), params["head"], x)
    if logits.ndim == 2:        # chunked-loss path: (tokens, V)
        return shard(logits, "tokens", "model")
    return shard(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# loss / decode steps
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; vocab dim may be model-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.sum(jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32) * logits,
                 axis=-1)
    return jnp.mean(lse - ll)


def chunked_cross_entropy(
    head_fn, hidden: jax.Array, labels: jax.Array, chunk: int
) -> jax.Array:
    """Fused head + CE, scanned over sequence chunks.

    Bounds the live logits buffer at (B, chunk, V) — used when the vocab
    cannot shard on the model axis (odd vocab sizes).  ``head_fn`` maps
    hidden (B, c, D) -> logits (B, c, V).
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    if s % c:
        c = s
    n = s // c

    # INTERLEAVED chunking: flatten to the tokens layout (the merged
    # (batch x seq) dim keeps its DP(+SP) sharding), then split the token
    # dim as (T/n major, n minor) — the sharded MAJOR dim survives the
    # reshape, so every chunk stays fully distributed.  (Both contiguous
    # reshapes and traced-index dynamic_slice on a sharded dim force
    # GSPMD into full-tensor rematerialisation — measured as hundreds of
    # GB of all-gather per step before this change.)  Cross-entropy is a
    # token-permutation-invariant mean, so interleaving is exact.
    tokens = b * s
    hf = shard(hidden.reshape(tokens, d), "tokens", None)
    lf = labels.reshape(tokens)
    hs = jnp.swapaxes(hf.reshape(tokens // n, n, d), 0, 1)   # (n, T/n, D)
    ls = jnp.swapaxes(lf.reshape(tokens // n, n), 0, 1)

    @jax.checkpoint  # recompute the head chain in bwd — never stack its
    def body(acc, inp):  # per-chunk intermediates across the scan
        h, lab = inp
        h = shard(h, "tokens", None)
        logits = head_fn(h).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.sum(
            jax.nn.one_hot(lab, logits.shape[-1], dtype=jnp.float32) * logits,
            axis=-1,
        )
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    if cfg.loss_chunk:
        hidden, _, aux = forward(
            cfg, params, batch["tokens"], frontend=batch.get("frontend"),
            return_hidden=True,
        )
        ce = chunked_cross_entropy(
            lambda h: apply_head(cfg, params, h), hidden, batch["labels"],
            cfg.loss_chunk,
        )
        return ce + cfg.aux_loss_weight * aux
    logits, _, aux = forward(
        cfg, params, batch["tokens"], frontend=batch.get("frontend")
    )
    return cross_entropy(logits, batch["labels"]) + cfg.aux_loss_weight * aux


def prefill_full(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Like :func:`prefill`, but returns the *full* (B, S, V) logits.

    The serve scheduler prefills bucket-padded prompts and needs the
    logits at the last *real* token (position ``prompt_len - 1``), not
    the last padded one — it slices the full logits at a traced index.
    """
    b, s = batch["tokens"].shape
    caches = init_caches(cfg, b, max_seq, jnp.dtype(cfg.dtype))
    logits, caches, _ = forward(
        cfg, params, batch["tokens"], frontend=batch.get("frontend"),
        caches=caches, cache_pos=jnp.zeros((), jnp.int32),
    )
    return logits, caches


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Run the full prompt, returning (last-token logits, primed caches).

    Attention families write the whole prompt's K/V into the caches in one
    dynamic_update_slice (see ``attention_apply`` s>1-with-cache path);
    state families advance their recurrent state through the scan.
    """
    logits, caches = prefill_full(cfg, params, batch, max_seq)
    return logits[:, -1], caches


def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,          # (B, 1) int32
    caches,
    cache_pos: jax.Array,      # () int32 — tokens already cached
):
    """One decode step: returns (logits (B, V), new_caches)."""
    logits, new_caches, _ = forward(
        cfg, params, token, caches=caches, cache_pos=cache_pos
    )
    return logits[:, -1], new_caches


def count_params(params) -> int:
    return sum(int(math.prod(a.shape)) for a in jax.tree.leaves(params))
