"""Continuous-batching request scheduler over a :class:`ServeEngine`.

The scheduler runs a virtual step clock (1 tick = 1 fixed-width decode
step).  Each tick it (1) moves arrived requests from the future queue
into a FIFO ready queue, (2) admits ready requests into free decode
lanes — prefilling each at batch 1 and slot-writing its caches — up to
the admission policy's per-step cap, then (3) runs one decode step for
all occupied lanes.  ``schedule="oneshot"`` is the same loop with
concurrency capped at 1: each request decodes alone (at the same fixed
slot width), which is the bit-exact reference the continuous mode is
tested against.

Token selection is host-side and per-request deterministic: greedy
``np.argmax`` (first-max tie-break) at temperature 0, Gumbel-max
sampling from a per-``(seed, rid)`` generator otherwise — a request's
tokens never depend on which lanes its neighbours occupy.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Optional, Sequence

import numpy as np

from .engine import ServeEngine
from .request import Completion, Request

SCHEDULES = ("oneshot", "continuous")


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Admission policy knobs.

    ``max_admissions_per_step`` bounds how many prefills one tick may
    run before the decode step (None = fill every free lane); FIFO order
    means a waiting request is admitted after at most
    ``ceil(queue_position / admissions_per_tick)`` ticks once lanes
    free up — the starvation bound the robustness suite pins down.
    """

    schedule: str = "continuous"
    max_admissions_per_step: Optional[int] = None

    def __post_init__(self) -> None:
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; have {SCHEDULES}")
        if (self.max_admissions_per_step is not None
                and self.max_admissions_per_step < 1):
            raise ValueError("max_admissions_per_step must be >= 1 or None")


@dataclasses.dataclass(frozen=True)
class ServeResult:
    completions: tuple[Completion, ...]
    steps: int
    wall_s: float
    n_slots: int
    #: mean fraction of decode lanes occupied over all decode steps
    occupancy: float

    def tokens_by_rid(self) -> dict[int, tuple[int, ...]]:
        return {c.rid: c.tokens for c in self.completions}


class _Active:
    """A request occupying (or about to occupy) a decode lane."""

    __slots__ = ("req", "tokens", "t_ready", "t_first", "admitted_step")

    def __init__(self, req: Request, first: int, t_ready: float,
                 t_first: float, admitted_step: int) -> None:
        self.req = req
        self.tokens = [first]
        self.t_ready = t_ready
        self.t_first = t_first
        self.admitted_step = admitted_step


class Scheduler:
    def __init__(self, engine: ServeEngine,
                 policy: Optional[ServePolicy] = None, *,
                 temperature: float = 0.0, seed: int = 0) -> None:
        self.engine = engine
        self.policy = policy or ServePolicy()
        self.temperature = float(temperature)
        self.seed = int(seed)

    def _select(self, row: np.ndarray, rng: np.random.Generator) -> int:
        if self.temperature <= 0:
            return int(np.argmax(row))
        z = row.astype(np.float64) / self.temperature
        return int(np.argmax(z + rng.gumbel(size=z.shape)))

    def _validate(self, requests: Sequence[Request]) -> list[Request]:
        eng = self.engine
        seen: set[int] = set()
        for r in requests:
            if r.rid in seen:
                raise ValueError(f"duplicate request id {r.rid}")
            seen.add(r.rid)
            pp = eng.padded_len(len(r.prompt))
            need = max(pp, len(r.prompt) + r.max_new_tokens - 1)
            if need > eng.max_seq:
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions "
                    f"(prompt {len(r.prompt)} padded to {pp}, "
                    f"gen {r.max_new_tokens}) but max_seq is {eng.max_seq}")
        # stable sort: arrival order, rid breaks ties deterministically
        return sorted(requests, key=lambda r: (r.arrival, r.rid))

    def run(self, requests: Sequence[Request]) -> ServeResult:
        reqs = self._validate(requests)
        eng = self.engine
        ns = eng.n_slots
        concurrency = 1 if self.policy.schedule == "oneshot" else ns

        future = collections.deque(reqs)
        ready: collections.deque[tuple[Request, float]] = collections.deque()
        slots: list[Optional[_Active]] = [None] * ns
        tok = np.zeros(ns, np.int64)
        pos = np.zeros(ns, np.int64)
        caches = eng.fresh_caches()
        rngs: dict[int, np.random.Generator] = {}
        done: list[Completion] = []

        step = 0
        active = 0
        lane_steps = 0   # sum over decode steps of occupied lanes
        decode_steps = 0
        t0 = time.perf_counter()

        while future or ready or active:
            while future and future[0].arrival <= step:
                ready.append((future.popleft(), time.perf_counter()))

            admitted = 0
            cap = self.policy.max_admissions_per_step
            while (ready and active < concurrency
                   and (cap is None or admitted < cap)):
                free = next((i for i, s in enumerate(slots) if s is None),
                            None)
                if free is None:
                    break
                req, t_ready = ready.popleft()
                rng = np.random.default_rng((self.seed, req.rid))
                rngs[req.rid] = rng
                row, small = eng.prefill_request(req.prompt)
                first = self._select(row, rng)
                st = _Active(req, first, t_ready, time.perf_counter(), step)
                admitted += 1
                if req.max_new_tokens == 1:
                    # done at admission — never occupies a decode lane
                    done.append(self._complete(st, step))
                    continue
                caches = eng.admit(caches, small, free)
                slots[free] = st
                tok[free] = first
                pos[free] = len(req.prompt)
                active += 1

            if active == 0:
                if not future and not ready:
                    break  # drained
                if ready:
                    # admission cap hit on single-token requests — next
                    # tick's fresh cap admits the rest
                    step += 1
                else:
                    # idle: jump the clock to the next arrival
                    step = max(step + 1, math.ceil(future[0].arrival))
                continue

            rows, caches = eng.decode(tok, pos, caches)
            decode_steps += 1
            lane_steps += active
            step += 1
            for i, st in enumerate(slots):
                if st is None:
                    continue
                nxt = self._select(rows[i], rngs[st.req.rid])
                st.tokens.append(nxt)
                tok[i] = nxt
                pos[i] += 1
                if len(st.tokens) >= st.req.max_new_tokens:
                    done.append(self._complete(st, step))
                    slots[i] = None
                    tok[i] = 0
                    pos[i] = 0
                    active -= 1

        wall = time.perf_counter() - t0
        occ = lane_steps / (decode_steps * ns) if decode_steps else 0.0
        return ServeResult(
            completions=tuple(sorted(done, key=lambda c: c.rid)),
            steps=step, wall_s=wall, n_slots=ns, occupancy=occ)

    @staticmethod
    def _complete(st: _Active, step: int) -> Completion:
        return Completion(
            rid=st.req.rid, prompt_len=len(st.req.prompt),
            tokens=tuple(st.tokens), arrival=st.req.arrival,
            admitted_step=st.admitted_step, done_step=step,
            t_ready=st.t_ready, t_first=st.t_first,
            t_done=time.perf_counter())
