"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.

Assigned dims: 24L d_model=2048 16H (kv=16) d_ff=1408 (per routed expert)
vocab=151936, MoE 60e top-4  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  The 4
shared experts are merged into one always-on FFN of width 4*1408=5632
(mathematically identical, fewer kernels).
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151_936,
    head_dim=128,
    moe_experts=60,
    moe_top_k=4,
    moe_shared=4,
    moe_shared_d_ff=5632,
    qkv_bias=True,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="qwen2moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab=256,
    head_dim=16,
    moe_experts=6,
    moe_top_k=2,
    moe_shared=2,
    moe_shared_d_ff=96,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
