"""Unified linear layer: dense or TT-factorized (the paper's technique).

Every projection in every model goes through this module, so flipping
``TTConfig.enabled`` tensorizes an entire architecture.  TT weights are
stored as their cores; the forward pass contracts input activations
through the cores along a contraction path chosen by the DSE (defaults to
the MAC-optimal candidate when no plan is installed).

Path search happens at *trace time* (shapes are static under jit) and is
memoised per network signature, so scan/jit tracing pays it once.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import CandidatePath, find_topk_paths
from repro.core.tensor_network import TensorNetwork, factorize, tt_linear_network
from repro.core.contraction import execute_path
from repro.sharding import shard as _shard


_EDGE_AXES = {"b": "tokens", "b0": "batch", "b1": "seq"}


def _single_device() -> bool:
    """True when no multi-device sharding rules are installed.

    Planned kernels run locally in this case.  On a >1-device mesh the
    dispatcher instead routes through ``repro.plan.sharded`` — explicit
    ``shard_map`` over the rules' token axes, per-shard Pallas execution
    — whenever the mesh can take the problem (a real ``rules.mesh`` and
    a token count divisible over the DP axes); only when it cannot does
    the jnp executor fall back with sharding constraints.
    """
    from repro.sharding import get_rules

    rules = get_rules()
    return rules is None or all(v <= 1 for v in rules.axis_sizes.values())


def _constrain_tokens(edges, t):
    """Pin TT-intermediate batch edges to their logical mesh axes.

    Split batch edges (b0=batch, b1=seq) keep the (B, S, ...) layout of
    the surrounding model — no tokens-flatten relayout; the flattened
    single-edge form maps to the merged DP(+SP) "tokens" axis.
    """
    axes = tuple(_EDGE_AXES.get(e) for e in edges)
    if any(axes):
        return _shard(t, *axes)
    return t


@dataclasses.dataclass(frozen=True)
class TTConfig:
    """Model-wide tensorization settings."""

    enabled: bool = False
    d: int = 3                      # modes per side
    rank: int = 16
    min_dim: int = 512              # tensorize only matrices with both dims >= this
    targets: tuple[str, ...] = ("attn", "mlp", "head")
    top_k: int = 4                  # candidate paths kept per layer (paper K)

    def applies(self, tag: str, d_in: int, d_out: int) -> bool:
        return (
            self.enabled
            and tag in self.targets
            and min(d_in, d_out) >= self.min_dim
        )


#: a per-layer TT factorization override: (out_modes, in_modes, ranks)
FactorizationTriple = tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Static description of one projection.

    ``factorization`` overrides the TTConfig-derived (out_modes,
    in_modes, ranks) for this one projection — the per-family handle the
    rank search (``repro.rank``) turns; when unset, a model-level
    override installed from a v4 plan (:data:`_FACTORIZATION`) applies,
    and otherwise the modes/ranks derive from the model-wide ``tt``.
    """

    name: str
    d_in: int
    d_out: int
    bias: bool = False
    tag: str = "mlp"                # attn | mlp | head | embed | other
    tt: Optional[TTConfig] = None
    factorization: Optional[FactorizationTriple] = None

    @property
    def tensorized(self) -> bool:
        return self.tt is not None and self.tt.applies(self.tag, self.d_in, self.d_out)

    def _factor(self) -> Optional[FactorizationTriple]:
        if self.factorization is not None:
            return self.factorization
        return _FACTORIZATION.get(self.name)

    def with_factorization(
        self,
        out_modes: Sequence[int],
        in_modes: Sequence[int],
        ranks: Sequence[int],
    ) -> "LinearSpec":
        """A copy pinned to an explicit (out_modes, in_modes, ranks)."""
        out_modes = tuple(int(m) for m in out_modes)
        in_modes = tuple(int(m) for m in in_modes)
        ranks = tuple(int(r) for r in ranks)
        if math.prod(out_modes) != self.d_out:
            raise ValueError(
                f"{self.name}: out_modes {out_modes} do not factor "
                f"d_out={self.d_out}")
        if math.prod(in_modes) != self.d_in:
            raise ValueError(
                f"{self.name}: in_modes {in_modes} do not factor "
                f"d_in={self.d_in}")
        if len(ranks) != len(out_modes) + len(in_modes) - 1:
            raise ValueError(
                f"{self.name}: need {len(out_modes) + len(in_modes) - 1} "
                f"interior ranks, got {len(ranks)}")
        return dataclasses.replace(
            self, factorization=(out_modes, in_modes, ranks))

    @property
    def in_modes(self) -> tuple[int, ...]:
        f = self._factor()
        if f is not None:
            return f[1]
        assert self.tt is not None
        return factorize(self.d_in, self.tt.d)

    @property
    def out_modes(self) -> tuple[int, ...]:
        f = self._factor()
        if f is not None:
            return f[0]
        assert self.tt is not None
        return factorize(self.d_out, self.tt.d)

    @property
    def tt_ranks(self) -> tuple[int, ...]:
        """Interior ranks, clipped to the full-rank bound at each cut."""
        f = self._factor()
        if f is not None:
            return f[2]
        assert self.tt is not None
        modes = self.out_modes + self.in_modes
        ranks = []
        left, right = 1, math.prod(modes)
        for k in range(len(modes) - 1):
            left *= modes[k]
            right //= modes[k]
            ranks.append(min(self.tt.rank, left, right))
        return tuple(ranks)

    def n_params(self) -> int:
        if not self.tensorized:
            return self.d_in * self.d_out + (self.d_out if self.bias else 0)
        modes = self.out_modes + self.in_modes
        ranks = (1,) + self.tt_ranks + (1,)
        total = sum(ranks[k] * modes[k] * ranks[k + 1] for k in range(len(modes)))
        return total + (self.d_out if self.bias else 0)

    def network(self, batch: int) -> TensorNetwork:
        return tt_linear_network(batch, self.in_modes, self.out_modes, self.tt_ranks)


# ---------------------------------------------------------------------------
# trace-time path cache + plan installation
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _topk_paths_cached(
    batch,                        # int or tuple of leading dims
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    k: int,
) -> tuple[CandidatePath, ...]:
    tn = tt_linear_network(batch, in_modes, out_modes, ranks)
    return tuple(find_topk_paths(tn, k=k))


_PLAN: dict[str, object] = {}  # linear name -> LayerPlan (from the DSE plan)

#: linear name -> (out_modes, in_modes, ranks) from a v4 plan's searched
#: factorizations.  Unlike _PLAN (swapped per serving phase), this
#: determines *parameter shapes* — a plan pair must carry identical
#: factorizations on both halves (the serve engine enforces it) and the
#: plan must be installed before ``init_params``.
_FACTORIZATION: dict[str, FactorizationTriple] = {}


def installed_factorizations() -> dict[str, FactorizationTriple]:
    """Snapshot of the model-level factorization overrides (name -> triple)."""
    return dict(_FACTORIZATION)


def install_plan(plan, *, force_backend: Optional[str] = None) -> None:
    """Install an :class:`repro.plan.ExecutionPlan` (or ``None`` to clear).

    Legacy form: a ``{name: path_index}`` mapping — wrapped into
    jnp-backend layer plans whose steps resolve against the trace-time
    top-K list (the pre-plan behaviour).

    ``force_backend`` overrides every entry's kernel backend (forcing
    ``"jnp"`` also forces the backward ops — a reference-executor plan
    stays reference end-to-end).  Pallas backends are differentiable:
    their custom VJP contracts the plan's backward networks through the
    planned kernels (``repro.plan.executor``), so training runs Pallas
    under ``jax.grad``.

    Install *before* tracing: jit caches baked with a previous plan are
    not invalidated.
    """
    from repro.plan.schema import BACKENDS, ExecutionPlan, LayerPlan

    if force_backend is not None and force_backend not in BACKENDS:
        raise ValueError(
            f"unknown force_backend {force_backend!r}; have {BACKENDS}")
    _PLAN.clear()
    _FACTORIZATION.clear()
    if plan is None:
        return
    if isinstance(plan, ExecutionPlan):
        entries = {lp.name: lp for lp in plan.layers}
        _FACTORIZATION.update({
            lp.name: lp.factorization.triple
            for lp in plan.layers if lp.factorization is not None
        })
    elif isinstance(plan, dict):
        entries = {
            name: LayerPlan(name=name, path_index=int(idx), path_steps=(),
                            dataflow="OS", partitioning=(1, 1), backend="jnp")
            for name, idx in plan.items()
        }
    else:
        raise TypeError(f"cannot install plan of type {type(plan).__name__}")
    if force_backend is not None:
        if force_backend != "jnp" and any(
                not v.path_steps for v in entries.values()):
            raise ValueError(
                f"force_backend={force_backend!r} requires plans with path "
                "steps; legacy name->index entries execute via jnp only")
        entries = {k: v.with_backend(force_backend) for k, v in entries.items()}
    _PLAN.update(entries)


def planned_layer(name: str):
    """The installed LayerPlan for a projection, or None."""
    return _PLAN.get(name)


@contextlib.contextmanager
def plan_context(plan, *, force_backend: Optional[str] = None) -> Iterator[None]:
    """Temporarily install ``plan`` (``None`` = run unplanned), restoring
    whatever was installed before on exit.

    This is the per-*phase* install primitive of the serve scheduler: the
    prefill stream traces under the prefill plan, the decode stream under
    the decode plan, and the boundary is a context switch rather than a
    global mutation the caller has to undo.  Tracing is lazy, so only
    calls that trace a new shape inside the context bake the plan; jit
    caches from earlier traces are (deliberately) untouched — switch
    plans before the first trace of a shape, as with ``install_plan``.
    """
    saved = dict(_PLAN)
    saved_fact = dict(_FACTORIZATION)
    install_plan(plan, force_backend=force_backend)
    try:
        yield
    finally:
        _PLAN.clear()
        _PLAN.update(saved)
        _FACTORIZATION.clear()
        _FACTORIZATION.update(saved_fact)


_CAPTURE: Optional[dict[str, list[float]]] = None


@contextlib.contextmanager
def capture_activation_rms() -> Iterator[dict[str, float]]:
    """Record per-projection input RMS during *eager* forward passes.

    Feeds the rank search's optional activation-weighted accuracy proxy
    (``repro.rank.proxy.activation_calibration``): families whose inputs
    run hot contribute more to the model-level reconstruction error.
    Traced (jit) calls are skipped — run the calibration batch eagerly.
    The yielded dict is filled with ``{name: mean input RMS}`` on exit.
    """
    global _CAPTURE
    saved, _CAPTURE = _CAPTURE, {}
    out: dict[str, float] = {}
    try:
        yield out
    finally:
        rec, _CAPTURE = _CAPTURE, saved
        for name, vals in rec.items():
            out[name] = float(np.mean(vals))


def _has_pallas_backward(lp) -> bool:
    """jnp-forward layers with Pallas *backward* ops (the auto-compiler
    emits these when only the weight-gradient GEMMs clear the kernel
    threshold) must still route through the planned executor's VJP."""
    from repro.plan.executor import has_pallas_backward

    return has_pallas_backward(lp)


def planned_path_index(name: str) -> int:
    lp = _PLAN.get(name)
    return lp.path_index if lp is not None else 0


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def linear_init(rng: jax.Array, spec: LinearSpec, dtype=jnp.float32) -> dict:
    if not spec.tensorized:
        k_w, _ = jax.random.split(rng)
        std = math.sqrt(2.0 / (spec.d_in + spec.d_out))
        params = {"w": (jax.random.normal(k_w, (spec.d_in, spec.d_out)) * std).astype(dtype)}
    else:
        modes = spec.out_modes + spec.in_modes
        ranks = (1,) + spec.tt_ranks + (1,)
        target = math.sqrt(2.0 / (spec.d_in + spec.d_out))
        prod_ranks = math.prod(spec.tt_ranks) or 1
        per_core_std = (target**2 / prod_ranks) ** (1.0 / (2 * len(modes)))
        keys = jax.random.split(rng, len(modes))
        cores = []
        for k in range(len(modes)):
            shape: tuple[int, ...] = (ranks[k], modes[k], ranks[k + 1])
            # boundary ranks of 1 are squeezed (matches tensor-network nodes)
            if k == 0:
                shape = (modes[k], ranks[k + 1])
            elif k == len(modes) - 1:
                shape = (ranks[k], modes[k])
            cores.append((jax.random.normal(keys[k], shape) * per_core_std).astype(dtype))
        params = {f"core{k}": c for k, c in enumerate(cores)}
    if spec.bias:
        params["b"] = jnp.zeros((spec.d_out,), dtype)
    return params


def linear_apply(
    spec: LinearSpec,
    params: dict,
    x: jax.Array,
    *,
    path_index: Optional[int] = None,
) -> jax.Array:
    """y = x @ W(^T) + b with x: (..., d_in) -> (..., d_out)."""
    lead = x.shape[:-1]
    if not spec.tensorized:
        y = jnp.einsum("...i,io->...o", x, params["w"])
    else:
        if _CAPTURE is not None and not isinstance(x, jax.core.Tracer):
            _CAPTURE.setdefault(spec.name, []).append(float(
                jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32))))))
        lp = planned_layer(spec.name) if path_index is None else None
        n_cores = len(spec.out_modes) + len(spec.in_modes)
        if lp is not None and (lp.backend != "jnp" or _has_pallas_backward(lp)):
            # planned kernel execution: flatten to (tokens, d_in) and route
            # through the plan's Pallas backend — locally on a single
            # device, via shard_map per-shard kernels on a mesh
            # (repro.plan.executor / repro.plan.sharded)
            tokens = math.prod(lead) if lead else 1
            decision = None
            routed = _single_device()
            if not routed:
                from repro.plan.sharded import shard_decision
                from repro.sharding import get_rules

                rules = get_rules()
                decision = shard_decision(rules, tokens, spec.in_modes)
                routed = decision is not None
            if routed:
                cores = [params[f"core{k}"] for k in range(n_cores)]
                x2d = x.reshape(tokens, spec.d_in)
                if decision is None:
                    from repro.plan.executor import planned_tt_linear

                    y2d = planned_tt_linear(
                        lp, x2d, cores,
                        spec.in_modes, spec.out_modes, spec.tt_ranks,
                    )
                else:
                    from repro.plan.sharded import sharded_tt_linear

                    y2d = sharded_tt_linear(
                        lp, x2d, cores,
                        spec.in_modes, spec.out_modes, spec.tt_ranks,
                        rules=rules, decision=decision,
                    )
                y = y2d.reshape(lead + (spec.d_out,)).astype(x.dtype)
                if spec.bias:
                    y = y + params["b"].astype(y.dtype)
                return y
        # keep (B, S) as split batch edges when present: shardings survive
        # without any tokens-flatten relayout (see _constrain_tokens)
        if len(lead) == 2:
            bdims: tuple | int = tuple(lead)
            b_edges = ("b0", "b1")
        else:
            bdims = math.prod(lead) if lead else 1
            b_edges = ("b",)
        xs = x.reshape(tuple(lead[:2] if len(lead) == 2 else (bdims,))
                       + spec.in_modes)
        in_edges = b_edges + tuple(f"j{t+1}" for t in range(len(spec.in_modes)))
        xs = _constrain_tokens(in_edges, xs)
        if lp is not None and lp.path_steps:
            # self-contained plan: replay its steps, skip the path search
            steps: tuple[tuple[int, int], ...] = lp.path_steps
        else:
            paths = _topk_paths_cached(
                bdims, spec.in_modes, spec.out_modes, spec.tt_ranks,
                spec.tt.top_k
            )
            idx = path_index if path_index is not None else planned_path_index(spec.name)
            steps = paths[min(idx, len(paths) - 1)].steps
        if lp is not None:
            from repro.plan.executor import record_execution

            # this branch always executes via jnp — log the effective backend
            eff = lp if lp.backend == "jnp" else lp.with_backend("jnp")
            record_execution(eff, math.prod(lead) if lead else 1)
        tn = tt_linear_network(bdims, spec.in_modes, spec.out_modes,
                               spec.tt_ranks)
        tensors = {"X": xs}
        core_names = [n.name for n in tn.nodes if n.name != "X"]
        for k, name in enumerate(core_names):
            tensors[name] = params[f"core{k}"]
        out_edges = b_edges + tuple(f"i{t+1}" for t in range(len(spec.out_modes)))
        y = execute_path(tn, steps, tensors, out_edges=out_edges,
                        constrain=_constrain_tokens)
        y = y.reshape(lead + (spec.d_out,))
    if spec.bias:
        y = y + params["b"].astype(y.dtype)
    return y


def linear_flops(spec: LinearSpec, tokens: int, path_index: int = 0) -> int:
    """Forward FLOPs for ``tokens`` rows (dense vs TT path)."""
    if not spec.tensorized:
        return 2 * tokens * spec.d_in * spec.d_out
    paths = _topk_paths_cached(
        tokens, spec.in_modes, spec.out_modes, spec.tt_ranks, spec.tt.top_k
    )
    return 2 * paths[min(path_index, len(paths) - 1)].macs
