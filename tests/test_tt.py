"""TT-SVD decomposition + INT8 quantization."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TTMatrix, reconstruction_error, tt_rand, tt_svd
from repro.core.tt import dequantize, quantize_int8


def test_tt_svd_full_rank_exact(rng):
    w = rng.normal(size=(24, 36))
    tt = tt_svd(w, (4, 6), (6, 6), max_rank=1000)
    assert reconstruction_error(tt, w) < 1e-10


def test_tt_svd_truncated_monotone(rng):
    w = rng.normal(size=(32, 32))
    errs = [
        reconstruction_error(tt_svd(w, (8, 4), (4, 8), max_rank=r), w)
        for r in (1, 2, 4, 8, 16, 32)
    ]
    assert all(errs[i] >= errs[i + 1] - 1e-12 for i in range(len(errs) - 1))


def test_tt_svd_low_rank_matrix_recovered(rng):
    u = rng.normal(size=(64, 3))
    v = rng.normal(size=(3, 64))
    w = u @ v
    tt = tt_svd(w, (8, 8), (8, 8), max_rank=16)
    assert reconstruction_error(tt, w) < 1e-8


def test_compression_ratio(rng):
    w = rng.normal(size=(256, 256))
    tt = tt_svd(w, (16, 16), (16, 16), max_rank=8)
    assert tt.compression_ratio > 5
    assert tt.n_params == sum(c.size for c in tt.cores)


def test_tt_rand_rank_clipping():
    rng = np.random.default_rng(0)
    tt = tt_rand(rng, (4, 4), (4, 4), rank=100)
    # interior ranks clipped to full-rank bounds
    assert max(tt.ranks) <= 64
    assert tt.to_matrix().shape == (16, 16)


# factor tuples paired with matched weight shapes so every drawn case
# is a valid (out_modes, in_modes) split of its matrix
_MODE_SPLITS = (
    ((24,), (18,)),
    ((4, 6), (6, 3)),
    ((2, 3, 4), (3, 2, 3)),
    ((8, 4), (2, 2, 8)),
)


@given(st.integers(1, 500), st.sampled_from(_MODE_SPLITS))
@settings(max_examples=30, deadline=None)
def test_tt_svd_full_rank_roundtrip_property(seed, split):
    out_modes, in_modes = split
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(np.prod(out_modes), np.prod(in_modes)))
    tt = tt_svd(w, out_modes, in_modes, max_rank=10**6)
    # unbounded rank => TT-SVD is an exact re-layout of w
    assert reconstruction_error(tt, w) < 1e-10
    assert np.allclose(tt.to_matrix(), w, atol=1e-10)


@given(st.integers(1, 500), st.sampled_from(_MODE_SPLITS))
@settings(max_examples=30, deadline=None)
def test_tt_svd_error_monotone_in_rank_property(seed, split):
    out_modes, in_modes = split
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(np.prod(out_modes), np.prod(in_modes)))
    errs = [reconstruction_error(tt_svd(w, out_modes, in_modes, max_rank=r), w)
            for r in (1, 2, 3, 4, 6, 8, 12, 24)]
    # more rank never hurts: truncation error is non-increasing
    assert all(errs[i] >= errs[i + 1] - 1e-12 for i in range(len(errs) - 1))


@given(st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(64,)).astype(np.float32) * rng.uniform(0.01, 100)
    q, scale = quantize_int8(x)
    err = np.abs(dequantize(q, scale) - x)
    assert np.max(err) <= scale / 2 + 1e-6


def test_int8_zero_tensor():
    q, scale = quantize_int8(np.zeros(8, np.float32))
    assert np.all(q == 0) and scale > 0
