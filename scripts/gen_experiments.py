"""Generate the data-driven tables of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python scripts/gen_experiments.py > /tmp/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.roofline import analyze_cell  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "results", "dryrun")
BASE = os.path.join(ROOT, "results", "dryrun_baseline")


def load(directory, pattern):
    out = {}
    for p in sorted(glob.glob(os.path.join(directory, pattern))):
        d = json.load(open(p))
        out[(d["arch"], d["shape"], d.get("multi_pod", False))] = d
    return out


def dryrun_table(cells, multi):
    print(f"\n### {'Multi-pod 2x16x16 (512 chips)' if multi else 'Single-pod 16x16 (256 chips)'}\n")
    print("| arch | shape | status | compile (s) | args (GiB) | temp (GiB) | "
          "collectives/step (MiB, scanned) |")
    print("|---|---|---|---|---|---|---|")
    for (arch, shape, mp), d in sorted(cells.items()):
        if mp != multi:
            continue
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | SKIP (full-attn @500k) | — | — | — | — |")
            continue
        m = d["memory"]
        sc = d.get("scanned_collectives", {})
        print(f"| {arch} | {shape} | {d['status']} | {d.get('compile_s','')} | "
              f"{m['argument_bytes']/2**30:.2f} | {m['temp_bytes']/2**30:.2f} | "
              f"{sc.get('total',0)/2**20:.0f} |")


def roofline_table(cells, title):
    print(f"\n### {title}\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | dense-equiv FLOPs ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for (arch, shape, mp), d in sorted(cells.items()):
        if mp:
            continue
        r = analyze_cell(d)
        if not r:
            continue
        print(f"| {arch} | {shape} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
              f"{r['collective_s']:.3g} | {r['dominant']} | "
              f"{r['useful_ratio']:.2f} | {r['bound_fraction']:.3f} |")


def perf_compare(base, new):
    print("\n### Before/after (per-device, all cells)\n")
    print("| arch | shape | coll bytes base | coll bytes opt | ratio | "
          "HLO bytes base | opt | ratio | temp GiB base | opt |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        if key not in new or key[2]:
            continue
        b, n = base[key], new[key]
        if b.get("status") != "ok" or n.get("status") != "ok":
            continue
        if "cost" not in b or "cost" not in n:
            continue
        cb, cn = b["cost"], n["cost"]
        rb = cb["collective_bytes_per_device"] or 1
        rn = cn["collective_bytes_per_device"] or 1
        print(f"| {key[0]} | {key[1]} | {rb:.2e} | {rn:.2e} | "
              f"{rb/rn:.1f}x | {cb['bytes_per_device']:.2e} | "
              f"{cn['bytes_per_device']:.2e} | "
              f"{cb['bytes_per_device']/cn['bytes_per_device']:.2f}x | "
              f"{b['memory']['temp_bytes']/2**30:.1f} | "
              f"{n['memory']['temp_bytes']/2**30:.1f} |")


def main():
    new_tt = load(DRY, "*_pod_tt.json")
    new_mp = load(DRY, "*_multipod_tt.json")
    base_tt = load(BASE, "*_pod_tt.json")
    section = sys.argv[1] if len(sys.argv) > 1 else "all"
    if section in ("all", "dryrun"):
        print("## Dry-run results")
        dryrun_table(new_tt, False)
        dryrun_table(new_mp, True)
    if section in ("all", "roofline"):
        print("\n## Roofline")
        roofline_table(base_tt, "Paper-faithful BASELINE (pre-optimization)")
        roofline_table(new_tt, "OPTIMIZED (after Perf iterations)")
    if section in ("all", "perf"):
        print("\n## Perf deltas")
        perf_compare(base_tt, new_tt)


if __name__ == "__main__":
    main()
