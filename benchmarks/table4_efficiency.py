"""Table 4 — energy-efficiency comparison (GOPS/W) vs prior FPGA trainers.

GOPS counts the dense-equivalent operations served per second (the
standard convention when comparing compressed accelerators — the TT
engine delivers the same functional work); latency comes from our
simulator, power from the paper's measured 21.2 W (ResNet-18 training,
TT-opt).  Prior-work rows are the paper's Table 4 constants.
"""

from __future__ import annotations

from repro.core import FPGA_VU9P, find_topk_paths, global_search
from repro.models.vision import model_layers
from .common import emit

PRIOR = [
    {"work": "[4] ZCU111", "eff_gops_w": None, "precision": "INT8"},
    {"work": "[23] Stratix10", "eff_gops_w": 9.0, "precision": "FP16"},
    {"work": "[21] ZCU102", "eff_gops_w": 8.2, "precision": "FP32"},
    {"work": "[15] MAX5", "eff_gops_w": 0.82, "precision": "INT8"},
    {"work": "[13] VC709", "eff_gops_w": 4.5, "precision": "PINT8"},
    {"work": "[6] ZCU102", "eff_gops_w": 15.1, "precision": "bm(2,5)"},
]
PAPER_POWER_W = 21.2     # measured TT-opt training power (paper Table 3)
PAPER_EFF = 19.19


def run() -> list[dict]:
    layers = model_layers("resnet18", "cifar10", batch=3)  # training mode
    dense_ops = 2 * sum(l.dense_macs for l in layers)      # dense-equivalent
    layer_paths = [find_topk_paths(l.tt_network, k=4) for l in layers]
    latency = global_search(layer_paths, FPGA_VU9P).total_latency_s
    gops = dense_ops / latency / 1e9
    rows = list(PRIOR)
    rows.append({
        "work": "Ours VU9P (simulated latency, paper power)",
        "eff_gops_w": gops / PAPER_POWER_W,
        "precision": "INT8",
    })
    rows.append({
        "work": "Ours VU9P (paper-reported)",
        "eff_gops_w": PAPER_EFF,
        "precision": "INT8",
    })
    emit("table4_efficiency", rows, keys=["work", "eff_gops_w", "precision"])
    return rows


if __name__ == "__main__":
    run()
