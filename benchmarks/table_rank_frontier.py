"""BENCH_rank — the decomposition axis: frozen baseline vs searched frontier.

Writes ``results/benchmarks/BENCH_rank.json``: per arch, every rank
candidate's (latency, compression, accuracy-proxy) triple, the
latency/accuracy Pareto frontier, and the chosen candidate — the
fastest one no less accurate than the frozen decomposition.  The
headline column is ``dominates_frozen``: whether the search found a
decomposition that is simultaneously faster and more accurate than the
model's frozen TTConfig point (on tt-lm-100m the degenerate d=1
low-rank candidate does).

  PYTHONPATH=src python -m benchmarks.run --only table_rank
"""

from __future__ import annotations

from repro.hw import get_target
from repro.rank import rank_search

from .common import emit, timed

ARCHS = ["tt-lm-100m", "vit_ti4/cifar10"]
TOP_K = 4
HW = "fpga_vu9p"


def run() -> list[dict]:
    rows = []
    hw_cfg = get_target(HW)
    for arch in ARCHS:
        res, wall_s = timed(rank_search, arch, hw_cfg, top_k=TOP_K,
                            repeat=1)
        frozen = res.frozen_eval
        chosen = res.chosen_eval
        cand_rows = [{
            "name": e.candidate.name,
            "d": e.candidate.d,
            "rank": e.candidate.rank,
            "latency_s": e.total_latency_s,
            "compression": e.candidate.compression,
            "accuracy_proxy": e.accuracy_proxy,
            "tt_params": e.candidate.n_params,
            "on_frontier": i in res.frontier,
            "eval_s": e.eval_seconds,
        } for i, e in enumerate(res.evals)]
        rows.append({
            "arch": arch,
            "hw": HW,
            "tokens": res.tokens,
            "n_candidates": len(res.evals),
            "frontier": [res.evals[i].candidate.name for i in res.frontier],
            "frozen_latency_s": frozen.total_latency_s,
            "frozen_proxy": frozen.accuracy_proxy,
            "chosen": chosen.candidate.name,
            "chosen_latency_s": chosen.total_latency_s,
            "chosen_proxy": chosen.accuracy_proxy,
            "chosen_compression": chosen.candidate.compression,
            "dominates_frozen": res.dominates_frozen,
            "improvement_pct": res.improvement_pct,
            "wall_s": wall_s,
            "candidates": cand_rows,
        })
    emit("BENCH_rank", rows,
         keys=["arch", "n_candidates", "chosen", "chosen_latency_s",
               "frozen_latency_s", "improvement_pct", "chosen_proxy",
               "frozen_proxy", "dominates_frozen", "wall_s"])
    return rows


if __name__ == "__main__":
    run()
