"""Jit'd public wrappers around the Pallas kernels.

On CPU containers the kernels execute in ``interpret=True`` mode (Python
evaluation of the kernel body — numerics identical); on TPU backends the
compiled Mosaic kernels run.  ``interpret`` is resolved from the default
backend unless forced.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.paths import CandidatePath
from repro.core.tensor_network import TensorNetwork
from . import tt_gemm as _tt_gemm
from . import streaming_tt as _streaming
from . import fused_path as _fused


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


#: zero-pad one axis up to a block multiple (canonical implementation
#: lives beside the kernel whose grid requires it)
_pad_to = _tt_gemm._pad_to_block


@functools.partial(
    jax.jit,
    static_argnames=("dataflow", "block_m", "block_k", "block_n", "interpret",
                     "differentiable"),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    dataflow: str = "OS",
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
    differentiable: bool = False,
) -> jax.Array:
    """Dataflow-configurable GEMM for arbitrary (non-block-multiple) dims.

    Padding to block multiples (and slicing back) happens inside
    :func:`tt_gemm.tt_gemm` itself — zero rows/columns contribute
    nothing to a matmul.  ``differentiable=True`` routes through
    :func:`tt_gemm.tt_gemm_vjp` (custom-VJP kernel whose backward GEMMs
    are also Pallas calls, each padding its own transposed shapes), so
    the whole call composes with ``jax.grad``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    kernel = _tt_gemm.tt_gemm_vjp if differentiable else _tt_gemm.tt_gemm
    return kernel(
        a, b,
        dataflow=dataflow,  # type: ignore[arg-type]
        block_m=block_m, block_k=block_k, block_n=block_n,
        interpret=interpret,
    )


def tt_linear(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    block_tokens: int = 256,
    interpret: bool | None = None,
    differentiable: bool = False,
    bwd_steps=None,
) -> jax.Array:
    """Streaming TT-linear; pads the token dim to the block multiple.

    ``differentiable=True`` routes through
    :func:`streaming_tt.streaming_tt_linear_vjp` (custom-VJP kernel: dx
    streams through the same Pallas kernel, weight grads contract their
    searched backward networks); ``bwd_steps`` optionally pins the
    DSE-searched backward path per gradient.  Padding rows are zero, so
    they contribute nothing to the weight gradients and their dx rows
    are sliced away — the padded call is exact under ``jax.grad``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    tokens = x.shape[0]
    xp = _pad_to(x, 0, block_tokens)
    if differentiable:
        y = _streaming.streaming_tt_linear_vjp(
            xp, cores, tn, path, bwd_steps=bwd_steps,
            block_tokens=block_tokens, interpret=interpret
        )
    else:
        y = _streaming.streaming_tt_linear(
            xp, cores, tn, path, block_tokens=block_tokens, interpret=interpret
        )
    return y[:tokens]


def fused_segment(
    work,
    steps,
    block_tokens: int = 256,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
    out_dtype=None,
):
    """Execute a chain run of contraction-path steps in one ``pallas_call``.

    Thin wrapper over :func:`fused_path.fused_segment_contract` resolving
    ``interpret`` from the default backend; ``work`` is the live
    ``execute_path`` work list and ``steps`` the current-index pairs of
    the segment.  Returns ``(result_edges, result)`` — the entry the
    sequential per-step route would have appended.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _fused.fused_segment_contract(
        work, steps, block_tokens=block_tokens, block_m=block_m,
        block_k=block_k, block_n=block_n,
        out_dtype=out_dtype, interpret=interpret)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def clamp_block(block: int, dim: int) -> int:
    """Shrink a compile-time block to the runtime dim (power of two, >= 8).

    The DSE tiles for its search-time shapes; a runtime call may carry
    fewer tokens (decode) or contract a smaller intermediate, and padding
    up to the full plan block would compute mostly zeros.
    """
    return max(8, min(block, _next_pow2(dim)))


def gemm_contract(
    dataflow: str = "OS",
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
    differentiable: bool = False,
):
    """A per-step ``contract_fn`` for ``core.contraction.execute_path``
    that lowers each pairwise tensor contraction to the dataflow-
    configurable Pallas GEMM.

    Operands are transposed to (free..., shared...) / (shared..., free...)
    and flattened to (M, K) @ (K, N); the result keeps tensordot's axis
    order (A's free axes then B's), so all edge bookkeeping stays in the
    path executor.  Blocks are clamped to the runtime dims.
    """

    def contract(ta: jax.Array, tb: jax.Array, axes) -> jax.Array:
        ax_a, ax_b = axes
        a_free = [i for i in range(ta.ndim) if i not in ax_a]
        b_free = [i for i in range(tb.ndim) if i not in ax_b]
        a_dims = [ta.shape[i] for i in a_free]
        b_dims = [tb.shape[i] for i in b_free]
        m = math.prod(a_dims)
        n = math.prod(b_dims)
        k = math.prod(ta.shape[i] for i in ax_a)
        a2 = jnp.transpose(ta, a_free + list(ax_a)).reshape(m, k)
        b2 = jnp.transpose(tb, list(ax_b) + b_free).reshape(k, n)
        c2 = gemm(a2, b2, dataflow=dataflow,
                  block_m=clamp_block(block_m, m),
                  block_k=clamp_block(block_k, k),
                  block_n=clamp_block(block_n, n),
                  interpret=interpret,
                  differentiable=differentiable)
        return c2.reshape(tuple(a_dims) + tuple(b_dims))

    return contract
