"""Table 5 — simulated training latency: train-DSE TT vs dense baseline.

The paper reports 3.42-3.85x lower *training* latency from jointly
exploring contraction path x hardware x dataflow.  Unlike table3 (which
proxies training as 3x inference tokens), this table runs the actual
training cost model on both sides: forward + backward (per-gradient
best contraction path under the layer's dataflow) + optimizer update,
through ``global_search(objective="train-latency")``.

The dense baseline gets the same treatment — its dx/dW gradient networks
and its best dataflow per layer — so the ratio isolates tensorization +
joint search, not modelling asymmetry.

Known conservatism: each TT gradient (dx + one per core) is charged an
*independent* full-network contraction — no cross-gradient reuse of
partial chains — while the dense backward is just two GEMMs.  The
simulated speedups therefore land below the paper's measured 3.42-3.85x
(the paper's engine shares intermediates across the per-core gradients);
the table reports both so the gap stays visible.
"""

from __future__ import annotations

import math

from repro.core import (
    FPGA_VU9P,
    find_topk_paths,
    global_search,
    greedy_path,
    memoised_layer_backwards,
)
from repro.core.tensor_network import dense_linear_network
from repro.models.vision import model_layers
from .common import emit

#: paper Table 5 (VU9P): end-to-end training latency reduction
PAPER = {
    ("resnet18", "cifar10"): 3.85,
    ("resnet18", "tiny_imagenet"): 3.82,
    ("vit_ti4", "cifar10"): 3.42,
}

BATCH = 8  # training mini-batch streamed per layer


def _train_latency(networks, top_k: int) -> tuple[float, dict]:
    layer_paths = [find_topk_paths(tn, k=top_k) if top_k > 1
                   else [greedy_path(tn)] for tn in networks]
    lbs = memoised_layer_backwards(networks, k=top_k)
    res = global_search(layer_paths, FPGA_VU9P, objective="train-latency",
                        layer_backwards=lbs)
    breakdown = {
        "fwd_s": sum(c.fwd_latency_s for c in res.choices),
        "bwd_s": sum(c.bwd_latency_s for c in res.choices),
        "update_s": sum(c.update_latency_s for c in res.choices),
    }
    return res.total_latency_s, breakdown


def _lm_networks(arch: str, tokens: int):
    """(tt_networks, dense_networks) for a registry LM config."""
    from repro.configs import get_config
    from repro.dse_cli import _block_specs

    cfg = get_config(arch)
    tt_nets, dense_nets = [], []
    for spec, count, scale in _block_specs(cfg):
        t = max(1, math.ceil(tokens * scale))
        for _ in range(count):
            dense_nets.append(dense_linear_network(t, spec.d_in, spec.d_out))
            # the TT model keeps its non-tensorized projections dense
            tt_nets.append(spec.network(t) if spec.tensorized else
                           dense_linear_network(t, spec.d_in, spec.d_out))
    return tt_nets, dense_nets


def run() -> list[dict]:
    rows = []
    for model, dataset in [("resnet18", "cifar10"),
                           ("resnet18", "tiny_imagenet"),
                           ("vit_ti4", "cifar10")]:
        layers = model_layers(model, dataset, batch=BATCH)
        dense_s, dense_bd = _train_latency(
            [l.dense_network for l in layers], top_k=1)
        tt_s, tt_bd = _train_latency(
            [l.tt_network for l in layers], top_k=4)
        rows.append({
            "model": model,
            "dataset": dataset,
            "dense_train_s": dense_s,
            "tt_train_s": tt_s,
            "tt_fwd_s": tt_bd["fwd_s"],
            "tt_bwd_s": tt_bd["bwd_s"],
            "tt_update_s": tt_bd["update_s"],
            "speedup": dense_s / tt_s,
            "paper": PAPER[(model, dataset)],
        })
    # extension beyond the paper: the bundled TT language model
    tt_nets, dense_nets = _lm_networks("tt-lm-100m", tokens=1024)
    dense_s, _ = _train_latency(dense_nets, top_k=1)
    tt_s, tt_bd = _train_latency(tt_nets, top_k=4)
    rows.append({
        "model": "tt-lm-100m",
        "dataset": "lm1b-synth",
        "dense_train_s": dense_s,
        "tt_train_s": tt_s,
        "tt_fwd_s": tt_bd["fwd_s"],
        "tt_bwd_s": tt_bd["bwd_s"],
        "tt_update_s": tt_bd["update_s"],
        "speedup": dense_s / tt_s,
        "paper": None,
    })
    emit("table5_training_latency", rows)
    return rows


if __name__ == "__main__":
    run()
