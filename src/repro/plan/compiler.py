"""DSEResult -> ExecutionPlan compiler (the "deploy" half of Algorithm 1).

The DSE emits per-layer-*instance* choices; the model executes repeated
blocks under one scanned trace.  The compiler therefore:

1. collapses instances (``attn.wq[0]``..``attn.wq[L-1]``) to one
   :class:`LayerPlan` per projection family — lossless, because identical
   tensor networks produce identical cost-table rows and argmins;
2. picks the kernel **backend** per layer:
   - ``streaming_tt`` when the whole contraction fits the VMEM budget at
     the plan's token-block size (cores pinned, activations streamed, no
     intermediate spills) — the fused in-VMEM chain of paper §4.2;
   - ``tt_gemm`` otherwise, lowering every pairwise contraction of the
     path to the dataflow-configurable Pallas GEMM;
   - ``jnp`` when the layer's GEMMs are too small for kernel tiling to
     pay off (and always available as the reference fallback);
3. derives the **tiling** from the path's dominant GEMM — and, for
   *co-searched* results, from the winning architecture: ``block_m``/
   ``block_n`` are capped by the searched array shape (R rows stream M,
   C columns stream N), ``block_k`` by its longer side, and the
   streaming backend's VMEM budget by the searched on-chip buffer
   capacity, so a plan emitted under ``--hw-search`` tiles for the
   architecture that won.  Fixed-target results keep the MXU-aligned
   default caps: the cost-model target (e.g. the FPGA) is *not* the
   execution substrate, and shrinking TPU Pallas blocks to an FPGA's
   32x32 array would only multiply grid steps.

Core partitioning (``1x2``/``2x1``) is an FPGA half-core construct with
no TPU kernel realization; it is recorded verbatim for provenance and for
the analytic latency numbers, but does not affect backend routing.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Mapping, Optional, Sequence

from repro.core import fusion
from repro.core.backward import backward_networks
from repro.core.dse import DSEResult, LayerChoice
from repro.core.paths import CandidatePath
from repro.core.simulator import HardwareConfig
from repro.core.tensor_network import Node, TensorNetwork

from .schema import (
    BACKENDS,
    PHASES,
    TILING_MODES,
    BackwardOp,
    ExecutionPlan,
    Factorization,
    LayerPlan,
    PlanSharding,
    Tiling,
)

#: conservative VMEM ceiling for the streaming backend (half a v5e core's
#: 16 MiB VMEM, leaving headroom for double-buffering the token blocks);
#: the effective budget is the min of this and the plan architecture's
#: on-chip buffer capacity (:func:`_streaming_budget`)
VMEM_BUDGET_BYTES = 8 * 2**20

#: below this many MACs in the dominant GEMM, kernel dispatch overhead
#: dominates and the plan keeps the pure-jnp executor
MIN_KERNEL_MACS = 1 << 16

#: fallback tiling caps when no architecture is supplied (MXU-aligned)
_DEFAULT_BLOCK_CAP = 128


def _streaming_budget(hw: Optional[HardwareConfig]) -> int:
    """VMEM budget for the streaming backend under ``hw``'s buffers."""
    if hw is None:
        return VMEM_BUDGET_BYTES
    return min(VMEM_BUDGET_BYTES,
               hw.sram_input_bytes + hw.sram_output_bytes)

_INSTANCE_RE = re.compile(r"\[\d+\]$")


def base_name(instance_name: str) -> str:
    """``attn.wq[3]`` -> ``attn.wq`` (DSE instance -> projection family)."""
    return _INSTANCE_RE.sub("", instance_name)


def _pow2_le(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _input_node(tn: TensorNetwork) -> Node:
    return next(n for n in tn.nodes if n.kind == "input")


def batch_dim(tn: TensorNetwork) -> int:
    """The streamed (batch) extent of a layer network.

    The batch dims are exactly the input node's *free* edges — the mode
    edges are all shared with cores.  Works for TT-linear networks (edge
    ``b``, or ``b0``/``b1`` split; leading) and TT-conv networks (patch
    edge ``l``; trailing).
    """
    x = _input_node(tn)
    free = set(tn.free_edges)
    return math.prod(d for e, d in zip(x.edges, x.dims) if e in free)


def rebatch(tn: TensorNetwork, tokens: int) -> TensorNetwork:
    """Rebind the input node's batch (free) edges to ``tokens`` total."""
    x = _input_node(tn)
    free = set(tn.free_edges)
    dims, first = [], True
    for e, d in zip(x.edges, x.dims):
        if e in free:
            dims.append(tokens if first else 1)
            first = False
        else:
            dims.append(d)
    nodes = [Node(n.name, n.edges, tuple(dims), n.kind)
             if n.name == x.name else n for n in tn.nodes]
    return TensorNetwork(nodes)


def _peak_live_elements(tn: TensorNetwork, steps) -> int:
    """Max total elements live at any point while replaying ``steps``."""
    peak = sum(n.size for n in tn.nodes)
    cur = tn
    for (i, j) in steps:
        cur, _ = cur.contract_pair(i, j)
        peak = max(peak, sum(n.size for n in cur.nodes))
    return peak


def streaming_fits(
    tn: TensorNetwork,
    steps,
    block_tokens: int,
    *,
    bytes_per_elem: int = 4,
    budget_bytes: int = VMEM_BUDGET_BYTES,
) -> bool:
    """Whether the full contraction of one token block stays in VMEM."""
    block = rebatch(tn, block_tokens)
    return _peak_live_elements(block, steps) * bytes_per_elem <= budget_bytes


def default_blocks(
    M: int, K: int, N: int,
    cap_m: int = _DEFAULT_BLOCK_CAP,
    cap_k: Optional[int] = None,
    cap_n: int = _DEFAULT_BLOCK_CAP,
) -> tuple[int, int, int]:
    """The heuristic ``(block_m, block_k, block_n)`` for one GEMM shape.

    Shared with the autotuner (``repro.tune.heuristic_blocks``), which
    measures its calibration at exactly this operating point — the
    tiling the analytic argmin would deploy.
    """
    cap_k = max(cap_m, cap_n) if cap_k is None else cap_k
    return (max(8, _pow2_le(min(cap_m, M))),
            max(8, _pow2_le(min(cap_k, K))),
            max(8, _pow2_le(min(cap_n, N))))


def _tiling_for_path(
    path: CandidatePath, tokens: int, hw: Optional[HardwareConfig] = None
) -> Tiling:
    """Blocks from the path's dominant (highest-MAC) GEMM, capped by the
    architecture's array shape: R rows stream the M dimension, C columns
    the N dimension, and the reduction tile by the longer side."""
    cap_m = hw.pe_rows if hw is not None else _DEFAULT_BLOCK_CAP
    cap_n = hw.pe_cols if hw is not None else _DEFAULT_BLOCK_CAP
    g = max(path.gemms, key=lambda g: g.macs)
    bm, bk, bn = default_blocks(g.M, g.K, g.N, cap_m=cap_m, cap_n=cap_n)
    return Tiling(
        block_m=bm,
        block_k=bk,
        block_n=bn,
        block_tokens=max(8, _pow2_le(min(256, tokens))),
    )


def choose_tiling(
    choice: LayerChoice, tokens: int, hw: Optional[HardwareConfig] = None
) -> Tiling:
    return _tiling_for_path(choice.path, tokens, hw)


def _measured_tiling(
    tn: TensorNetwork,
    choice: LayerChoice,
    heuristic: Tiling,
    backend: str,
    tokens: int,
    tuner,
    hw: Optional[HardwareConfig],
) -> Tiling:
    """Replace the heuristic tiling by the autotuner's measured argmin.

    The heuristic is injected into every sweep, so the measured tiling
    can tie it but never lose to it (on the machine doing the tuning).
    ``tt_gemm`` layers tune the dominant GEMM's ``block_m/k/n`` under
    the plan's dataflow; ``streaming_tt`` layers sweep ``block_tokens``
    within the same VMEM budget the backend choice assumed; ``jnp``
    layers (and streaming networks the kernel layout cannot express)
    keep the heuristic.
    """
    if backend == "tt_gemm":
        g = max(choice.path.gemms, key=lambda g: g.macs)
        bm, bk, bn = tuner.tune_gemm(
            int(g.M), int(g.K), int(g.N), choice.dataflow.value,
            include=[(heuristic.block_m, heuristic.block_k,
                      heuristic.block_n)])
        return dataclasses.replace(heuristic, block_m=bm, block_k=bk,
                                   block_n=bn)
    if backend == "streaming_tt":
        bt = tuner.tune_streaming(
            tn, choice.path.steps, tokens,
            include=[heuristic.block_tokens],
            budget_bytes=_streaming_budget(hw))
        if bt is not None:
            return dataclasses.replace(heuristic, block_tokens=bt)
    return heuristic


def choose_segments(
    tn: TensorNetwork,
    steps,
    tiling: Tiling,
    hw: Optional[HardwareConfig] = None,
) -> Optional[tuple[tuple[int, int], ...]]:
    """Fusion segmentation for a ``tt_gemm`` layer (``None`` = nothing fuses).

    Greedy maximal chain runs (``repro.core.fusion.segment_path``) under
    the same on-chip budget the streaming backend gets
    (:func:`_streaming_budget`), at the plan's token-block size.  The
    plan only records segments when at least one run spans >= 2 steps —
    an all-singleton segmentation is the absent-on-wire default, so
    pre-fusion plans and unfusable paths serialize identically.
    """
    segs = fusion.segment_path(
        tn, tuple(tuple(s) for s in steps),
        block_tokens=tiling.block_tokens,
        budget_bytes=_streaming_budget(hw))
    return segs if fusion.has_fused(segs) else None


def choose_backend(
    tn: TensorNetwork,
    choice: LayerChoice,
    tiling: Tiling,
    hw: Optional[HardwareConfig] = None,
) -> str:
    if max(g.macs for g in choice.path.gemms) < MIN_KERNEL_MACS:
        return "jnp"
    if streaming_fits(tn, choice.path.steps, tiling.block_tokens,
                      budget_bytes=_streaming_budget(hw)):
        return "streaming_tt"
    return "tt_gemm"


def _choose_bwd_backend(
    wrt: str,
    net: TensorNetwork,
    path: CandidatePath,
    tiling: Tiling,
    hw: Optional[HardwareConfig] = None,
) -> str:
    """Backend heuristic for one backward contraction.

    Mirrors the forward heuristic; only the single-streamed-operand dx
    gradient qualifies for the streaming kernel (weight gradients stream
    both X and dY).
    """
    if max(g.macs for g in path.gemms) < MIN_KERNEL_MACS:
        return "jnp"
    if wrt == "dx" and streaming_fits(net, path.steps, tiling.block_tokens,
                                      budget_bytes=_streaming_budget(hw)):
        return "streaming_tt"
    return "tt_gemm"


def _cached_bwd_tiling(
    net: TensorNetwork,
    ch,
    tiling: Tiling,
    be: str,
    dataflow: str,
    tokens: int,
    tuner,
) -> Tiling:
    """Measured backward-op tiling from the tuner's cache *only*.

    Train-mode plans may reuse measurements the forward sweeps already
    deposited (the cache is keyed by GEMM problem, not by direction), but
    backward ops never trigger new measurements — a cache miss keeps the
    analytic heuristic.
    """
    if be == "tt_gemm":
        g = max(ch.path.gemms, key=lambda g: g.macs)
        blocks = tuner.cached_gemm_blocks(int(g.M), int(g.K), int(g.N),
                                          dataflow)
        if blocks is not None:
            bm, bk, bn = blocks
            return dataclasses.replace(tiling, block_m=bm, block_k=bk,
                                       block_n=bn)
    elif be == "streaming_tt":
        bt = tuner.cached_streaming_tokens(net, ch.path.steps, tokens)
        if bt is not None:
            return dataclasses.replace(tiling, block_tokens=bt)
    return tiling


def _compile_backward(
    tn: TensorNetwork,
    choice: LayerChoice,
    tokens: int,
    backend: str,
    hw: Optional[HardwareConfig] = None,
    *,
    tilings: str = "heuristic",
    tuner=None,
) -> tuple[BackwardOp, ...]:
    """BackwardOps from a train-DSE choice (empty for inference results)."""
    if not choice.backward:
        return ()
    nets = dict(backward_networks(tn))
    ops = []
    for ch in choice.backward:
        net = nets[ch.wrt]
        tiling = _tiling_for_path(ch.path, tokens or batch_dim(tn), hw)
        if backend == "auto":
            be = _choose_bwd_backend(ch.wrt, net, ch.path, tiling, hw)
        elif backend == "streaming_tt" and ch.wrt != "dx":
            be = "tt_gemm"  # weight grads cannot stream; closest kernel
        else:
            be = backend
        if tilings == "measured" and tuner is not None:
            tiling = _cached_bwd_tiling(net, ch, tiling, be,
                                        choice.dataflow.value,
                                        tokens or batch_dim(tn), tuner)
        ops.append(BackwardOp(
            wrt=ch.wrt,
            path_index=ch.path_index,
            path_steps=tuple(tuple(s) for s in ch.path.steps),
            backend=be,
            tiling=tiling,
        ))
    return tuple(ops)


def _steps_in_range(n_nodes: int, steps) -> bool:
    """Replay current-index bookkeeping: every (i, j) must name two
    distinct live nodes (the merged node is appended, shrinking the list
    by one per step)."""
    n = n_nodes
    for (i, j) in steps:
        if i == j or not (0 <= i < n and 0 <= j < n):
            return False
        n -= 1
    return n == 1


def _model_dims(tn: TensorNetwork) -> tuple[int, int]:
    """(d_in, d_out) of the projection a TT-linear network computes.

    The input node's *shared* edges are the input modes (their product is
    ``d_in``), and the free edges of the core nodes are the output modes
    (their product is ``d_out``).  Both products are invariant under
    re-factorization, so they identify the projection regardless of which
    decomposition the network was built with.
    """
    x = _input_node(tn)
    free = set(tn.free_edges)
    d_in = math.prod(d for e, d in zip(x.edges, x.dims) if e not in free)
    d_out = math.prod(d for n in tn.nodes if n.kind != "input"
                      for e, d in zip(n.edges, n.dims) if e in free)
    return d_in, d_out


def validate_plan(
    plan,
    named_layers: Sequence[tuple[str, TensorNetwork]],
) -> list[str]:
    """Structural compatibility of a plan with a model's layer networks.

    Returns human-readable problem strings (empty = compatible): a plan
    layer whose step count cannot contract the model's network (emitted
    for a different TT geometry / smoke setting), a v4 factorization
    whose modes do not factor the model's projection dims, or a plan
    that matches no projection at all.  Called by the serve/train
    drivers before installing — a mismatched plan should fail loudly,
    not replay bogus steps deep inside tracing.

    Layers carrying a v4 ``factorization`` are checked against the
    geometry the plan *itself* prescribes (the installed plan overrides
    the model's default decomposition, so the model network's node count
    is not the reference for them — only its projection dims are).
    """
    families: dict[str, TensorNetwork] = {}
    for inst_name, tn in named_layers:
        families.setdefault(base_name(inst_name), tn)
    problems = []
    matched = 0
    for lp in plan.layers:
        tn = families.get(lp.name)
        if tn is None:
            continue  # plans may cover projections this model lacks
        matched += 1
        if lp.factorization is not None:
            f = lp.factorization
            d_in, d_out = _model_dims(tn)
            if (math.prod(f.in_modes) != d_in
                    or math.prod(f.out_modes) != d_out):
                problems.append(
                    f"{lp.name}: plan factorization "
                    f"{list(f.out_modes)}x{list(f.in_modes)} does not factor "
                    f"the model's {d_out}x{d_in} projection "
                    "(plan emitted for a different arch or smoke setting?)")
                continue
            # the factorized network: one node per core plus the input
            want_nodes = len(f.out_modes) + len(f.in_modes) + 1
        else:
            want_nodes = len(tn.nodes)
        if not lp.path_steps:
            if lp.backend == "jnp":
                continue  # index-only entry: steps resolve at trace time
            problems.append(
                f"{lp.name}: backend {lp.backend!r} requires path_steps "
                "(only jnp entries may be index-only)")
            continue
        if len(lp.path_steps) != want_nodes - 1:
            problems.append(
                f"{lp.name}: plan has {len(lp.path_steps)} contraction steps "
                f"but the model's network needs {want_nodes - 1} "
                "(plan emitted for a different TT geometry or smoke setting?)")
        elif not _steps_in_range(want_nodes, lp.path_steps):
            problems.append(
                f"{lp.name}: plan step indices {list(map(list, lp.path_steps))} "
                "do not describe a valid pairwise contraction of "
                f"{want_nodes} nodes (corrupted or hand-edited plan?)")
        if (lp.segments is not None and fusion.has_fused(lp.segments)
                and len(lp.path_steps) == len(tn.nodes) - 1
                and _steps_in_range(len(tn.nodes), lp.path_steps)):
            problems.extend(
                f"{lp.name}: {p}"
                for p in fusion.chain_problems(tn, lp.path_steps,
                                               lp.segments))
        if lp.backward and lp.factorization is None:
            want = {"dx"} | {n.name for n in tn.nodes if n.kind != "input"}
            got = {op.wrt for op in lp.backward}
            if got != want:
                problems.append(
                    f"{lp.name}: backward entries cover {sorted(got)} but "
                    f"the layer's gradients are {sorted(want)} "
                    "(hand-edited or geometry-mismatched plan?)")
        # every backward network of a TT layer has the same node count as
        # the forward (one node swapped for / replaced by dY), so the same
        # step-count check applies
        for op in lp.backward:
            if len(op.path_steps) != want_nodes - 1:
                problems.append(
                    f"{lp.name}: backward[{op.wrt}] has {len(op.path_steps)} "
                    f"steps but the gradient network needs "
                    f"{want_nodes - 1}")
            elif not _steps_in_range(want_nodes, op.path_steps):
                problems.append(
                    f"{lp.name}: backward[{op.wrt}] step indices are not a "
                    f"valid pairwise contraction of {want_nodes} nodes")
    if matched == 0:
        problems.append(
            "plan matches no tensorized projection of this model "
            f"(plan layers: {sorted(lp.name for lp in plan.layers)})")
    return problems


def check_plan_for_config(plan, arch: str, cfg,
                          *, phase: Optional[str] = None) -> list[str]:
    """Driver-side guard: is ``plan`` installable for (arch, cfg)?

    Combines the arch provenance check with :func:`validate_plan` over
    the model's actual tensorized projections.  LLM layer names collide
    across architectures (every transformer has an ``attn.wq``), so name
    matching alone would let a foreign plan install silently.

    ``phase`` additionally asserts the plan's serving-phase hint: a plan
    stamped ``"decode"`` installed as the prefill half of a pair (or vice
    versa) is flagged.  Phase-agnostic plans (``phase == ""``) install
    under any phase.
    """
    problems = []
    if plan.arch and plan.arch != arch:
        problems.append(
            f"plan was emitted for arch {plan.arch!r}, not {arch!r}")
    if phase is not None:
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}; have {PHASES}")
        if plan.phase and phase and plan.phase != phase:
            problems.append(
                f"plan is a {plan.phase} plan but would install as the "
                f"{phase} half of the pair (swapped --plan-prefill/"
                "--plan-decode?)")
    from repro.dse_cli import model_dse_layers

    # a v4 plan's factorizations define the networks it executes over —
    # rebuild the model's problems under them so path/step validation
    # runs against the decomposition the plan was actually compiled for
    fact = {lp.name: lp.factorization.triple
            for lp in plan.layers if lp.factorization is not None}
    try:
        named = model_dse_layers(cfg, tokens=8,
                                 factorizations=fact or None)
    except ValueError as e:
        problems.append(str(e.args[0] if e.args else e))
        return problems
    problems.extend(validate_plan(plan, named))
    return problems


def compile_plan(
    named_layers: Sequence[tuple[str, TensorNetwork]],
    result: DSEResult,
    hw: HardwareConfig,
    *,
    arch: str = "",
    objective: str = "latency",
    tokens: int = 0,
    backend: str = "auto",
    total_latency_s: Optional[float] = None,
    tilings: str = "heuristic",
    phase: str = "",
    tuner=None,
    factorizations: Optional[Mapping[str, Factorization]] = None,
    sharding: Optional[PlanSharding] = None,
) -> ExecutionPlan:
    """Compile a DSE result into an installable :class:`ExecutionPlan`.

    ``named_layers`` are the (instance_name, network) problems the search
    ran over, aligned with ``result.choices``.  ``backend`` forces every
    layer onto one executor (``"auto"`` = per-layer heuristic).  ``hw``
    is the architecture the result was evaluated on (pass ``result.hw``
    after a co-search): it is embedded in the plan (schema v3), and for
    co-searched results it also drives the kernel tiling caps and the
    streaming-backend VMEM budget.

    ``tilings="measured"`` replaces each layer's heuristic forward
    tiling by the measured argmin of ``tuner`` (a
    ``repro.tune.Autotuner`` — required in this mode): sweeps are
    deduped across layer families and served from the tuner's
    persistent cache, so a warm cache compiles without any measurement.
    Backend selection and backward-op tilings stay heuristic — the
    executor is unchanged either way.

    ``phase`` stamps the plan's serving-phase hint (``"prefill"`` /
    ``"decode"``; default phase-agnostic) — ``repro.dse
    --emit-plan-pair`` compiles one plan per phase, searched at that
    phase's token count, and the serve driver checks the stamp before
    installing.

    ``factorizations`` maps projection-family names to the searched TT
    decomposition (schema v4, from ``repro.rank``): the named layers
    must already have been built *under* that factorization — the
    compiler records it, it does not re-derive networks.

    ``sharding`` stamps the mesh provenance (``repro.dse --shards``):
    like factorizations, the named layers must already be the per-shard
    problems — ``tokens`` is then the per-shard token count the tilings
    derive from, matching what the shard_map executor streams per
    device.
    """
    if backend != "auto" and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {('auto',) + BACKENDS}")
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; have {PHASES}")
    if tilings not in TILING_MODES:
        raise ValueError(
            f"unknown tilings mode {tilings!r}; have {TILING_MODES}")
    if tilings == "measured" and tuner is None:
        raise ValueError(
            "tilings='measured' requires a tuner (repro.tune.Autotuner)")
    if len(named_layers) != len(result.choices):
        raise ValueError(
            f"{len(named_layers)} layers vs {len(result.choices)} choices")
    # hw caps apply only when the architecture was actually searched;
    # a fixed cost-model target says nothing about the execution device
    tile_hw = hw if result.hw_candidates else None

    by_family: dict[str, LayerPlan] = {}
    counts: dict[str, int] = {}
    for (inst_name, tn), choice in zip(named_layers, result.choices):
        name = base_name(inst_name)
        counts[name] = counts.get(name, 0) + 1
        if name in by_family:
            prev = by_family[name]
            bwd_steps = tuple(ch.path.steps for ch in choice.backward)
            prev_bwd_steps = tuple(op.path_steps for op in prev.backward)
            if (prev.path_steps != choice.path.steps
                    or prev.dataflow != choice.dataflow.value
                    or prev.partitioning != tuple(choice.partitioning)
                    or prev_bwd_steps != bwd_steps):
                raise ValueError(
                    f"instances of {name!r} received divergent DSE choices; "
                    "cannot collapse to one scanned layer plan")
            continue
        tiling = choose_tiling(choice, tokens or batch_dim(tn), tile_hw)
        be = (backend if backend != "auto"
              else choose_backend(tn, choice, tiling, tile_hw))
        if tilings == "measured":
            tiling = _measured_tiling(tn, choice, tiling, be,
                                      tokens or batch_dim(tn), tuner,
                                      tile_hw)
        segments = (choose_segments(tn, choice.path.steps, tiling, tile_hw)
                    if be == "tt_gemm" else None)
        by_family[name] = LayerPlan(
            name=name,
            path_index=choice.path_index,
            path_steps=tuple(tuple(s) for s in choice.path.steps),
            dataflow=choice.dataflow.value,
            partitioning=tuple(choice.partitioning),
            backend=be,
            tiling=tiling,
            backward=_compile_backward(tn, choice, tokens, backend, tile_hw,
                                       tilings=tilings, tuner=tuner),
            factorization=(factorizations.get(name)
                           if factorizations is not None else None),
            segments=segments,
            macs=choice.path.macs,
            latency_s=choice.latency_s,
            bwd_latency_s=choice.bwd_latency_s,
        )

    layers = tuple(
        dataclasses.replace(lp, instances=counts[lp.name])
        for lp in by_family.values()
    )
    return ExecutionPlan(
        layers=layers,
        arch=arch,
        hw=hw.name,
        objective=objective,
        strategy=result.strategy,
        tokens=tokens,
        total_latency_s=(result.total_latency_s if total_latency_s is None
                         else total_latency_s),
        hardware=hw,
        tilings=tilings,
        phase=phase,
        sharding=sharding,
    )
