import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and extracts the roofline
inputs:

  * ``memory_analysis()``  — per-device arg/temp/peak bytes (the full
    scanned model: its memory report is exact),
  * ``cost_analysis()``    — per-device FLOPs / bytes.  XLA counts a scan
    body ONCE, so per-cell totals come from unrolled L=1/L=2 (and
    family-specific) variants extrapolated linearly in depth — exact for
    homogeneous stacks (see ``_cost_variants``),
  * collective bytes       — parsed from the compiled HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute output
    shapes), same depth extrapolation.

Results land incrementally in ``results/dryrun/*.json`` — re-runs skip
existing cells unless ``--force``.

Usage:
  python -m repro.launch.dryrun --arch all --shape all            # single-pod 16x16
  python -m repro.launch.dryrun --arch all --shape all --multi-pod  # 2x16x16
  REPRO_DRYRUN_DEVICES=8 python -m repro.launch.dryrun --test-mesh ...  # CI
"""

import argparse
import json
import math
import re
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES, api, input_specs, shape_applicable
from repro.models.config import ModelConfig, ShapeConfig
from repro.launch.mesh import (
    batch_shardings,
    cache_shardings,
    make_production_mesh,
    make_rules,
    make_test_mesh,
    param_shardings,
    replicated,
)
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.optim import adamw_init
from repro.sharding import use_rules

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective kind (output-shape sizes).

    HLO lines look like ``%ag = bf16[8,128]{1,0} all-gather(%x)`` or
    ``(f32[..], f32[..]) all-reduce(..)``; we sum the result-shape bytes of
    every collective op (start/done pairs counted once via -start).
    """
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(?:\([^)]*\)|[a-z]+\d*\[[\d,]*\](?:\{[^}]*\})?)\s+([a-z0-9-]+)",
                     rhs)
        if not m:
            continue
        op = m.group(1)
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        header = rhs[: m.start(1)]
        size = 0.0
        for dt, dims in _SHAPE_RE.findall(header):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        out[base] += size
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _jsonable(x):
    if isinstance(x, (int, float, str, bool)) or x is None:
        return x
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return float(x)


# ---------------------------------------------------------------------------
# lowering one step kind
# ---------------------------------------------------------------------------

def lower_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """Returns the lowered computation for the cell's step kind."""
    m = api(cfg)
    specs = input_specs(cfg, shape)
    p_shapes = jax.eval_shape(m.init_params, jax.random.PRNGKey(0))
    p_sh = param_shardings(p_shapes, mesh)

    if shape.step == "train":
        step = make_train_step(cfg)
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        opt_sh = param_shardings(opt_shapes, mesh)
        b_sh = batch_shardings(specs, rules)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=(p_sh, opt_sh, replicated({"loss": 0, "grad_norm": 0}, mesh)),
        )
        return fn.lower(p_shapes, opt_shapes, specs)

    if shape.step == "prefill":
        extra = (cfg.n_frontend_tokens or 256) if cfg.family == "vlm" else 0
        max_seq = shape.seq_len + extra
        step = make_prefill_step(cfg, max_seq=max_seq)
        b_sh = batch_shardings(specs, rules)
        cache_shapes = jax.eval_shape(
            lambda: api(cfg).init_caches(shape.global_batch, max_seq))
        c_sh = cache_shardings(cfg, cache_shapes, rules)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh))
        return fn.lower(p_shapes, specs)

    if shape.step == "decode":
        step = make_decode_step(cfg)
        c_sh = cache_shardings(cfg, specs["caches"], rules)
        t_sh = batch_shardings(specs["token"], rules)
        pos_sh = replicated(specs["cache_pos"], mesh)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, t_sh, c_sh, pos_sh),
            out_shardings=(None, c_sh),
        )
        return fn.lower(p_shapes, specs["token"], specs["caches"],
                        specs["cache_pos"])

    raise ValueError(shape.step)


# ---------------------------------------------------------------------------
# depth-extrapolated cost variants
# ---------------------------------------------------------------------------

def _cost_variants(cfg: ModelConfig, shape: ShapeConfig
                   ) -> list[tuple[str, ModelConfig, float]]:
    """(label, unrolled variant, weight) triples; the total cost is the
    weighted sum after solving the per-layer deltas (see extrapolate).

    Variants are SCAN-FREE everywhere (unrolled layers, q_chunk = seq,
    no loss chunking): XLA counts every op exactly once, so the counts
    are exact — these variants are never executed, so their huge
    intermediate shapes cost nothing.  The full scanned model (chunked,
    remat'd) is what memory_analysis reports on.
    """
    base = dict(scan_layers=False, q_chunk=max(shape.seq_len, cfg.q_chunk),
                loss_chunk=0)
    if cfg.family == "hybrid":
        g = cfg.attn_every
        return [
            ("m1", cfg.with_(n_layers=1, attn_every=0, **base), 0.0),
            ("m2", cfg.with_(n_layers=2, attn_every=0, **base), 0.0),
            ("g1", cfg.with_(n_layers=g, attn_every=g, **base), 0.0),
        ]
    if cfg.family == "encdec":
        return [
            ("e1d1", cfg.with_(n_layers=1, encoder_layers=1, **base), 0.0),
            ("e2d1", cfg.with_(n_layers=1, encoder_layers=2, **base), 0.0),
            ("e1d2", cfg.with_(n_layers=2, encoder_layers=1, **base), 0.0),
        ]
    return [
        ("l1", cfg.with_(n_layers=1, **base), 0.0),
        ("l2", cfg.with_(n_layers=2, **base), 0.0),
    ]


def extrapolate(cfg: ModelConfig, values: dict[str, float]) -> float:
    """Combine variant costs into the full-depth estimate."""
    if cfg.family == "hybrid":
        mamba = values["m2"] - values["m1"]
        base = values["m1"] - mamba
        g = cfg.attn_every
        attn = values["g1"] - base - g * mamba
        n_groups = cfg.n_layers // g
        return base + cfg.n_layers * mamba + n_groups * attn
    if cfg.family == "encdec":
        enc = values["e2d1"] - values["e1d1"]
        dec = values["e1d2"] - values["e1d1"]
        base = values["e1d1"] - enc - dec
        return base + cfg.encoder_layers * enc + cfg.n_layers * dec
    per = values["l2"] - values["l1"]
    base = values["l1"] - per
    return base + cfg.n_layers * per


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def _cost_analysis(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions.

    jax < 0.5 returns a list with one properties-dict per computation;
    newer jax returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    tt: bool = True,
    test_mesh: bool = False,
    with_cost: bool = True,
    out_dir: str = RESULTS_DIR,
    force: bool = False,
    smoke: bool = False,
) -> dict:
    mesh_tag = ("test" if test_mesh else "") + ("multipod" if multi_pod else "pod")
    tt_tag = "tt" if tt else "dense"
    cell_id = f"{arch}_{shape_name}_{mesh_tag}_{tt_tag}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch, tt=tt, smoke=smoke)
    shape = SHAPES[shape_name]
    result: dict[str, Any] = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "multi_pod": multi_pod, "tt": tt, "step": shape.step,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        _write(path, result)
        return result

    mesh = make_test_mesh(multi_pod=multi_pod) if test_mesh \
        else make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, shape, mesh)
    result["mesh"] = {k: int(v) for k, v in mesh.shape.items()}
    result["n_devices"] = int(math.prod(mesh.shape.values()))
    result["sp_enabled"] = rules.seq_axis is not None

    try:
        t0 = time.time()
        with use_rules(rules):
            lowered = lower_step(cfg, shape, mesh, rules)
            compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 1)

        ma = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
        }
        ca = _cost_analysis(compiled)
        result["scanned_cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
        }
        result["scanned_collectives"] = collective_bytes(compiled.as_text())

        if with_cost and not multi_pod:
            variants = _cost_variants(cfg, shape)
            vals_f: dict[str, float] = {}
            vals_b: dict[str, float] = {}
            vals_c: dict[str, float] = {}
            for label, vcfg, _ in variants:
                with use_rules(rules):
                    vlow = lower_step(vcfg, shape, mesh, rules)
                    vcomp = vlow.compile()
                vca = _cost_analysis(vcomp)
                vals_f[label] = float(vca.get("flops", 0.0))
                vals_b[label] = float(vca.get("bytes accessed", 0.0))
                vals_c[label] = collective_bytes(vcomp.as_text())["total"]
            result["variant_flops"] = vals_f
            result["cost"] = {
                "flops_per_device": extrapolate(cfg, vals_f),
                "bytes_per_device": extrapolate(cfg, vals_b),
                "collective_bytes_per_device": extrapolate(cfg, vals_c),
            }
        result["status"] = "ok"
    except Exception as e:  # record the failure, keep sweeping
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]

    _write(path, result)
    return result


def _write(path: str, result: dict) -> None:
    with open(path, "w") as f:
        json.dump(_jsonable(result), f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dense", action="store_true", help="dense baseline (no TT)")
    ap.add_argument("--test-mesh", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    for arch in archs:
        for shape in shapes:
            t0 = time.time()
            r = run_cell(
                arch, shape,
                multi_pod=args.multi_pod,
                tt=not args.dense,
                test_mesh=args.test_mesh,
                with_cost=not args.no_cost,
                out_dir=args.out_dir,
                force=args.force,
                smoke=args.smoke,
            )
            status = r.get("status")
            extra = ""
            if status == "ok":
                mem = r["memory"]["peak_bytes"] or (
                    r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"])
                extra = f"peak={mem/2**30:.2f}GiB"
                if "cost" in r:
                    extra += f" flops/dev={r['cost']['flops_per_device']:.3e}"
            elif status == "error":
                extra = r["error"][:120]
            elif status == "skipped":
                extra = r["reason"]
            print(f"[{time.time()-t0:7.1f}s] {r['cell']:60s} {status:8s} {extra}",
                  flush=True)


if __name__ == "__main__":
    main()
