"""Serving metrics: throughput + per-request latency percentiles."""

from __future__ import annotations

import math
from typing import Sequence

from .scheduler import ServeResult


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, math.ceil(q / 100.0 * len(s)) - 1)
    return float(s[k])


def summarize(result: ServeResult) -> dict:
    """Flatten a :class:`ServeResult` into the BENCH/CI metric row.

    ``gen_tok_s`` counts generated tokens only (decode-weighted — the
    sustained-load number the throughput objective optimizes);
    ``latency_*`` is ready-to-done per request, ``ttft_*`` ready-to-
    first-token, both in milliseconds.
    """
    comps = result.completions
    gen = sum(len(c.tokens) for c in comps)
    total = sum(c.prompt_len + len(c.tokens) for c in comps)
    lat_ms = [(c.t_done - c.t_ready) * 1e3 for c in comps]
    ttft_ms = [(c.t_first - c.t_ready) * 1e3 for c in comps]
    wall = result.wall_s
    return {
        "n_requests": len(comps),
        "steps": result.steps,
        "n_slots": result.n_slots,
        "wall_s": wall,
        "generated_tokens": gen,
        "total_tokens": total,
        "gen_tok_s": gen / wall if wall > 0 else 0.0,
        "total_tok_s": total / wall if wall > 0 else 0.0,
        "mean_occupancy": result.occupancy,
        "ttft_p50_ms": percentile(ttft_ms, 50),
        "ttft_p95_ms": percentile(ttft_ms, 95),
        "latency_p50_ms": percentile(lat_ms, 50),
        "latency_p95_ms": percentile(lat_ms, 95),
    }
