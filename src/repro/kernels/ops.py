"""Jit'd public wrappers around the Pallas kernels.

On CPU containers the kernels execute in ``interpret=True`` mode (Python
evaluation of the kernel body — numerics identical); on TPU backends the
compiled Mosaic kernels run.  ``interpret`` is resolved from the default
backend unless forced.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.paths import CandidatePath
from repro.core.tensor_network import TensorNetwork
from . import tt_gemm as _tt_gemm
from . import streaming_tt as _streaming


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("dataflow", "block_m", "block_k", "block_n", "interpret"),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    dataflow: str = "OS",
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Dataflow-configurable GEMM; pads to block multiples and slices back."""
    interpret = _default_interpret() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(_pad_to(a, 0, block_m), 1, block_k)
    bp = _pad_to(_pad_to(b, 0, block_k), 1, block_n)
    out = _tt_gemm.tt_gemm(
        ap, bp,
        dataflow=dataflow,  # type: ignore[arg-type]
        block_m=block_m, block_k=block_k, block_n=block_n,
        interpret=interpret,
    )
    return out[:m, :n]


def tt_linear(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    block_tokens: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Streaming TT-linear; pads the token dim to the block multiple."""
    interpret = _default_interpret() if interpret is None else interpret
    tokens = x.shape[0]
    xp = _pad_to(x, 0, block_tokens)
    y = _streaming.streaming_tt_linear(
        xp, cores, tn, path, block_tokens=block_tokens, interpret=interpret
    )
    return y[:tokens]
