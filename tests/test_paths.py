"""MAC-guided top-K path search: correctness vs exhaustive enumeration."""

import itertools

from hypothesis import given, settings, strategies as st

from repro.core import (
    TensorNetwork,
    find_topk_paths,
    greedy_path,
    reconstruction_path,
    tt_linear_network,
)


def exhaustive_min_macs(tn: TensorNetwork) -> int:
    """Brute-force minimum MACs over ALL pairwise contraction orders."""
    best = [float("inf")]

    def rec(cur, acc):
        if acc >= best[0]:
            return
        if len(cur) == 1:
            best[0] = min(best[0], acc)
            return
        n = len(cur)
        for i in range(n):
            for j in range(i + 1, n):
                nxt, g = cur.contract_pair(i, j)
                rec(nxt, acc + g.macs)

    rec(tn, 0)
    return best[0]


def test_topk_matches_exhaustive_minimum():
    tn = tt_linear_network(3, (2, 3), (4, 2), (3, 2, 3))
    paths = find_topk_paths(tn, k=4)
    assert paths[0].macs == exhaustive_min_macs(tn)


def test_topk_sorted_and_distinct():
    tn = tt_linear_network(8, (4, 4), (4, 4), (4, 4, 4))
    paths = find_topk_paths(tn, k=6)
    macs = [p.macs for p in paths]
    assert macs == sorted(macs)
    sigs = {p.signature for p in paths}
    assert len(sigs) == len(paths)  # diversity: no equivalent duplicates


def test_topk_paths_are_valid():
    tn = tt_linear_network(8, (4, 4), (4, 4), (4, 4, 4))
    for p in find_topk_paths(tn, k=5):
        gemms = tn.gemm_sequence(p.steps)   # raises if invalid
        assert sum(g.macs for g in gemms) == p.macs


def test_greedy_not_better_than_optimal():
    tn = tt_linear_network(16, (4, 4, 4), (4, 4, 4), (8,) * 5)
    best = find_topk_paths(tn, k=1)[0]
    assert best.macs <= greedy_path(tn).macs


def test_reconstruction_path_is_expensive():
    """The naive 'materialise W then multiply' order (paper Fig. 3 left)
    must cost more than the searched optimum for a realistic layer."""
    tn = tt_linear_network(64, (8, 8, 8), (8, 8, 8), (16,) * 5)
    best = find_topk_paths(tn, k=1)[0]
    recon = reconstruction_path(tn)
    assert best.macs < recon.macs


@given(
    st.integers(1, 6),
    st.lists(st.integers(2, 4), min_size=1, max_size=2),
    st.lists(st.integers(2, 4), min_size=1, max_size=2),
    st.integers(2, 4),
)
@settings(max_examples=25, deadline=None)
def test_topk_optimal_vs_exhaustive_property(batch, im, om, rank):
    """Connected-pair DFS is exhaustive-optimal for non-degenerate ranks.

    (rank=1 TT chains are effectively disconnected — outer products can
    then beat connected orders; paper workloads use ranks 8-64 where the
    connected-only space contains the optimum.  See find_topk_paths docs.)
    """
    ranks = (rank,) * (len(im) + len(om) - 1)
    tn = tt_linear_network(batch, tuple(im), tuple(om), ranks)
    paths = find_topk_paths(tn, k=2)
    assert paths[0].macs == exhaustive_min_macs(tn)


def test_topk_rank1_degenerate_documented_limitation():
    """With rank-1 interior edges the connected-only search may be off by
    a small constant (outer products excluded by design) — it must still
    return a VALID path within 2x of the true optimum."""
    tn = tt_linear_network(1, (2,), (2, 3), (1, 1))
    best = find_topk_paths(tn, k=2)[0]
    assert best.macs <= 2 * exhaustive_min_macs(tn)
    tn.gemm_sequence(best.steps)  # valid
