"""The hardware axis: registry, architecture space, hw-batched tables,
joint (arch, path, dataflow) co-search.

Acceptance bars: (1) the hw-batched cost-table engine is *bit-identical*
to the scalar ``simulate()`` oracle for every candidate; (2) every
candidate the space generates is resource-feasible and the base target
is candidate 0; (3) the co-searched optimum is <= every fixed-
architecture optimum, for every registered target and for both the
latency and train-latency objectives.
"""

import dataclasses

import pytest

from repro.core import (
    ALL_PARTITIONINGS,
    build_cost_tables_hw,
    build_train_cost_tables,
    build_train_cost_tables_hw,
    find_topk_paths,
    global_search,
    memoised_layer_backwards,
    tt_linear_network,
)
from repro.core.dse import build_cost_table
from repro.hw import (
    ArchSpace,
    FPGA_VU9P,
    HW_TARGETS,
    HardwareConfig,
    TPU_V5E,
    get_target,
    list_targets,
    register_target,
)


def _layer_paths():
    return [
        find_topk_paths(tt_linear_network(64, (2, 8), (8, 2), (4, 4, 4)), k=4),
        find_topk_paths(tt_linear_network(4, (4, 4), (4, 4), (4, 4, 4)), k=3),
    ]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_resolves_named_targets():
    assert get_target("fpga_vu9p") is FPGA_VU9P
    assert get_target("tpu_v5e") is TPU_V5E
    assert set(list_targets()) >= {"fpga_vu9p", "tpu_v5e"}
    assert HW_TARGETS["fpga_vu9p"] is FPGA_VU9P


def test_registry_unknown_name_lists_choices():
    with pytest.raises(KeyError, match="fpga_vu9p"):
        get_target("no-such-hw")


def test_register_target_rejects_conflicting_redefinition():
    register_target(FPGA_VU9P)  # identical re-registration is fine
    clash = dataclasses.replace(FPGA_VU9P, pe_rows=64)
    with pytest.raises(ValueError, match="already registered"):
        register_target(clash)


def test_hardware_config_json_roundtrip():
    for hw in (FPGA_VU9P, TPU_V5E):
        assert HardwareConfig.from_json(hw.to_json()) == hw


# ---------------------------------------------------------------------------
# architecture space
# ---------------------------------------------------------------------------

def test_space_base_first_and_large_enough():
    space = ArchSpace(base=FPGA_VU9P)
    cands = space.candidates()
    assert cands[0] is FPGA_VU9P          # ties resolve to the default
    assert len(cands) >= 64               # the acceptance floor


@pytest.mark.parametrize("base", [FPGA_VU9P, TPU_V5E])
def test_space_candidates_all_feasible(base):
    space = ArchSpace(base=base)
    cands = space.candidates()
    names = [c.name for c in cands]
    assert len(set(names)) == len(names)
    for hw in cands:
        assert space.feasibility(hw) == [], hw.name
        assert hw.pe_rows * hw.pe_cols <= space.mac_budget
        assert hw.pe_rows * hw.pe_cols >= (
            space.min_budget_util * space.mac_budget)
        assert (hw.sram_input_bytes + hw.sram_output_bytes
                <= space.sram_total_bytes)
        assert hw.dram_words_per_cycle <= base.dram_words_per_cycle
        # process/board constants are inherited, not searched
        assert hw.freq_hz == base.freq_hz
        assert hw.bytes_per_word == base.bytes_per_word


def test_space_no_duplicate_parameterizations():
    cands = ArchSpace(base=FPGA_VU9P).candidates()
    seen = {dataclasses.astuple(dataclasses.replace(c, name=""))
            for c in cands}
    assert len(seen) == len(cands)


def test_space_feasibility_reports_problems():
    space = ArchSpace(base=FPGA_VU9P)
    too_big = dataclasses.replace(FPGA_VU9P, pe_rows=64, pe_cols=64)
    assert any("budget" in p for p in space.feasibility(too_big))
    skewed = dataclasses.replace(FPGA_VU9P, pe_rows=512, pe_cols=2)
    assert space.feasibility(skewed)  # aspect + dim bounds
    greedy_bw = dataclasses.replace(FPGA_VU9P, dram_words_per_cycle=1024.0)
    assert any("bandwidth" in p for p in space.feasibility(greedy_bw))


def test_space_rejects_impossible_budget():
    with pytest.raises(ValueError, match="budget"):
        ArchSpace(base=FPGA_VU9P, mac_budget=16)


def test_space_rejects_overclocked_bw_tiers():
    with pytest.raises(ValueError, match="bandwidth"):
        ArchSpace(base=FPGA_VU9P, bw_tiers=(512.0,))


def test_space_keeps_base_under_enlarged_budget():
    """A budget that makes the base's PE count fall below the utilization
    preference must NOT drop the base from its own space — it is the
    reference point of the <= guarantee and of the report's fixed row."""
    space = ArchSpace(base=FPGA_VU9P, mac_budget=4096)
    cands = space.candidates()
    assert cands[0] is FPGA_VU9P
    assert space.resource_problems(FPGA_VU9P) == []
    # ... even though the full preference check would prune it
    assert any("waste" in p for p in space.feasibility(FPGA_VU9P))
    lp = _layer_paths()
    co = global_search(lp, hw_space=cands)
    fixed = global_search(lp, FPGA_VU9P)
    assert co.total_latency_s <= fixed.total_latency_s


# ---------------------------------------------------------------------------
# hw-batched cost tables vs the scalar oracle
# ---------------------------------------------------------------------------

def test_hw_batched_tables_bit_identical_to_scalar_oracle():
    """Brute-force equality on a tiny space: every candidate's table must
    compare equal with ``==`` (no tolerance) to its per-cell scalar
    sweep."""
    lp = _layer_paths()
    cands = ArchSpace(base=FPGA_VU9P).candidates()[:5] + (TPU_V5E,)
    tables = build_cost_tables_hw(lp, cands, ALL_PARTITIONINGS)
    assert len(tables) == len(cands)
    for hw, t in zip(cands, tables):
        scalar = build_cost_table(lp, hw, ALL_PARTITIONINGS, engine="scalar")
        assert t.seconds == scalar, hw.name  # dict equality => bit-identical


def test_hw_batched_train_tables_match_single_hw_build():
    nets = [tt_linear_network(32, (4, 4), (4, 4), (4, 4, 4))]
    lp = [find_topk_paths(tn, k=3) for tn in nets]
    lbs = memoised_layer_backwards(nets, k=3)
    cands = (FPGA_VU9P,
             dataclasses.replace(FPGA_VU9P, name="half", pe_rows=16,
                                 pe_cols=64, dram_words_per_cycle=64.0))
    batched = build_train_cost_tables_hw(lp, lbs, cands)
    for hw, got in zip(cands, batched):
        ref = build_train_cost_tables(lp, lbs, hw)
        assert got.train_seconds() == ref.train_seconds()
        assert got.bwd_seconds == ref.bwd_seconds
        assert got.update_seconds == ref.update_seconds


# ---------------------------------------------------------------------------
# joint co-search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(HW_TARGETS))
def test_cosearch_beats_every_fixed_arch(name):
    """The co-searched optimum is <= every fixed-architecture optimum
    over the candidate space (exhaustive outer loop)."""
    base = get_target(name)
    lp = _layer_paths()
    cands = ArchSpace(base=base).candidates()
    co = global_search(lp, hw_space=cands)
    assert co.hw in cands
    assert len(co.hw_candidates) == len(cands)
    for cand in co.hw_candidates:
        fixed = global_search(lp, cand.hw)
        assert fixed.total_latency_s == cand.total_latency_s
        assert co.total_latency_s <= cand.total_latency_s
    # the winner's recorded latency is the returned optimum
    chosen = next(c for c in co.hw_candidates if c.hw is co.hw)
    assert chosen.total_latency_s == co.total_latency_s


def test_cosearch_train_objective():
    nets = [tt_linear_network(32, (4, 4), (4, 4), (4, 4, 4))]
    lp = [find_topk_paths(tn, k=3) for tn in nets]
    lbs = memoised_layer_backwards(nets, k=3)
    cands = ArchSpace(base=FPGA_VU9P).candidates()[:12]
    fixed = global_search(lp, FPGA_VU9P, objective="train-latency",
                          layer_backwards=lbs)
    co = global_search(lp, objective="train-latency", layer_backwards=lbs,
                       hw_space=cands)
    assert co.objective == "train-latency"
    assert co.total_latency_s <= fixed.total_latency_s
    assert all(c.backward for c in co.choices)


def test_cosearch_tie_resolves_to_base():
    """A space of identical-cost candidates picks the first (the base)."""
    lp = _layer_paths()
    clone = dataclasses.replace(FPGA_VU9P, name="clone")
    co = global_search(lp, hw_space=(FPGA_VU9P, clone))
    assert co.hw is FPGA_VU9P


def test_cosearch_validation_errors():
    lp = _layer_paths()
    cands = (FPGA_VU9P,)
    with pytest.raises(ValueError, match="hw_space"):
        global_search(lp, table={}, hw_space=cands)
    with pytest.raises(ValueError, match="scalar"):
        global_search(lp, engine="scalar", hw_space=cands)
    with pytest.raises(ValueError, match="hw_space"):
        global_search(lp, hw_tables=[{}])
    with pytest.raises(ValueError, match="layer_backwards"):
        global_search(lp, objective="train-latency", hw_space=cands)
    with pytest.raises(ValueError, match="candidates"):
        global_search(lp, hw_space=cands, hw_tables=[{}, {}])
    with pytest.raises(ValueError, match="at least one"):
        global_search(lp, hw_space=())
    # cross-objective table arguments fail loudly, never silently ignored
    with pytest.raises(ValueError, match="hw_train_tables"):
        global_search(lp, objective="train-latency", hw_space=cands,
                      hw_tables=[{}])
    with pytest.raises(ValueError, match="train-latency"):
        global_search(lp, hw_space=cands, hw_train_tables=[object()])


def test_fixed_search_records_its_architecture():
    lp = _layer_paths()
    res = global_search(lp, TPU_V5E)
    assert res.hw is TPU_V5E
    assert res.hw_candidates == ()
