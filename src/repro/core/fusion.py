"""Fusion analysis: partition a contraction path into chain segments whose
intermediates can stay resident in VMEM.

The ``tt_gemm`` backend lowers every pairwise contraction of a searched
path to its own ``pallas_call``, so each interior intermediate round-trips
through HBM between steps.  A *fusable segment* is a maximal contiguous run
of path steps that forms a chain — each step after the first consumes the
previous step's result — whose working set (streamed input block, pinned
operands, fp32 interior intermediates, output block) fits the on-chip
buffer budget.  Such a run can execute inside ONE ``pallas_call``
(``repro.kernels.fused_path``) with interior intermediates in VMEM
scratch, paying a single kernel-launch overhead and zero HBM bytes for the
interior tensors.

Chain rules (checked per step of a multi-step segment):

  * exactly one operand carries the batch edge (the streamed chain); the
    other operand is batch-free and pinned whole in VMEM;
  * for every step after the segment's first, the batch-carrying operand
    is the previous step's result (current-index ``n0 - t - 1``, mirroring
    ``TensorNetwork.contract_pair``'s append-at-end bookkeeping).

Core-core contractions (no batch edge) are deliberately left as singleton
segments: fusing them would recompute a batch-independent product once per
token block instead of once per call.

This module is consumed by both the plan compiler (stamping
``LayerPlan.segments``) and the cost-table engine (fused traffic
accounting), so it lives in ``core`` and depends only on the tensor
network — not on the plan schema.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .tensor_network import TensorNetwork

#: batch (streamed-token) edge label of the standard TT-linear network
BATCH_EDGE = "b"

#: interior intermediates are carried in fp32 VMEM scratch
INTERIOR_BYTES = 4

Segment = tuple[int, int]  # half-open step range [s, e)


@dataclasses.dataclass(frozen=True)
class StepRole:
    """How one path step participates in its segment (cost-model view)."""

    segment: Segment
    #: "a"/"b" when that operand is the VMEM-resident chain (zero HBM
    #: reads); ``None`` for singleton segments / segment-opening steps
    chain_operand: str | None
    #: the step's result stays in VMEM scratch (zero HBM writes)
    interior_output: bool


def _entry_dims(tn: TensorNetwork, block_tokens: int | None,
                batch_edge: str) -> list[tuple[tuple[str, ...], tuple[int, ...]]]:
    """Initial work-list (edges, dims) with the batch dim re-blocked."""
    entries = []
    for n in tn.nodes:
        dims = tuple(
            block_tokens if (block_tokens is not None and e == batch_edge)
            else d
            for e, d in zip(n.edges, n.dims))
        entries.append((n.edges, dims))
    return entries


def _merge(ea, da, eb, db):
    """Result (edges, dims) of contracting A with B (A free then B free)."""
    shared = set(ea) & set(eb)
    ec = tuple(e for e in ea if e not in shared) + tuple(
        e for e in eb if e not in shared)
    dc = tuple(d for e, d in zip(ea, da) if e not in shared) + tuple(
        d for e, d in zip(eb, db) if e not in shared)
    return ec, dc


def _nbytes(dims: Sequence[int], itemsize: int) -> int:
    return math.prod(dims) * itemsize


def segment_path(
    tn: TensorNetwork,
    steps: Sequence[tuple[int, int]],
    *,
    block_tokens: int,
    budget_bytes: int,
    batch_edge: str = BATCH_EDGE,
    input_bytes: int = 4,
) -> tuple[Segment, ...]:
    """Greedy maximal segmentation of ``steps`` under ``budget_bytes``.

    Returns contiguous half-open ``(s, e)`` ranges covering
    ``[0, len(steps))``.  A range with ``e - s >= 2`` is a fused segment;
    singletons keep the per-step route.  ``block_tokens`` re-blocks the
    batch edge (the fused kernel streams that block per grid step);
    ``input_bytes`` is the element size of the streamed/pinned operands
    (interior intermediates are always counted at fp32).
    """
    steps = tuple(steps)
    if not steps:
        return ()
    work = _entry_dims(tn, block_tokens, batch_edge)
    n0 = len(work)

    segments: list[Segment] = []
    seg_start = 0
    # working-set bytes of the tentative segment [seg_start, t)
    in_bytes = 0       # streamed block + pinned operands, counted once
    interior_bytes = 0  # fp32 scratch for already-chained intermediates
    out_bytes = 0      # the segment's current (fp32) result block

    def close(end: int) -> None:
        nonlocal seg_start, in_bytes, interior_bytes, out_bytes
        segments.append((seg_start, end))
        seg_start = end
        in_bytes = interior_bytes = out_bytes = 0

    for t, (i, j) in enumerate(steps):
        (ea, da), (eb, db) = work[i], work[j]
        ec, dc = _merge(ea, da, eb, db)
        prev = n0 - t - 1  # index of step t-1's result (appended at end)
        a_batch = batch_edge in ea
        b_batch = batch_edge in eb

        if t > seg_start:
            chain_is_a = (i == prev)
            chain_is_b = (j == prev)
            chain_e, chain_d = (ea, da) if chain_is_a else (eb, db)
            other_e, other_d = (eb, db) if chain_is_a else (ea, da)
            extendable = (
                (chain_is_a or chain_is_b)
                and batch_edge in chain_e
                and batch_edge not in other_e
            )
            if extendable:
                new_in = in_bytes + _nbytes(other_d, input_bytes)
                new_interior = interior_bytes + out_bytes
                new_out = _nbytes(dc, INTERIOR_BYTES)
                if new_in + new_interior + new_out <= budget_bytes:
                    in_bytes = new_in
                    interior_bytes = new_interior
                    out_bytes = new_out
                else:
                    close(t)
            else:
                close(t)

        if t == seg_start:
            # a fresh segment opens at t; it only becomes fused if a later
            # step chains onto it, which requires exactly one batch operand
            if a_batch != b_batch:
                in_bytes = _nbytes(da, input_bytes) + _nbytes(db, input_bytes)
                out_bytes = _nbytes(dc, INTERIOR_BYTES)
                if in_bytes + out_bytes > budget_bytes:
                    # even the opening working set overflows: never extend
                    in_bytes = out_bytes = 0
                    # mark unfusable by closing immediately after this step
                    work = [w for s_, w in enumerate(work)
                            if s_ not in (i, j)] + [(ec, dc)]
                    close(t + 1)
                    continue
            else:
                # core-core (or degenerate) step: singleton by construction
                work = [w for s_, w in enumerate(work)
                        if s_ not in (i, j)] + [(ec, dc)]
                close(t + 1)
                continue

        work = [w for s_, w in enumerate(work) if s_ not in (i, j)]
        work.append((ec, dc))

    if seg_start < len(steps):
        close(len(steps))
    return tuple(segments)


def has_fused(segments: Sequence[Segment] | None) -> bool:
    """True when at least one segment spans more than one step."""
    return bool(segments) and any(e - s >= 2 for s, e in segments)


def step_roles(
    n_nodes: int,
    steps: Sequence[tuple[int, int]],
    segments: Sequence[Segment],
) -> list[StepRole]:
    """Per-step fusion roles for the cost model.

    ``n_nodes`` is the initial work-list size (``len(tn.nodes)``); chain
    operands are recovered purely from current-index arithmetic — before
    step ``t`` the list holds ``n_nodes - t`` entries, so step ``t-1``'s
    result sits at index ``n_nodes - t - 1``.
    """
    roles: list[StepRole] = []
    by_step: dict[int, Segment] = {}
    for seg in segments:
        for t in range(seg[0], seg[1]):
            by_step[t] = seg
    for t, (i, j) in enumerate(steps):
        seg = by_step.get(t, (t, t + 1))
        s, e = seg
        fused = e - s >= 2
        chain = None
        if fused and t > s:
            prev = n_nodes - t - 1
            chain = "a" if i == prev else ("b" if j == prev else None)
        roles.append(StepRole(
            segment=seg,
            chain_operand=chain,
            interior_output=fused and t < e - 1,
        ))
    return roles


def chain_problems(
    tn: TensorNetwork,
    steps: Sequence[tuple[int, int]],
    segments: Sequence[Segment],
    batch_edge: str = BATCH_EDGE,
) -> list[str]:
    """Why ``segments``' fused runs cannot execute on ``tn`` (empty = OK).

    Structural check only (chain shape + batch-edge placement, no VMEM
    budget): a plan's recorded segmentation may have been produced under
    a different budget, but a fused range that is not a batch-carrying
    chain can never execute as one ``pallas_call``.  Used by
    ``plan.compiler.validate_plan``.
    """
    try:
        validate_segments(segments, len(steps))
    except ValueError as e:
        return [str(e)]
    problems: list[str] = []
    seg_of: dict[int, Segment] = {}
    for seg in segments:
        for t in range(seg[0], seg[1]):
            seg_of[t] = seg
    work = [n.edges for n in tn.nodes]
    n0 = len(work)
    for t, (i, j) in enumerate(steps):
        if i == j or not (0 <= i < len(work) and 0 <= j < len(work)):
            problems.append(f"step {t} indices ({i}, {j}) out of range")
            break
        ea, eb = work[i], work[j]
        s, e = seg_of[t]
        if e - s >= 2:
            a_batch = batch_edge in ea
            b_batch = batch_edge in eb
            if t == s:
                if a_batch == b_batch:
                    problems.append(
                        f"segment ({s}, {e}) opens at step {t} with "
                        f"{int(a_batch) + int(b_batch)} batch-carrying "
                        "operands (need exactly one)")
            else:
                prev = n0 - t - 1
                if i != prev and j != prev:
                    problems.append(
                        f"segment ({s}, {e}) step {t} does not consume "
                        "the previous step's result (not a chain)")
                else:
                    chain_e = ea if i == prev else eb
                    other_e = eb if i == prev else ea
                    if batch_edge not in chain_e or batch_edge in other_e:
                        problems.append(
                            f"segment ({s}, {e}) step {t}: the batch edge "
                            "must ride the chain operand")
        shared = set(ea) & set(eb)
        ec = tuple(x for x in ea if x not in shared) + tuple(
            x for x in eb if x not in shared)
        work = [w for k, w in enumerate(work) if k not in (i, j)]
        work.append(ec)
    return problems


def validate_segments(
    segments: Sequence[Segment], n_steps: int
) -> None:
    """Raise ``ValueError`` unless ``segments`` is a contiguous ascending
    cover of ``[0, n_steps)`` (the wire-format invariant)."""
    if not segments:
        raise ValueError("segments must be non-empty when present")
    pos = 0
    for s, e in segments:
        if s != pos or e <= s:
            raise ValueError(
                f"segments must contiguously cover [0, {n_steps}): "
                f"got {tuple(segments)}")
        pos = e
    if pos != n_steps:
        raise ValueError(
            f"segments cover [0, {pos}) but the path has {n_steps} steps")
