"""Pure-jnp oracles for the Pallas kernels (numerical ground truth)."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.contraction import execute_path
from repro.core.paths import CandidatePath
from repro.core.tensor_network import Node, TensorNetwork


def _with_batch(tn: TensorNetwork, tokens: int) -> TensorNetwork:
    """Rebind the X node's batch dim (contraction paths are batch-size
    agnostic — the network structure is identical)."""
    nodes = [
        Node(n.name, n.edges, (tokens,) + n.dims[1:], n.kind)
        if n.name == "X" else n
        for n in tn.nodes
    ]
    return TensorNetwork(nodes)


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """fp32-accumulated matmul reference."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def tt_linear_ref(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    out_dtype=None,
) -> jax.Array:
    """TT-linear forward along ``path`` on the whole batch at once."""
    out_dtype = out_dtype or x.dtype
    in_modes = tuple(
        d for n in tn.nodes if n.name == "X"
        for e, d in zip(n.edges, n.dims) if e != "b"
    )
    tokens = x.shape[0]
    tn = _with_batch(tn, tokens)
    tensors = {"X": x.reshape((tokens,) + in_modes)}
    names = [n.name for n in tn.nodes if n.name != "X"]
    for name, c in zip(names, cores):
        tensors[name] = c
    n_out_edges = len(tn.free_edges) - 1
    out_edges = ("b",) + tuple(f"i{t+1}" for t in range(n_out_edges))
    y = execute_path(tn, path, tensors, out_edges=out_edges,
                     preferred_dtype=jnp.float32)
    return y.reshape(tokens, -1).astype(out_dtype)
