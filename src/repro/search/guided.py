"""Budgeted guided search over the joint (arch, path, dataflow) space.

The exhaustive co-search (``core/dse.global_search(hw_space=...)``)
reads every cell of every candidate's cost table — optimal, and the
permanent test oracle, but its evaluation count multiplies with each new
axis.  This driver spends a *fixed evaluation budget* instead:

- **Evaluation unit**: one unique ``(arch, layer, path, partitioning,
  dataflow)`` cell read.  The exhaustive search reads
  ``len(space) * table_cells(...)`` of them; the guided search stops at
  ``budget``.  Cells are charged once — re-reading is free — so a
  generous budget costs *at most* the exhaustive count.
- **Exact per-architecture refinement**: an architecture is "refined" by
  running the very same hierarchical argmin the exhaustive search runs,
  over the same lazily built vectorized table (charging all its cells).
  The returned optimum only ever comes from refined architectures, so
  every guided result is the *exact* optimum of the architectures it
  visited — and with budget for all of them, exactly the exhaustive
  result, tie-breaks included (the differential-oracle property
  ``tests/test_search_oracle.py`` asserts).
- **Genome-guided ordering**: which architecture to refine next is
  steered by an evolutionary population of :class:`~.encoding.Genome`
  proposals, scored by cheap table reads (one cell per layer); winners'
  choices migrate to unrefined neighboring architectures via
  mutation/crossover.  The base target refines first, so the guided
  search inherits the "never worse than the fixed target" guarantee
  after its very first refinement.
- **Budget-independent evaluation stream**: the operation sequence is a
  pure function of the seed — the budget only cuts it off (an operation
  that would exceed it raises and the partial work is discarded).  A
  larger budget therefore replays the same prefix and can only improve
  the result: budget-monotonicity holds by construction, and the same
  seed yields a bit-identical ``DSEResult``.
"""

from __future__ import annotations

import random
from typing import Mapping, Optional, Sequence

from repro.core.dse import (
    DSEResult,
    HwCandidateResult,
    _hierarchical_argmin,
    apply_calibration,
)
from repro.core.paths import CandidatePath
from repro.core.simulator import (
    ALL_DATAFLOWS,
    STRATEGY_SPACE,
    Dataflow,
    HardwareConfig,
    Partitioning,
)

from .encoding import Genome, JointSpace

#: evolutionary population size (proposal pool per refinement round)
POPULATION = 16

#: default budget fraction of the exhaustive count for co-searches —
#: matches the acceptance bar "within 2% of exhaustive best latency at
#: <= 25% of the exhaustive evaluation count"
DEFAULT_BUDGET_FRACTION = 0.25


class BudgetExhausted(Exception):
    """Raised inside the driver when an operation would exceed the budget."""


class _TableStore:
    """Lazily built per-architecture cost tables + unique-cell accounting.

    ``read``/``charge_all`` charge each table cell at most once against
    the budget; an operation that would cross it raises
    :class:`BudgetExhausted` *before* charging, so ``spent <= budget``
    is an invariant and partially charged operations cannot exist.
    """

    def __init__(
        self,
        layer_paths: Sequence[Sequence[CandidatePath]],
        hw_space: Sequence[HardwareConfig],
        all_parts: Sequence[Partitioning],
        dataflows: Sequence[Dataflow],
        objective: str,
        layer_backwards,
        train_weights,
        calibration,
        budget: int,
    ) -> None:
        self.layer_paths = layer_paths
        self.hw_space = tuple(hw_space)
        self.all_parts = tuple(all_parts)
        self.dataflows = tuple(dataflows)
        self.objective = objective
        self.layer_backwards = layer_backwards
        self.train_weights = train_weights
        self.calibration = calibration
        self.budget = budget
        self.spent = 0
        self._tables: dict[int, Mapping] = {}
        self._trains: dict[int, object] = {}
        self._charged: dict[int, set] = {}

    def table(self, a: int) -> Mapping:
        t = self._tables.get(a)
        if t is None:
            hw = self.hw_space[a]
            if self.objective == "train-latency":
                from repro.core.cost_table import build_train_cost_tables_hw

                train = build_train_cost_tables_hw(
                    self.layer_paths, self.layer_backwards, (hw,),
                    self.all_parts, self.dataflows,
                    weights=self.train_weights)[0]
                self._trains[a] = train
                t = train.train_seconds()
            else:
                from repro.core.cost_table import build_cost_tables_hw

                t = build_cost_tables_hw(
                    self.layer_paths, (hw,), self.all_parts,
                    self.dataflows)[0].seconds
            if self.calibration is not None:
                t = apply_calibration(t, self.calibration, self.dataflows,
                                      layer_paths=self.layer_paths)
            self._tables[a] = t
        return t

    def train(self, a: int):
        return self._trains.get(a)

    def _charge(self, a: int, keys) -> None:
        charged = self._charged.setdefault(a, set())
        fresh = [k for k in keys if k not in charged]
        if self.spent + len(fresh) > self.budget:
            raise BudgetExhausted
        charged.update(fresh)
        self.spent += len(fresh)

    def read(self, a: int, keys) -> float:
        """Charge + sum the given cells of architecture ``a``'s table."""
        t = self.table(a)
        self._charge(a, keys)
        return sum(t[k] for k in keys)

    def charge_all(self, a: int) -> Mapping:
        """Charge every cell of architecture ``a`` (exact refinement)."""
        t = self.table(a)
        self._charge(a, t.keys())
        return t


def guided_search(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    *,
    objective: str = "latency",
    hw_space: Sequence[HardwareConfig] | None = None,
    budget: Optional[int] = None,
    seed: int = 0,
    layer_backwards: Sequence | None = None,
    train_weights=None,
    calibration=None,
    population: int = POPULATION,
) -> DSEResult:
    """Budgeted guided joint search; same contract as ``global_search``.

    Accepts the ``global_search`` argument surface for the ``latency``
    and ``train-latency`` objectives (EDP/throughput consume pre-built
    tables the driver cannot rebuild per architecture — keep those on
    the exhaustive path).  Without ``hw_space`` the single fixed target
    is refined exactly (the guided search degenerates to Algorithm 1 —
    same result, ``search="guided"`` provenance).  ``budget`` defaults
    to the full table for fixed targets and to
    ``DEFAULT_BUDGET_FRACTION`` of the exhaustive count for co-searches.
    """
    if objective not in ("latency", "train-latency"):
        raise ValueError(
            f"guided search supports objectives ('latency', "
            f"'train-latency'); got {objective!r} — EDP and throughput "
            "tables are pre-built and stay on the exhaustive path")
    if objective == "train-latency":
        if layer_backwards is None:
            raise ValueError(
                "objective='train-latency' requires layer_backwards "
                "(see repro.core.backward.memoised_layer_backwards)")
        if calibration is not None:
            raise ValueError(
                "calibration rescales the inference table; the training "
                "decomposition is analytic-only for now (ROADMAP.md)")

    archs = tuple(hw_space) if hw_space is not None else (hw,)
    if not archs:
        raise ValueError("hw_space must contain at least one candidate")
    all_parts = sorted({c for cs in strategy_space.values() for c in cs})
    from repro.core.cost_table import table_cells

    n_cells = table_cells(layer_paths, all_parts, dataflows)
    exhaustive_evals = len(archs) * n_cells
    if budget is None:
        budget = (n_cells if hw_space is None else
                  max(n_cells,
                      int(exhaustive_evals * DEFAULT_BUDGET_FRACTION)))
    if budget < n_cells:
        raise ValueError(
            f"budget {budget} cannot refine even one architecture "
            f"(one table holds {n_cells} cells)")

    store = _TableStore(layer_paths, archs, all_parts, dataflows, objective,
                        layer_backwards, train_weights, calibration, budget)
    rng = random.Random(seed)
    space = JointSpace(layer_paths, archs, strategy_space, dataflows)

    refined: dict[int, tuple[str, tuple, float]] = {}
    best: tuple[float, int] | None = None       # (cost, arch) — tie to base
    found_at = 0

    def refine(a: int) -> None:
        nonlocal best, found_at
        table = store.charge_all(a)
        strategy, choices, cost = _hierarchical_argmin(
            layer_paths, table, strategy_space, dataflows, store.train(a))
        refined[a] = (strategy, choices, cost)
        if best is None or (cost, a) < best:
            best = (cost, a)
            found_at = store.spent

    try:
        # the base target (candidate 0) always refines first: one table
        # in, the guided result already can't lose to the fixed target
        refine(0)
        if len(archs) > 1:
            base_genome = space.encode_choices(0, refined[0][0],
                                               refined[0][1])
            # probe sweep: the base optimum's genome costs one cell per
            # layer on each candidate — a cheap global proxy ranking
            # (the per-arch cost surfaces share shape, so a config that
            # is fast on the base tends to rank its neighbors honestly)
            proxy: dict[int, float] = {}
            for a in range(len(archs)):
                proxy[a] = store.read(a, base_genome.keys())
            pop = [base_genome]
            while len(pop) < population:
                pop.append(space.random_genome(rng))
            while len(refined) < len(archs):
                scored = [(store.read(g.arch, g.keys()), i, g)
                          for i, g in enumerate(pop)]
                scored.sort(key=lambda t: (t[0], t[1]))
                for s, _, g in scored:
                    if s < proxy.get(g.arch, float("inf")):
                        proxy[g.arch] = s
                # next refinement: the unrefined arch with the best
                # proxy seen so far (probe or population proposal)
                nxt = min((a for a in range(len(archs))
                           if a not in refined),
                          key=lambda a: (proxy.get(a, float("inf")), a))
                refine(nxt)
                # evolve: elites survive, offspring = crossover+mutate,
                # plus one migrant — the freshly refined optimum pushed
                # toward an unrefined neighbor
                elites = [g for _, _, g in scored[:max(2, population // 2)]]
                nxt_s, nxt_c, _ = refined[nxt]
                migrant = space.mutate(
                    space.encode_choices(nxt, nxt_s, nxt_c), rng)
                pop = list(elites) + [migrant]
                while len(pop) < population:
                    a_p = elites[rng.randrange(len(elites))]
                    b_p = elites[rng.randrange(len(elites))]
                    pop.append(space.mutate(
                        space.crossover(a_p, b_p, rng), rng))
    except BudgetExhausted:
        pass

    assert best is not None  # budget >= n_cells covers the base refinement
    cost, a = best
    strategy, choices, _ = refined[a]
    return DSEResult(
        strategy, choices, cost, store.table(a), objective, hw=archs[a],
        hw_candidates=(tuple(
            HwCandidateResult(archs[i], s, c)
            for i, (s, _, c) in refined.items())
            if hw_space is not None else ()),
        search="guided", evals=store.spent, found_at_eval=found_at)
