"""Mamba2 (SSD) block: chunked selective-state-space scan + O(1) decode.

Training/prefill uses the SSD chunked algorithm (Dao & Gu 2024): the
sequence is split into chunks; within a chunk the recurrence is expanded
into an attention-like lower-triangular form (MXU-friendly GEMMs), across
chunks a short ``lax.scan`` carries the (heads, d_state, head_dim) state.
Decode advances the state one token at a time — O(1) per token, which is
why the hybrid/SSM archs run the ``long_500k`` shape that full-attention
models skip.

All projections route through ``repro.nn.linear`` and are tensorizable.
n_groups is fixed at 1 (B/C shared across heads), matching Zamba2.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard
from .linear import LinearSpec, TTConfig, linear_apply, linear_init
from .norms import rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    name: str
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    tt: Optional[TTConfig] = None

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def d_in_proj(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state

    @property
    def in_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.win", self.d_model, self.d_in_proj, False, "attn", self.tt)

    @property
    def out_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wout", self.d_inner, self.d_model, False, "attn", self.tt)


class SSMState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_channels) — trailing conv inputs
    ssm: jax.Array    # (B, n_heads, d_state, head_dim)


def ssm_init(rng: jax.Array, spec: SSMSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, 4)
    h = spec.n_heads
    # A in [1, 16) log-spaced (mamba2 default init)
    a_init = jnp.log(1.0 + jnp.arange(h, dtype=jnp.float32) * 15.0 / max(h - 1, 1))
    return {
        "win": linear_init(ks[0], spec.in_spec, dtype),
        "wout": linear_init(ks[1], spec.out_spec, dtype),
        "conv_w": (jax.random.normal(ks[2], (spec.d_conv, spec.conv_channels)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((spec.conv_channels,), dtype),
        "A_log": a_init,
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(spec.d_inner, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d.  x (B, S, C), w (K, C); history (B, K-1, C)
    prepends cached inputs (decode) or zeros (prefill)."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _ssd_chunked(
    x: jax.Array,      # (B, S, H, P) — already scaled by dt
    da: jax.Array,     # (B, S, H)    — log-decay increments (<= 0)
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """SSD scan: y_t = C_t^T h_t,  h_t = exp(da_t) h_{t-1} + B_t x_t^T.

    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s)
    if s > 8192:
        l = min(l, 64)  # bound the (b,c,l,l,h) decay tensor at long context
    if s % l:
        l = s  # ragged fallback: single chunk
    c = s // l
    xc = x.reshape(b, c, l, h, p)
    dac = da.reshape(b, c, l, h)
    bc = bmat.reshape(b, c, l, n)
    cc = cmat.reshape(b, c, l, n)

    cum = jnp.cumsum(dac, axis=2)                       # (b, c, l, h)
    # intra-chunk attention-like term.  Mask the exponent BEFORE exp: at
    # masked (j > t) positions diff is large-positive, and exp-then-mask
    # produces 0*inf = NaN in the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,c,l,l,h)
    mask = jnp.tril(jnp.ones((l, l), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    decay = jnp.exp(diff)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)      # (b,c,l,l)
    att = (scores[..., None] * decay).astype(x.dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # chunk-boundary states
    to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (b,c,l,h)
    s_chunk = jnp.einsum("bcln,bclh,bclhp->bchnp", bc, to_end.astype(x.dtype), xc)
    total = cum[:, :, -1, :]                            # (b,c,h)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    def step(carry, inp):
        s_c, tot = inp                                  # (b,h,n,p), (b,h)
        out = carry                                     # state BEFORE this chunk
        carry = carry * jnp.exp(tot)[..., None, None] + s_c.astype(jnp.float32)
        return carry, out

    final, s_prev = jax.lax.scan(
        step,
        init_state,
        (s_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)            # (b,c,h,n,p)

    y_inter = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", cc, jnp.exp(cum).astype(x.dtype),
        s_prev.astype(x.dtype),
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def ssm_apply(
    spec: SSMSpec,
    params: dict,
    u: jax.Array,                       # (B, S, D)
    state: Optional[SSMState] = None,
) -> tuple[jax.Array, Optional[SSMState]]:
    """Returns (y, new_state).  state given => decode (S small, usually 1)."""
    b, s, _ = u.shape
    h, p, n = spec.n_heads, spec.head_dim, spec.d_state
    zxbcdt = linear_apply(spec.in_spec, params["win"], u)
    z, xbc, dt = jnp.split(
        zxbcdt, [spec.d_inner, spec.d_inner + spec.conv_channels], axis=-1
    )
    conv_hist = state.conv if state is not None else None
    xbc_conv = jax.nn.silu(
        _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_hist)
    )
    x, bmat, cmat = jnp.split(xbc_conv, [spec.d_inner, spec.d_inner + n], axis=-1)
    x = shard(x.reshape(b, s, h, p), "batch", "seq", "model", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,s,h)
    da = -jnp.exp(params["A_log"])[None, None, :] * dt                # <= 0
    xd = x * dt[..., None].astype(x.dtype)

    init = state.ssm if state is not None else None
    y, final = _ssd_chunked(xd, da, bmat, cmat, spec.chunk, init)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * x
    y = y.reshape(b, s, spec.d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = linear_apply(spec.out_spec, params["wout"], y)

    new_state = None
    if state is not None:
        k = spec.d_conv
        hist = jnp.concatenate([state.conv, xbc], axis=1)[:, -(k - 1):, :]
        new_state = SSMState(conv=hist, ssm=final)
    return shard(out, "batch", "seq", None), new_state


def init_ssm_state(spec: SSMSpec, batch: int, dtype=jnp.float32) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, spec.d_conv - 1, spec.conv_channels), dtype),
        ssm=jnp.zeros((batch, spec.n_heads, spec.d_state, spec.head_dim), jnp.float32),
    )
