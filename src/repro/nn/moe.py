"""Mixture-of-Experts FFN: GShard-style capacity dispatch + shared experts.

Routing is done in fixed-size token groups (``router_group``) so the
dispatch tensors stay bounded at long sequence lengths.  Dispatch uses the
two-one-hot construction (expert one-hot x capacity-slot one-hot), never
materialising a (tokens, k, E, C) tensor.

Expert weights are stacked on a leading expert axis; when the expert
count divides the mesh's model axis they shard there (true EP), otherwise
the per-expert ``d_ff`` dim shards (TP-MoE) — both handled by the global
param-sharding heuristic.  Expert FFNs are dense or TT-factorized
(vmapped over experts), so the paper's technique covers MoE archs too.

Shared experts (Qwen2-MoE style) are merged into one wide always-on FFN.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard
from .linear import LinearSpec, TTConfig, linear_apply, linear_init
from .mlp import MLPSpec, mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class MoESpec:
    name: str
    d_model: int
    d_ff: int                      # per routed expert
    n_experts: int
    top_k: int
    n_shared: int = 0              # always-on shared experts (merged)
    shared_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    router_group: int = 512        # tokens per routing group
    kind: str = "swiglu"
    tt: Optional[TTConfig] = None

    @property
    def expert_gate(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.eg", self.d_model, self.d_ff, False, "moe", self.tt)

    @property
    def expert_up(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.eu", self.d_model, self.d_ff, False, "moe", self.tt)

    @property
    def expert_down(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.ed", self.d_ff, self.d_model, False, "moe", self.tt)

    @property
    def shared_spec(self) -> Optional[MLPSpec]:
        if not self.n_shared:
            return None
        ff = self.shared_d_ff if self.shared_d_ff else self.n_shared * self.d_ff
        return MLPSpec(f"{self.name}.shared", self.d_model, ff, self.kind, self.tt)


def moe_init(rng: jax.Array, spec: MoESpec, dtype=jnp.float32) -> dict:
    k_r, k_e, k_s = jax.random.split(rng, 3)
    params: dict = {
        "router": (
            jax.random.normal(k_r, (spec.d_model, spec.n_experts)) * 0.02
        ).astype(jnp.float32)  # router always fp32 for routing stability
    }
    # stacked expert params: vmap linear_init over the expert axis
    ks = jax.random.split(k_e, spec.n_experts)
    specs = [spec.expert_up, spec.expert_down]
    names = ["eu", "ed"]
    if spec.kind == "swiglu":
        specs.append(spec.expert_gate)
        names.append("eg")
    for nm, ls in zip(names, specs):
        params[nm] = jax.vmap(lambda k: linear_init(k, ls, dtype))(ks)
    if spec.shared_spec is not None:
        params["shared"] = mlp_init(k_s, spec.shared_spec, dtype)
    return params


def _expert_ffn(spec: MoESpec, eparams: dict, x: jax.Array) -> jax.Array:
    """One expert's FFN on (capacity, d_model) — vmapped over experts."""
    up = linear_apply(spec.expert_up, eparams["eu"], x)
    if spec.kind == "swiglu":
        gate = linear_apply(spec.expert_gate, eparams["eg"], x)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return linear_apply(spec.expert_down, eparams["ed"], h)


def moe_apply(
    spec: MoESpec, params: dict, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar).

    aux_loss is the Switch/GShard load-balance loss
    ``E * sum_e f_e * p_e`` (f = fraction of tokens routed to e,
    p = mean router prob of e).
    """
    b, s, d = x.shape
    E, K = spec.n_experts, spec.top_k
    g = min(spec.router_group, b * s)
    total = b * s
    pad = (-total) % g
    xf = x.reshape(total, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], axis=0)
    xg = xf.reshape(-1, g, d)                              # (G, g, D)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                # (G, g, E)
    gate_vals, idx = jax.lax.top_k(probs, K)               # (G, g, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = int(math.ceil(K * g * spec.capacity_factor / E))
    cap = max(4, min(cap, g))

    expert_oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G, g, K, E)
    # capacity slot: tokens claim slots in (token, choice) priority order
    flat = expert_oh.reshape(-1, g * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                  # earlier claims
    pos = pos.reshape(-1, g, K, E)
    in_cap = jnp.sum(pos * expert_oh, axis=-1) < cap       # (G, g, K)
    slot = jnp.sum(pos * expert_oh, axis=-1)               # (G, g, K)
    keep = in_cap.astype(jnp.float32)
    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), cap, dtype=jnp.float32)

    # dispatch (G, g, E, C) = sum_k expert_oh * slot_oh * keep
    dispatch = jnp.einsum(
        "gtke,gtkc->gtec", expert_oh * keep[..., None], slot_oh
    ).astype(x.dtype)
    combine = jnp.einsum(
        "gtke,gtkc->gtec",
        expert_oh * (gate_vals * keep)[..., None],
        slot_oh,
    ).astype(jnp.float32)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)   # (G, E, C, D)
    expert_in = shard(expert_in, "batch", None, None, None)
    # (E, G*C, D): experts on the leading axis, vmapped
    ein = expert_in.transpose(1, 0, 2, 3).reshape(E, -1, d)
    eout = jax.vmap(lambda ep, xe: _expert_ffn(spec, ep, xe))(
        {k: params[k] for k in ("eu", "ed", "eg") if k in params}, ein
    )
    expert_out = eout.reshape(E, -1, cap, d).transpose(1, 0, 2, 3)  # (G,E,C,D)
    yg = jnp.einsum("gtec,gecd->gtd", combine, expert_out.astype(jnp.float32))
    y = yg.reshape(-1, d)[:total].reshape(b, s, d).astype(x.dtype)

    if spec.shared_spec is not None:
        y = y + mlp_apply(spec.shared_spec, params["shared"], x)

    # load-balance aux loss over real (unpadded) tokens
    frac_tokens = jnp.mean(
        jnp.sum(expert_oh * keep[..., None], axis=2).reshape(-1, E), axis=0
    )
    frac_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
