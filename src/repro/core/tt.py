"""Tensor-train decomposition (TT-SVD, Oseledets 2011) and helpers.

Used to (a) factorize pretrained dense weights into TT cores for the
paper's compression experiments (Table 1) and (b) report reconstruction
error / compression ratios.  Runs in numpy — decomposition is an offline,
host-side operation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class TTMatrix:
    """TT representation of a matrix W in R^{M x N} (paper eq. 2).

    ``cores[k]`` has shape (r_k, mode_k, r_{k+1}), where the first
    ``len(out_modes)`` cores carry output modes m_k and the rest carry
    input modes n_k.  Boundary ranks are 1.
    """

    cores: list[np.ndarray]
    out_modes: tuple[int, ...]
    in_modes: tuple[int, ...]

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(c.shape[0] for c in self.cores) + (self.cores[-1].shape[2],)

    @property
    def n_params(self) -> int:
        return sum(c.size for c in self.cores)

    @property
    def dense_params(self) -> int:
        return math.prod(self.out_modes) * math.prod(self.in_modes)

    @property
    def compression_ratio(self) -> float:
        return self.dense_params / self.n_params

    def to_matrix(self) -> np.ndarray:
        """Reconstruct the dense (M, N) matrix."""
        full = self.cores[0]  # (1, m1, r1)
        for c in self.cores[1:]:
            full = np.tensordot(full, c, axes=([full.ndim - 1], [0]))
        full = full.reshape(self.out_modes + self.in_modes)
        m = math.prod(self.out_modes)
        n = math.prod(self.in_modes)
        return full.reshape(m, n)


def tt_svd(
    w: np.ndarray,
    out_modes: Sequence[int],
    in_modes: Sequence[int],
    max_rank: int,
    rel_eps: float = 0.0,
) -> TTMatrix:
    """TT-SVD of a matrix with mode order (m_1..m_d, n_1..n_e).

    Sequential truncated SVDs; each unfolding is truncated to
    ``max_rank`` and, if ``rel_eps`` > 0, to the rank capturing
    (1 - rel_eps^2 / (d-1)) of the Frobenius mass (Oseledets' bound).
    """
    out_modes = tuple(out_modes)
    in_modes = tuple(in_modes)
    m, n = w.shape
    if math.prod(out_modes) != m or math.prod(in_modes) != n:
        raise ValueError("mode products must match matrix dims")
    modes = out_modes + in_modes
    d = len(modes)
    tensor = w.reshape(modes)
    delta = (rel_eps / math.sqrt(max(d - 1, 1))) * np.linalg.norm(w) if rel_eps else 0.0

    cores: list[np.ndarray] = []
    rank = 1
    rest = tensor.reshape(rank * modes[0], -1)
    for k in range(d - 1):
        u, s, vt = np.linalg.svd(rest, full_matrices=False)
        if delta > 0:
            tail = np.sqrt(np.cumsum(s[::-1] ** 2))[::-1]
            keep = int(np.searchsorted(-tail, -delta) )
            keep = max(keep, 1)
        else:
            keep = len(s)
        r_new = min(max_rank, keep, len(s))
        cores.append(u[:, :r_new].reshape(rank, modes[k], r_new))
        rest = (np.diag(s[:r_new]) @ vt[:r_new]).reshape(r_new * modes[k + 1], -1)
        rank = r_new
    cores.append(rest.reshape(rank, modes[-1], 1))
    return TTMatrix(cores, out_modes, in_modes)


def tt_rand(
    rng: np.random.Generator,
    out_modes: Sequence[int],
    in_modes: Sequence[int],
    rank: int,
    stddev: float | None = None,
) -> TTMatrix:
    """Random TT cores whose contraction has approximately unit-variance
    columns scaled like a Glorot-initialised dense matrix.

    Each interior rank is min(rank, full_rank_at_cut).  Cores are i.i.d.
    normal with per-core variance chosen so the reconstructed matrix has
    stddev ~= sqrt(2 / (fan_in + fan_out)) (or the supplied ``stddev``).
    """
    out_modes = tuple(out_modes)
    in_modes = tuple(in_modes)
    modes = out_modes + in_modes
    d = len(modes)
    ranks = [1]
    left = 1
    right = math.prod(modes)
    for k in range(d - 1):
        left *= modes[k]
        right //= modes[k]
        ranks.append(min(rank, left, right))
    ranks.append(1)
    m = math.prod(out_modes)
    n = math.prod(in_modes)
    target = stddev if stddev is not None else math.sqrt(2.0 / (m + n))
    # product of d independent gaussians: var multiplies; contraction over
    # ranks sums r_k terms -> scale each core by (target^2 / prod r)^(1/2d)
    prod_ranks = math.prod(ranks[1:-1]) or 1
    per_core_std = (target**2 / prod_ranks) ** (1.0 / (2 * d))
    cores = [
        rng.normal(0.0, per_core_std, size=(ranks[k], modes[k], ranks[k + 1]))
        for k in range(d)
    ]
    return TTMatrix(cores, out_modes, in_modes)


def reconstruction_error(tt: TTMatrix, w: np.ndarray) -> float:
    """Relative Frobenius reconstruction error."""
    return float(np.linalg.norm(tt.to_matrix() - w) / np.linalg.norm(w))


def quantize_int8(x: np.ndarray) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor INT8 quantization: returns (q, scale)."""
    scale = float(np.max(np.abs(x))) / 127.0 if x.size else 1.0
    scale = scale or 1.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize(q: np.ndarray, scale: float) -> np.ndarray:
    return q.astype(np.float32) * scale
