"""Sharded planned execution: shard_map routing, provenance, equivalence.

Covers the sharded-execution PR:

1. ``shard_decision`` routes a planned projection onto the installed
   mesh exactly when the mesh can take the problem (real mesh object,
   token count divisible over the DP axes) — and declines otherwise, so
   the constrained jnp fallback is preserved;
2. ``PlanSharding`` provenance round-trips through plan JSON and is
   absent-on-wire for unsharded plans (no schema version bump);
3. ``repro.dse --shards N`` searches per-shard problems and stamps the
   emitted plan with the shard context;
4. the load-bearing equivalence (hypothesis, subprocess on a forced
   8-device host mesh): continuous serving with shard_map-routed Pallas
   kernels produces per-request token ids bit-identical to the
   single-device oneshot reference, with the execution log proving both
   streams ran Pallas backends at per-shard shapes (no silent jnp
   demotion).

The equivalence test forks a subprocess because device count is fixed at
jax init: the main pytest process runs single-device, the child forces
``--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_table import shard_streamed_tokens
from repro.plan import ExecutionPlan, PlanSharding
from repro.plan.sharded import ShardDecision, shard_decision
from repro.sharding import ShardingRules

ARCH = "tt-lm-100m"


def _rules(axis_sizes, *, mesh="not-none", seq_axis=None, reduce=False):
    return ShardingRules(axis_sizes=dict(axis_sizes), mesh=mesh,
                         seq_axis=seq_axis, tt_model_reduce=reduce)


# ---------------------------------------------------------------------------
# shard_decision: routing policy
# ---------------------------------------------------------------------------

def test_no_rules_or_no_mesh_declines():
    assert shard_decision(None, 64, (8, 8)) is None
    rules = _rules({"data": 4, "model": 2}, mesh=None)
    assert shard_decision(rules, 64, (8, 8)) is None


def test_token_dp_decision():
    rules = _rules({"data": 4, "model": 2})
    d = shard_decision(rules, 64, (8, 8))
    assert d == ShardDecision(("data",), 4)
    assert d.describe(rules.axis_sizes, "model") == "data=4"
    # indivisible token count -> decline (shard_map needs exact blocks)
    assert shard_decision(rules, 3, (8, 8)) is None


def test_sp_axis_joins_token_shards():
    rules = _rules({"data": 4, "model": 2}, seq_axis="model")
    d = shard_decision(rules, 64, (8, 8))
    assert d is not None
    assert d.axes == ("data", "model") and d.n_shards == 8


def test_model_reduce_is_opt_in():
    # default: model axis unused, pure DP
    d = shard_decision(_rules({"data": 4, "model": 2}), 64, (8, 8))
    assert d is not None and not d.model_reduce
    # opted in: leading input mode splits over the model axis
    d = shard_decision(_rules({"data": 4, "model": 2}, reduce=True),
                       64, (8, 8))
    assert d is not None and d.model_reduce and d.tp == 2
    assert d.describe({"data": 4, "model": 2}, "model") == \
        "data=4+reduce(model=2)"
    # leading mode not divisible by tp -> reduction declined, DP kept
    d = shard_decision(_rules({"data": 4, "model": 2}, reduce=True),
                       64, (7, 8))
    assert d is not None and not d.model_reduce


def test_single_axis_mesh_replicated_model():
    rules = _rules({"data": 8, "model": 1})
    d = shard_decision(rules, 64, (8, 8))
    assert d is not None and d.axes == ("data",) and d.n_shards == 8


def test_shard_streamed_tokens():
    assert shard_streamed_tokens(1024, 1) == 1024
    assert shard_streamed_tokens(1024, 4) == 256
    assert shard_streamed_tokens(2, 4) == 1  # floor at one token


# ---------------------------------------------------------------------------
# PlanSharding provenance: round-trip, absent-on-wire
# ---------------------------------------------------------------------------

def test_plan_sharding_roundtrip():
    s = PlanSharding(n_shards=4, axes=(("data", 4),), tokens_per_shard=256)
    assert PlanSharding.from_json(s.to_json()) == s
    with pytest.raises(ValueError):
        PlanSharding(n_shards=0, axes=(), tokens_per_shard=1)


def test_plan_json_sharding_field(tmp_path):
    from repro.dse_cli import run_dse_plan

    _, plan = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=64,
                           plan_backend="jnp")
    assert plan.sharding is None
    d = plan.to_json()
    assert d["sharding"] is None
    # absent-on-wire: a v4 plan without the key still loads (no bump)
    d2 = {k: v for k, v in d.items() if k != "sharding"}
    assert ExecutionPlan.from_json(d2).sharding is None

    _, sharded = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=64,
                              plan_backend="jnp", shards=4)
    assert sharded.sharding == PlanSharding(
        n_shards=4, axes=(("data", 4),), tokens_per_shard=16)
    path = str(tmp_path / "p.json")
    sharded.save(path)
    from repro.plan import load_plan

    assert load_plan(path).sharding == sharded.sharding


def test_dse_report_carries_shard_context():
    from repro.dse_cli import run_dse

    report = run_dse(ARCH, smoke=True, top_k=2, tokens=64, shards=4)
    sh = report["sharding"]
    assert sh["n_shards"] == 4 and sh["axes"] == [["data", 4]]
    assert sh["tokens_per_shard"] == 16 and sh["global_tokens"] == 64
    # the searched problems are the per-shard ones
    assert report["tokens"] == 16
    # unsharded report keeps the null field
    assert run_dse(ARCH, smoke=True, top_k=2, tokens=64)["sharding"] is None


def test_rank_search_rejects_shards():
    from repro.dse_cli import run_dse

    with pytest.raises(ValueError, match="rank"):
        run_dse(ARCH, smoke=True, tokens=64, shards=4, rank_search="budget")


# ---------------------------------------------------------------------------
# the equivalence property (subprocess: forced 8-device host mesh)
# ---------------------------------------------------------------------------

_HARNESS = r"""
import json, sys
import jax

assert jax.device_count() == 8, jax.device_count()

import numpy as np
from repro.configs import get_config
from repro.dse_cli import run_dse_plan
from repro.launch.mesh import make_rules, make_test_mesh
from repro.models import api
from repro.models.config import ShapeConfig
from repro.plan import execution_log, reset_execution_log
from repro.serve import Request, Scheduler, ServeEngine, ServePolicy
from repro.sharding import use_rules

spec = json.loads(sys.argv[1])
ARCH, N_SLOTS, BUCKET, MAX_SEQ = "tt-lm-100m", 8, 8, 16
cfg = get_config(ARCH, smoke=True)

_, plan_p = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=64,
                         plan_backend="streaming_tt", phase="prefill",
                         shards=4)
_, plan_d = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=N_SLOTS,
                         plan_backend="streaming_tt", phase="decode",
                         shards=4)
assert plan_p.sharding is not None and plan_p.sharding.n_shards == 4

reqs = []
for i, (p, g) in enumerate(spec):
    rng = np.random.default_rng((0xBEEF, i))
    prompt = tuple(int(t) for t in rng.integers(0, cfg.vocab, size=p))
    reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=g))

params = api(cfg).init_params(jax.random.PRNGKey(0))


def run(schedule, rules):
    reset_execution_log()
    eng = ServeEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                      prompt_bucket=BUCKET, prefill_plan=plan_p,
                      decode_plan=plan_d, arch=ARCH)
    sched = Scheduler(eng, ServePolicy(schedule=schedule))
    with use_rules(rules):
        res = sched.run(reqs)
    return res.tokens_by_rid(), execution_log()


mesh = make_test_mesh()
assert mesh is not None and mesh.devices.size == 8
shape = ShapeConfig("test", MAX_SEQ, N_SLOTS, "decode")
rules = make_rules(cfg, shape, mesh)
sharded_tokens, sharded_log = run("continuous", rules)
solo_tokens, solo_log = run("oneshot", None)

# the property: per-request tokens bit-identical across mesh widths
assert sharded_tokens == solo_tokens, (sharded_tokens, solo_tokens)

# both runs executed planned Pallas on both streams — no silent jnp
for tag, log in (("sharded", sharded_log), ("solo", solo_log)):
    assert log, tag
    streams = {r["stream"] for r in log}
    assert streams == {"prefill", "decode"}, (tag, streams)
    backends = {r["backend"] for r in log}
    assert backends == {"streaming_tt"}, (tag, backends)

# sharded records carry mesh provenance at per-shard shapes; solo none
for r in sharded_log:
    assert r["mesh"] == "data=4", r
    assert r["shard_shape"] is not None and r["shard_shape"][0] >= 1, r
    # the record was traced inside the shard_map body, so its token
    # count IS the per-shard problem size
    assert r["shard_shape"][0] == r["tokens"], r
for r in solo_log:
    assert r["mesh"] == "" and r["shard_shape"] is None, r

print("PASS")
"""


def _run_harness(spec: list) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), os.pardir, "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _HARNESS, json.dumps(spec)],
        capture_output=True, text=True, timeout=1200, env=env)
    assert proc.returncode == 0 and "PASS" in proc.stdout, (
        f"harness failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")


@pytest.mark.slow
@given(raw=st.lists(st.integers(0, 10**9), min_size=2, max_size=8))
@settings(max_examples=2, deadline=None)
def test_sharded_continuous_matches_single_device_oneshot(raw):
    # (prompt_len 1..8, gen 1..4) per request — prompts bucket to 8, so
    # the prefill token count stays divisible over the data axis
    spec = [[1 + raw[2 * i] % 8, 1 + raw[2 * i + 1] % 4]
            for i in range(len(raw) // 2)]
    _run_harness(spec)
