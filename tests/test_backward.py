"""Training-aware DSE: backward networks, train cost tables, joint search,
and differentiable planned execution.

Covers the acceptance criteria of the training-DSE PR:

1. backward-network construction is edge/shape-consistent with the
   autodiff of the jnp reference (``jax.make_jaxpr`` output avals) and
   numerically exact against ``jax.grad``;
2. the ``custom_vjp`` wrappers of both Pallas kernels gradcheck against
   the jnp path (fp32: rtol/atol 1e-4 — accumulation-order differences
   only, the contractions are mathematically identical);
3. ``global_search(objective="train-latency")`` returns a path/dataflow
   choice that differs from the inference-optimal one on a bundled arch;
4. planned Pallas execution composes with ``jax.grad`` end-to-end
   (execution log shows Pallas backends in the ``bwd`` phase, gradients
   match the unplanned jnp reference).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FPGA_VU9P,
    TrainCostWeights,
    backward_networks,
    build_train_cost_tables,
    find_topk_paths,
    global_search,
    layer_backward,
    memoised_layer_backwards,
    tt_linear_network,
)
from repro.core.contraction import execute_path
from repro.core.tensor_network import dense_linear_network

#: documented tolerance for Pallas-vs-jnp gradient comparisons: fp32
#: kernels accumulate in a different association order than tensordot
GRAD_RTOL = 1e-4
GRAD_ATOL = 1e-4


def _tiny_tt(batch=8):
    return tt_linear_network(batch, (4, 4), (4, 4), (3, 3, 3))


def _tensors(tn, rng):
    return {n.name: jnp.asarray(rng.standard_normal(n.dims), jnp.float32)
            for n in tn.nodes}


# ---------------------------------------------------------------------------
# 1. backward-network construction
# ---------------------------------------------------------------------------

def test_backward_networks_cover_all_gradients():
    tn = _tiny_tt()
    nets = backward_networks(tn)
    wrts = [wrt for wrt, _ in nets]
    assert wrts == ["dx", "G1", "G2", "G3", "G4"]
    # every backward network has the same node count as the forward
    for _, net in nets:
        assert len(net.nodes) == len(tn.nodes)


def test_backward_network_shapes_match_jaxpr_avals():
    """Each gradient network's output dims == the aval of the matching
    gradient in ``jax.make_jaxpr(jax.grad(reference))``."""
    tn = _tiny_tt()
    rng = np.random.default_rng(0)
    tensors = _tensors(tn, rng)
    path = find_topk_paths(tn, k=1)[0]

    def loss(tensors):
        y = execute_path(tn, path, tensors, out_edges=("b", "i1", "i2"))
        return jnp.sum(y * y)

    jaxpr = jax.make_jaxpr(jax.grad(loss))(tensors)
    grad_avals = {
        name: aval
        for name, aval in zip(sorted(tensors), jaxpr.out_avals)
    }
    target = {"dx": "X", "G1": "G1", "G2": "G2", "G3": "G3", "G4": "G4"}
    for wrt, net in backward_networks(tn):
        node = next(n for n in tn.nodes if n.name == target[wrt])
        got = net.output_dims()
        # free edges of the gradient network == the target node's edges
        assert set(got) == set(node.edges)
        assert tuple(got[e] for e in node.edges) == node.dims
        assert tuple(grad_avals[target[wrt]].shape) == node.dims


@pytest.mark.parametrize("make_net,target_edges", [
    (lambda: _tiny_tt(), ("b", "i1", "i2")),
    (lambda: dense_linear_network(8, 16, 32), ("b", "i")),
])
def test_backward_networks_match_jax_grad(make_net, target_edges):
    tn = make_net()
    rng = np.random.default_rng(1)
    tensors = _tensors(tn, rng)
    path = find_topk_paths(tn, k=1)[0]

    def fwd(tensors):
        return execute_path(tn, path, tensors, out_edges=target_edges)

    dy = jnp.asarray(rng.standard_normal(fwd(tensors).shape), jnp.float32)
    ref = jax.grad(lambda t: jnp.vdot(fwd(t), dy))(tensors)
    for wrt, net in backward_networks(tn):
        target = "X" if wrt == "dx" else wrt
        bw_tensors = {n.name: (dy if n.name == "dY" else tensors[n.name])
                      for n in net.nodes}
        out_edges = next(n.edges for n in tn.nodes if n.name == target)
        for q in find_topk_paths(net, k=3):
            got = execute_path(net, q, bw_tensors, out_edges=out_edges)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref[target]),
                rtol=1e-5, atol=1e-5, err_msg=f"{wrt} path {q.steps}")


def test_backward_rejects_multi_input_networks():
    from repro.core.backward import grad_core_network

    tn = _tiny_tt()
    dG = grad_core_network(tn, "G2")  # has two input-kind nodes (X, dY)
    with pytest.raises(ValueError):
        backward_networks(dG)


# ---------------------------------------------------------------------------
# 2. train cost tables
# ---------------------------------------------------------------------------

def test_train_table_decomposition_and_weights():
    tn = _tiny_tt(64)
    paths = [find_topk_paths(tn, k=3)]
    lbs = [layer_backward(tn, k=3)]
    w = TrainCostWeights(fwd=1.0, bwd=2.0, update=0.5)
    tt = build_train_cost_tables(paths, lbs, FPGA_VU9P, weights=w)
    train = tt.train_seconds()
    assert set(train) == set(tt.fwd.seconds)
    for (l, p, c, d), v in train.items():
        expect = (tt.fwd.seconds[(l, p, c, d)]
                  + 2.0 * tt.bwd_seconds[(l, c, d)]
                  + 0.5 * tt.update_seconds[l])
        assert v == pytest.approx(expect, rel=1e-12)
    # the backward term is the sum of the per-problem argmin latencies
    for (l, c, d), choices in tt.bwd_choices.items():
        assert [ch.wrt for ch in choices] == ["dx", "G1", "G2", "G3", "G4"]
        assert tt.bwd_seconds[(l, c, d)] == pytest.approx(
            sum(ch.latency_s for ch in choices), rel=1e-12)
    assert tt.update_seconds[0] > 0.0


def test_train_search_attaches_backward_choices():
    tn = _tiny_tt(64)
    paths = [find_topk_paths(tn, k=3)]
    lbs = memoised_layer_backwards([tn], k=3)
    res = global_search(paths, FPGA_VU9P, objective="train-latency",
                        layer_backwards=lbs)
    assert res.objective == "train-latency"
    ch = res.choices[0]
    assert [b.wrt for b in ch.backward] == ["dx", "G1", "G2", "G3", "G4"]
    assert ch.latency_s == pytest.approx(
        ch.fwd_latency_s + ch.bwd_latency_s + ch.update_latency_s, rel=1e-12)
    assert res.total_latency_s == pytest.approx(
        sum(c.latency_s for c in res.choices), rel=1e-12)


def test_train_objective_requires_backwards():
    tn = _tiny_tt()
    paths = [find_topk_paths(tn, k=2)]
    with pytest.raises(ValueError, match="layer_backwards"):
        global_search(paths, FPGA_VU9P, objective="train-latency")
    with pytest.raises(ValueError, match="objective"):
        global_search(paths, FPGA_VU9P, objective="nope")


# ---------------------------------------------------------------------------
# 3. train-latency optimum differs from the inference optimum
# ---------------------------------------------------------------------------

def test_train_choice_differs_from_inference_on_bundled_arch():
    """Acceptance: on ``vit_ti4/cifar10`` (FPGA target), the joint
    fwd+bwd search picks a different path and a different dataflow than
    the inference search for at least one layer."""
    from repro.dse_cli import _vision_dse_layers

    named = _vision_dse_layers("vit_ti4/cifar10", 1)
    nets = [tn for _, tn in named]
    memo: dict = {}
    layer_paths = []
    for tn in nets:
        key = tuple((n.edges, n.dims, n.kind) for n in tn.nodes)
        if key not in memo:
            memo[key] = find_topk_paths(tn, k=4)
        layer_paths.append(memo[key])
    lbs = memoised_layer_backwards(nets, k=4)
    inf = global_search(layer_paths, FPGA_VU9P)
    tr = global_search(layer_paths, FPGA_VU9P, objective="train-latency",
                       layer_backwards=lbs)
    path_diff = sum(1 for a, b in zip(inf.choices, tr.choices)
                    if a.path_index != b.path_index)
    df_diff = sum(1 for a, b in zip(inf.choices, tr.choices)
                  if a.dataflow != b.dataflow)
    assert path_diff > 0, "train search never changed a contraction path"
    assert df_diff > 0, "train search never changed a dataflow"


# ---------------------------------------------------------------------------
# 4. differentiable kernels (gradcheck vs jnp, tolerance documented above)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", ["OS", "WS", "IS"])
def test_tt_gemm_vjp_gradcheck(dataflow):
    from repro.kernels import ops

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 20)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 20)), jnp.float32)

    def f(a, b):
        y = ops.gemm(a, b, dataflow=dataflow, block_m=8, block_k=8,
                     block_n=8, interpret=True, differentiable=True)
        return jnp.vdot(y, w)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(lambda a, b: jnp.vdot(a @ b, w), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra),
                               rtol=GRAD_RTOL, atol=GRAD_ATOL)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=GRAD_RTOL, atol=GRAD_ATOL)


def test_streaming_tt_vjp_gradcheck():
    from repro.kernels import ops, ref

    tn = _tiny_tt(8)  # block network: batch == block_tokens
    path = find_topk_paths(tn, k=1)[0]
    rng = np.random.default_rng(3)
    cores = [jnp.asarray(rng.standard_normal(n.dims), jnp.float32)
             for n in tn.nodes if n.name != "X"]
    # 20 tokens: exercises the pad-to-block path under grad as well
    x = jnp.asarray(rng.standard_normal((20, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((20, 16)), jnp.float32)

    def f_pallas(x, cores):
        y = ops.tt_linear(x, cores, tn, path, block_tokens=8,
                          interpret=True, differentiable=True)
        return jnp.vdot(y, w)

    def f_ref(x, cores):
        return jnp.vdot(ref.tt_linear_ref(x, list(cores), tn, path), w)

    got = jax.grad(f_pallas, argnums=(0, 1))(x, tuple(cores))
    want = jax.grad(f_ref, argnums=(0, 1))(x, tuple(cores))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=GRAD_RTOL, atol=GRAD_ATOL)
    for g, r in zip(got[1], want[1]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=GRAD_RTOL, atol=GRAD_ATOL)


# ---------------------------------------------------------------------------
# 5. planned execution under jax.grad (execution log + gradient match)
# ---------------------------------------------------------------------------

@pytest.fixture
def _clean_plan_state():
    from repro.nn import install_plan
    from repro.plan import reset_execution_log

    install_plan(None)
    reset_execution_log()
    yield
    install_plan(None)
    reset_execution_log()


def test_planned_pallas_backends_run_under_grad(_clean_plan_state):
    from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
    from repro.plan import compile_plan, execution_log, reset_execution_log

    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tokens = 32
    tn = spec.network(tokens)
    paths = [find_topk_paths(tn, k=4)]
    lbs = memoised_layer_backwards([tn], k=4)
    res = global_search(paths, FPGA_VU9P, objective="train-latency",
                        layer_backwards=lbs)
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                        objective="train-latency", tokens=tokens)
    assert plan.layers[0].backward, "train plan must carry backward ops"

    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, spec.d_in))
    w = jax.random.normal(jax.random.PRNGKey(2), (tokens, spec.d_out))

    def loss(params, x):
        return jnp.vdot(linear_apply(spec, params, x), w)

    install_plan(None)
    ref_grads = jax.grad(loss, argnums=(0, 1))(params, x)

    for backend in ("tt_gemm", "streaming_tt"):
        install_plan(plan.with_backend(backend))
        reset_execution_log()
        got = jax.jit(jax.grad(loss, argnums=(0, 1)))(params, x)
        log = execution_log()
        fwd_backends = {r["backend"] for r in log if r["phase"] == "fwd"}
        bwd = [r for r in log if r["phase"] == "bwd"]
        assert fwd_backends == {backend}
        # the backward pass itself ran through Pallas kernels
        assert {r["backend"] for r in bwd} <= {"streaming_tt", "tt_gemm"}
        assert {r["wrt"] for r in bwd} == {"dx", "G1", "G2", "G3", "G4"}
        for k in ref_grads[0]:
            np.testing.assert_allclose(
                np.asarray(got[0][k]), np.asarray(ref_grads[0][k]),
                rtol=GRAD_RTOL, atol=GRAD_ATOL,
                err_msg=f"{backend}: grad wrt {k}")
        np.testing.assert_allclose(
            np.asarray(got[1]), np.asarray(ref_grads[1]),
            rtol=GRAD_RTOL, atol=GRAD_ATOL, err_msg=f"{backend}: grad wrt x")


@pytest.mark.slow
def test_model_train_step_runs_pallas_under_grad(_clean_plan_state):
    """Acceptance: a full model train step with a train-mode plan executes
    at least one Pallas-backed contraction under ``jax.grad`` and the loss
    matches the unplanned reference."""
    from repro.configs import get_config
    from repro.dse_cli import run_dse_plan
    from repro.launch.steps import make_train_step
    from repro.models import api
    from repro.plan import check_plan_for_config, execution_log
    from repro.optim import adamw_init

    _, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                           mode="train")
    cfg = get_config("tt-lm-100m", smoke=True)
    assert check_plan_for_config(plan, "tt-lm-100m", cfg) == []
    assert any(lp.backend != "jnp" for lp in plan.layers)

    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.ones((2, 16), jnp.int32),
    }
    m = api(cfg, plan=plan)
    params = m.init_params(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    _, _, metrics = step(params, adamw_init(params), batch)
    loss_planned = float(metrics["loss"])

    log = execution_log()
    bwd = [r for r in log if r["phase"] == "bwd"]
    assert any(r["backend"] in ("tt_gemm", "streaming_tt") for r in bwd), \
        "no Pallas-backed contraction executed under jax.grad"

    api(cfg, plan=None)  # clear -> unplanned jnp reference
    _, _, ref_metrics = jax.jit(make_train_step(cfg))(
        params, adamw_init(params), batch)
    assert loss_planned == pytest.approx(float(ref_metrics["loss"]),
                                         rel=1e-4)


def test_jnp_forward_with_pallas_backward_ops_routes_vjp(_clean_plan_state):
    """A layer whose forward is jnp but whose backward ops name Pallas
    backends must still execute the searched backward through the VJP
    (the auto-compiler emits this pairing when only the weight-gradient
    GEMMs clear the kernel threshold)."""
    import dataclasses

    from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
    from repro.plan import compile_plan, execution_log

    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tokens = 16
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P,
                        objective="train-latency",
                        layer_backwards=memoised_layer_backwards([tn], k=4))
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, tokens=tokens)
    lp = plan.layers[0].with_backend("tt_gemm")
    lp = dataclasses.replace(lp, backend="jnp")  # jnp fwd, tt_gemm bwd
    install_plan(dataclasses.replace(plan, layers=(lp,)))

    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, spec.d_in))
    jax.grad(lambda p: jnp.sum(linear_apply(spec, p, x) ** 2))(params)
    bwd = [r for r in execution_log() if r["phase"] == "bwd"]
    assert bwd and all(r["backend"] == "tt_gemm" for r in bwd)


def test_with_backend_forces_backward_ops_too(_clean_plan_state):
    from repro.plan import compile_plan

    tokens = 32
    from repro.nn import LinearSpec, TTConfig

    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P,
                        objective="train-latency",
                        layer_backwards=memoised_layer_backwards([tn], k=4))
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, tokens=tokens)
    forced = plan.with_backend("tt_gemm").layers[0]
    assert all(op.backend == "tt_gemm" for op in forced.backward)
    forced = plan.with_backend("jnp").layers[0]
    assert all(op.backend == "jnp" for op in forced.backward)
    forced = plan.with_backend("streaming_tt").layers[0]
    assert all(op.backend == ("streaming_tt" if op.wrt == "dx" else "tt_gemm")
               for op in forced.backward)


def test_partial_backward_list_is_caught_and_defaulted(_clean_plan_state):
    """validate_plan flags a backward list that misses a gradient; the
    executor fills the gap with defaults instead of KeyError-ing inside
    the grad trace."""
    import dataclasses

    from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
    from repro.plan import compile_plan, validate_plan

    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tokens = 16
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P,
                        objective="train-latency",
                        layer_backwards=memoised_layer_backwards([tn], k=4))
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, tokens=tokens)
    lp = plan.layers[0]
    partial = dataclasses.replace(
        lp, backward=tuple(op for op in lp.backward if op.wrt != "G2"))
    broken = dataclasses.replace(plan, layers=(partial,))
    problems = validate_plan(broken, [("demo", tn)])
    assert any("G2" in p or "gradients" in p for p in problems)

    # executor robustness: installing it anyway still computes correct grads
    install_plan(broken)
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, spec.d_in))
    got = jax.grad(lambda p: jnp.sum(linear_apply(spec, p, x) ** 2))(params)
    install_plan(None)
    ref = jax.grad(lambda p: jnp.sum(linear_apply(spec, p, x) ** 2))(params)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=GRAD_RTOL, atol=GRAD_ATOL)


def test_inference_plan_still_differentiable_with_default_backward(
        _clean_plan_state):
    """A v1-style (inference) plan has no backward entries; the executor
    derives MAC-optimal backward paths and still runs Pallas under grad."""
    from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
    from repro.plan import compile_plan, execution_log

    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tokens = 16
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P)
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, tokens=tokens)
    assert plan.layers[0].backward == ()

    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, spec.d_in))

    install_plan(None)
    ref_grad = jax.grad(
        lambda p: jnp.sum(linear_apply(spec, p, x) ** 2))(params)
    install_plan(plan.with_backend("tt_gemm"))
    got = jax.grad(lambda p: jnp.sum(linear_apply(spec, p, x) ** 2))(params)
    bwd = [r for r in execution_log() if r["phase"] == "bwd"]
    assert bwd and {r["backend"] for r in bwd} <= {"tt_gemm", "streaming_tt"}
    for k in ref_grad:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref_grad[k]),
                                   rtol=GRAD_RTOL, atol=GRAD_ATOL)
