"""Config registry: ``get_config("<arch>")`` / ``--arch <id>``.

Every assigned architecture has one module defining FULL (the exact
assigned dims) and SMOKE (a reduced same-family variant for CPU tests).
``get_config(name, tt=..., smoke=...)`` is the single entry point; the
default is the TT-enabled deployment configuration (the paper's
technique); ``tt=False`` gives the dense baseline.
"""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ARCH_IDS = (
    "zamba2-1.2b",
    "phi3-medium-14b",
    "chatglm3-6b",
    "glm4-9b",
    "qwen1.5-110b",
    "seamless-m4t-medium",
    "grok-1-314b",
    "qwen2-moe-a2.7b",
    "internvl2-2b",
    "rwkv6-7b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULES["tt-lm-100m"] = "tt_lm_100m"


def get_config(name: str, tt: bool = True, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ModelConfig = mod.SMOKE if smoke else mod.FULL
    if not tt:
        cfg = cfg.with_(tt=cfg.tt.__class__(enabled=False))
    return cfg


def all_arch_ids() -> tuple[str, ...]:
    return ARCH_IDS


__all__ = [
    "ARCH_IDS", "get_config", "all_arch_ids",
    "SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable",
]
