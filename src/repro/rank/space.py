"""Candidate grid for the rank search: per-family TT factorizations.

The frozen TTConfig (``d`` modes per side, scalar ``rank``) is one point
in a much larger decomposition space.  :class:`RankSpace` enumerates the
neighbourhood the search explores — a (modes-per-side x rank-ladder)
grid applied uniformly across the model's tensorized projection
families, filtered to a parameter budget relative to the frozen
baseline.  Candidate 0 is always the frozen decomposition itself, so
the searched frontier degrades gracefully to "keep what you had".

Per-family heterogeneous grids would square the space; the paper's DSE
treats the decomposition as a model-level knob, and so do we — each
candidate is one (d, rank) pair instantiated per family through the
same :func:`repro.core.tensor_network.factorize` mode split the frozen
models use, so the frozen candidate's networks are bit-identical to an
unsearched run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.tensor_network import factorize

#: scalar-rank multipliers tried around the frozen rank (dedup'd after
#: rounding and full-rank clipping)
RANK_LADDER_FACTORS = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0)

#: modes-per-side counts tried around the frozen ``TTConfig.d``.
#: d=1 is the degenerate TT — a plain low-rank factorization W ~= A @ B
#: with a single (middle) cut: fewer contraction steps AND no side-cut
#: truncation loss, so it can genuinely dominate deeper TTs at equal
#: rank when the weight spectrum decays fast
MODES_PER_SIDE = (1, 2, 3, 4)

#: default parameter budget: candidates may spend at most this multiple
#: of the frozen decomposition's TT parameters
DEFAULT_PARAM_BUDGET_RATIO = 2.0


def clip_ranks(modes: Sequence[int], rank: int) -> tuple[int, ...]:
    """Interior TT ranks for ``modes``, clipped to the full-rank bound
    at each cut (the same rule as ``LinearSpec.tt_ranks`` / TT-SVD)."""
    ranks = []
    left, right = 1, math.prod(modes)
    for k in range(len(modes) - 1):
        left *= modes[k]
        right //= modes[k]
        ranks.append(min(rank, left, right))
    return tuple(ranks)


@dataclasses.dataclass(frozen=True)
class FamilyFactorization:
    """One projection family under one candidate decomposition."""

    name: str
    d_out: int
    d_in: int
    out_modes: tuple[int, ...]
    in_modes: tuple[int, ...]
    ranks: tuple[int, ...]
    instances: int = 1            # repeated transformer layers / experts
    token_scale: float = 1.0      # MoE capacity fraction (provenance only)

    def __post_init__(self):
        if math.prod(self.out_modes) != self.d_out:
            raise ValueError(
                f"{self.name}: out_modes {self.out_modes} do not factor "
                f"d_out={self.d_out}")
        if math.prod(self.in_modes) != self.d_in:
            raise ValueError(
                f"{self.name}: in_modes {self.in_modes} do not factor "
                f"d_in={self.d_in}")
        want = len(self.out_modes) + len(self.in_modes) - 1
        if len(self.ranks) != want:
            raise ValueError(
                f"{self.name}: need {want} interior ranks, got "
                f"{len(self.ranks)}")

    @property
    def triple(self) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
        return (self.out_modes, self.in_modes, self.ranks)

    @property
    def n_params(self) -> int:
        """TT core parameters of ONE instance."""
        modes = self.out_modes + self.in_modes
        ranks = (1,) + self.ranks + (1,)
        return sum(ranks[k] * modes[k] * ranks[k + 1]
                   for k in range(len(modes)))

    @property
    def dense_params(self) -> int:
        return self.d_out * self.d_in

    @property
    def compression(self) -> float:
        return self.dense_params / self.n_params


@dataclasses.dataclass(frozen=True)
class RankCandidate:
    """One point of the decomposition axis: a (d, rank) pair expanded
    into per-family factorizations."""

    name: str                     # "frozen" or "d{d}_r{rank}"
    d: int
    rank: int
    families: tuple[FamilyFactorization, ...]

    @property
    def n_params(self) -> int:
        """Model-wide TT parameters (instance-weighted)."""
        return sum(f.n_params * f.instances for f in self.families)

    @property
    def dense_params(self) -> int:
        return sum(f.dense_params * f.instances for f in self.families)

    @property
    def compression(self) -> float:
        return self.dense_params / self.n_params

    def factorization_map(self) -> dict[str, tuple]:
        """name -> (out_modes, in_modes, ranks), the ``model_dse_layers``
        / ``LinearSpec.with_factorization`` override format."""
        return {f.name: f.triple for f in self.families}

    def _key(self) -> tuple:
        return tuple((f.name,) + f.triple for f in self.families)


def _candidate(bases: Sequence[tuple], name: str, d: int,
               rank: int) -> RankCandidate:
    fams = tuple(
        FamilyFactorization(
            name=fname, d_out=d_out, d_in=d_in,
            out_modes=factorize(d_out, d), in_modes=factorize(d_in, d),
            ranks=clip_ranks(factorize(d_out, d) + factorize(d_in, d), rank),
            instances=instances, token_scale=token_scale)
        for fname, d_out, d_in, instances, token_scale in bases
    )
    return RankCandidate(name=name, d=d, rank=rank, families=fams)


class RankSpace:
    """The searched decomposition grid for one model.

    ``families`` is a sequence of ``(name, d_out, d_in, instances,
    token_scale)`` tuples — one per tensorized projection family.  The
    grid is ``mode_counts x`` the rank ladder around ``base_rank``,
    dedup'd (rank clipping collapses distinct ladder points on small
    models) and filtered to ``param_budget_ratio x`` the frozen
    candidate's TT parameters.  The frozen candidate survives the filter
    by construction and is always first.
    """

    def __init__(
        self,
        families: Sequence[tuple],
        *,
        base_d: int,
        base_rank: int,
        param_budget_ratio: float = DEFAULT_PARAM_BUDGET_RATIO,
        ladder: Sequence[float] = RANK_LADDER_FACTORS,
        mode_counts: Sequence[int] = MODES_PER_SIDE,
    ):
        if not families:
            raise ValueError("rank space needs at least one tensorized "
                             "projection family")
        if param_budget_ratio <= 0:
            raise ValueError("param_budget_ratio must be positive")
        self.families = tuple(tuple(f) for f in families)
        self.base_d = int(base_d)
        self.base_rank = int(base_rank)
        self.param_budget_ratio = float(param_budget_ratio)
        self.ladder = tuple(ladder)
        self.mode_counts = tuple(mode_counts)
        self.frozen = _candidate(self.families, "frozen", self.base_d,
                                 self.base_rank)

    def candidates(self) -> list[RankCandidate]:
        budget = self.param_budget_ratio * self.frozen.n_params
        out = [self.frozen]
        seen = {self.frozen._key()}
        for d in self.mode_counts:
            for f in self.ladder:
                rank = max(1, round(self.base_rank * f))
                cand = _candidate(self.families, f"d{d}_r{rank}", d, rank)
                if cand._key() in seen:
                    continue
                seen.add(cand._key())
                if cand.n_params > budget:
                    continue
                out.append(cand)
        return out

    @classmethod
    def from_config(
        cls,
        cfg,
        *,
        param_budget_ratio: float = DEFAULT_PARAM_BUDGET_RATIO,
        ladder: Sequence[float] = RANK_LADDER_FACTORS,
        mode_counts: Sequence[int] = MODES_PER_SIDE,
    ) -> "RankSpace":
        """Rank space over ``cfg``'s tensorized projection families
        (the same enumeration the DSE problems are built from)."""
        from repro.dse_cli import _block_specs

        families = [
            (spec.name, spec.d_out, spec.d_in, count, scale)
            for spec, count, scale in _block_specs(cfg)
            if spec.tensorized
        ]
        if not families:
            raise ValueError(
                f"config {cfg.name!r} has no tensorized projections to "
                f"rank-search (tt.enabled={cfg.tt.enabled})")
        return cls(families, base_d=cfg.tt.d, base_rank=cfg.tt.rank,
                   param_budget_ratio=param_budget_ratio,
                   ladder=ladder, mode_counts=mode_counts)


def vision_rank_space(
    arch: str,
    *,
    base_rank: int = 16,
    param_budget_ratio: float = DEFAULT_PARAM_BUDGET_RATIO,
    ladder: Sequence[float] = RANK_LADDER_FACTORS,
) -> RankSpace:
    """Rank space for a vision workload (``resnet18/...``, ``vit_ti4/...``).

    Vision layers are rebuilt by ``repro.models.vision.model_layers(rank=r)``
    — the mode split is structural (d=2 linear splits, 5-core TT-conv), so
    only the scalar rank varies; the per-family factorizations here drive
    the accuracy proxy and the parameter budget, approximating conv layers
    by the TT-SVD of their im2col matrix.
    """
    from repro.models.vision import model_layers

    model, dataset = arch.split("/")
    families = []
    for layer in model_layers(model, dataset, batch=1, rank=base_rank):
        w = next(n for n in layer.dense_network.nodes if n.kind != "input")
        d_in, d_out = w.dims
        families.append((layer.name, d_out, d_in, 1, 1.0))
    return RankSpace(families, base_d=2, base_rank=base_rank,
                     param_budget_ratio=param_budget_ratio,
                     ladder=ladder, mode_counts=(2,))
