"""Pallas TPU GEMM with DSE-selectable dataflow (IS / OS / WS).

The paper's FPGA engine switches dataflows by re-muxing which operand is
pinned in the PE array.  The TPU-native analogue is the *grid iteration
order* of a tiled Pallas matmul: the operand whose BlockSpec ``index_map``
is constant along the innermost grid axis stays VMEM-resident across
consecutive grid steps, while the others stream HBM->VMEM.  The resulting
HBM traffic asymmetry is exactly the IS/OS/WS asymmetry the paper's
simulator models:

  OS  grid=(m, n, k), k innermost  -> C block resident (classic matmul);
                                      A, B stream; C written once.
  WS  grid=(k, n, m), m innermost  -> B (weight) block resident; A streams;
                                      C partials spill/refill per k-fold.
  IS  grid=(m, k, n), n innermost  -> A (input) block resident; B streams;
                                      C partials spill/refill per k-fold.

Block shapes are the DSE's tiling decision <T_M, T_K, T_N>; MXU-aligned
multiples of 128 (8 on the sublane dim) are preferred.

Grids must tile the operands exactly; dims that are not block multiples
are zero-padded up and the result sliced back automatically (zero rows
and columns contribute nothing to a matmul), so autotuned tilings never
need caller-side padding logic.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DataflowName = Literal["IS", "OS", "WS"]


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Output-stationary: k innermost; fp32 accumulator scratch in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ws_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Weight-stationary: grid (k, n, m), m innermost; B block pinned.

    The output block is revisited once per k step (non-consecutive, so a
    VMEM scratch accumulator cannot carry it); partial sums round-trip
    through the fp32 output buffer — the WS traffic cost the simulator
    charges as ``C * (2*k_folds - 1)``.  ``o_ref`` is always fp32
    (``tt_gemm`` casts to the requested dtype after the call), so cross-k
    accumulation never loses precision to a narrow output dtype.
    """
    k = pl.program_id(0)
    part = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _first():
        o_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


def _is_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Input-stationary: grid (m, k, n), n innermost; A block pinned.

    Same fp32 partial-sum contract as :func:`_ws_kernel`.
    """
    k = pl.program_id(1)
    part = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _first():
        o_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


def _pad_to_block(x: jax.Array, axis: int, block: int) -> jax.Array:
    pad = (-x.shape[axis]) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tt_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    dataflow: DataflowName = "OS",
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``a @ b`` via a dataflow-configurable Pallas kernel.

    Dims that are not multiples of the block shape are zero-padded up to
    the next multiple and the result is sliced back — planned/autotuned
    tilings compose without caller-side padding.  ``interpret=True`` runs
    the kernel body in Python on CPU — the container-side validation
    mode; TPU is the compile target.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    if m % block_m or k % block_k or n % block_n:
        ap = _pad_to_block(_pad_to_block(a, 0, block_m), 1, block_k)
        bp = _pad_to_block(_pad_to_block(b, 0, block_k), 1, block_n)
        out = tt_gemm(ap, bp, dataflow=dataflow, block_m=block_m,
                      block_k=block_k, block_n=block_n,
                      out_dtype=out_dtype, interpret=interpret)
        return out[:m, :n]
    out_dtype = out_dtype or a.dtype
    n_m, n_k, n_n = m // block_m, k // block_k, n // block_n
    # WS/IS revisit output blocks non-consecutively per k-fold, so their
    # cross-k partials accumulate in an fp32 output buffer (cast once
    # below) — matching the OS kernel's fp32 scratch precision.
    inner_dtype = out_dtype if dataflow == "OS" else jnp.float32
    out_shape = jax.ShapeDtypeStruct((m, n), inner_dtype)

    if dataflow == "OS":
        grid = (n_m, n_n, n_k)
        a_spec = pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk))
        b_spec = pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))
        o_spec = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
        kernel = functools.partial(_os_kernel, n_k=n_k)
        scratch = [pltpu_accumulator((block_m, block_n))]
        dims = ("parallel", "parallel", "arbitrary")
    elif dataflow == "WS":
        grid = (n_k, n_n, n_m)
        a_spec = pl.BlockSpec((block_m, block_k), lambda kk, j, i: (i, kk))
        b_spec = pl.BlockSpec((block_k, block_n), lambda kk, j, i: (kk, j))
        o_spec = pl.BlockSpec((block_m, block_n), lambda kk, j, i: (i, j))
        kernel = functools.partial(_ws_kernel, n_k=n_k)
        scratch = []
        dims = ("arbitrary", "parallel", "parallel")
    elif dataflow == "IS":
        grid = (n_m, n_k, n_n)
        a_spec = pl.BlockSpec((block_m, block_k), lambda i, kk, j: (i, kk))
        b_spec = pl.BlockSpec((block_k, block_n), lambda i, kk, j: (kk, j))
        o_spec = pl.BlockSpec((block_m, block_n), lambda i, kk, j: (i, j))
        kernel = functools.partial(_is_kernel, n_k=n_k)
        scratch = []
        dims = ("parallel", "arbitrary", "parallel")
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    kwargs = {}
    if not interpret:
        # TPU compile target: annotate which grid axes may be parallelised
        from jax.experimental.pallas import tpu as pltpu

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=dims
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(a, b)
    return out.astype(out_dtype)


def pltpu_accumulator(shape: tuple[int, int]):
    """fp32 VMEM scratch accumulator (works in interpret mode too)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


# ---------------------------------------------------------------------------
# differentiable wrapper: backward GEMMs run the same Pallas kernel
# ---------------------------------------------------------------------------

def tt_gemm_vjp(
    a: jax.Array,
    b: jax.Array,
    *,
    dataflow: DataflowName = "OS",
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``tt_gemm`` with a ``jax.custom_vjp``: differentiable end-to-end.

    A ``pallas_call`` has no transpose rule, so plain autodiff cannot
    cross :func:`tt_gemm`.  The VJP of ``C = A @ B`` is itself two GEMMs
    — ``dA = dC @ B^T`` and ``dB = A^T @ dC`` — and both are issued
    through the *same* dataflow-configurable Pallas kernel, with the
    block shapes permuted to follow the transposed operands (so the
    dimension/block divisibility contract of :func:`tt_gemm` carries
    over to the backward shapes unchanged).
    """

    @jax.custom_vjp
    def f(a, b):
        return tt_gemm(a, b, dataflow=dataflow, block_m=block_m,
                       block_k=block_k, block_n=block_n,
                       out_dtype=out_dtype, interpret=interpret)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        # dA (m, k) = g (m, n) @ B^T (n, k): K axis is n -> block_n
        da = tt_gemm(g, b.T, dataflow=dataflow, block_m=block_m,
                     block_k=block_n, block_n=block_k, interpret=interpret)
        # dB (k, n) = A^T (k, m) @ g (m, n): M axis is k -> block_k
        db = tt_gemm(a.T, g, dataflow=dataflow, block_m=block_k,
                     block_k=block_m, block_n=block_n, interpret=interpret)
        return da.astype(a.dtype), db.astype(b.dtype)

    f.defvjp(fwd, bwd)
    return f(a, b)
