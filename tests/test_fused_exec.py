"""Fusion-aware path execution: kernel, segmentation, costing, tuning.

Covers the fused-segment PR's acceptance criteria: (1) fused-segment
execution is *bit-identical* to the per-step ``tt_gemm`` route
(property-tested over random modes/ranks/paths/segmentations, plus a
sharded variant through ``plan/sharded.py``); (2) a DSE run exists where
fused-aware costing flips the chosen path vs spill-always costing;
(3) the ``segments`` schema field round-trips and is absent-on-wire
backward compatible; (4) the execution-log ring stays bounded; (5) the
WS/IS fp32-accumulation fix pins their bf16 results to OS; (6) the
backward-path cache is keyed on a stable pow2 token bucket.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FPGA_VU9P, fusion
from repro.core.contraction import core_tensors, execute_path
from repro.core.cost_table import build_cost_tables, fused_cost_tables
from repro.core.dse import global_search
from repro.core.paths import find_topk_paths
from repro.core.simulator import (
    Dataflow,
    fused_layer_latency,
    gemm_latency,
)
from repro.core.tensor_network import tt_linear_network
from repro.kernels import ops
from repro.plan import (
    LayerPlan,
    Tiling,
    choose_segments,
    execution_log,
    execution_log_dropped,
    load_plan,
    reset_execution_log,
)
from repro.plan import executor as plan_executor
from repro.plan.executor import planned_tt_linear

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

#: (in_modes, out_modes, ranks) draws for the property tests — kept
#: small so interpret-mode Pallas stays fast, but spanning d=2/d=3,
#: rank-1 boundary edges, and non-square mode products
PROBLEMS = (
    ((4, 8), (8, 4), (1, 4, 1)),
    ((8, 8), (8, 8), (4, 8, 4)),
    ((3, 5), (5, 3), (1, 3, 1)),
    ((3, 5, 2), (2, 5, 3), (2, 3, 4, 3, 2)),
    ((10, 6), (6, 10), (1, 6, 1)),
)


@pytest.fixture(autouse=True)
def _clean_log():
    reset_execution_log()
    yield
    reset_execution_log()


def _layer_inputs(tn, in_modes, tokens, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.standard_normal((tokens, int(np.prod(in_modes)))), dtype)
    cores = [jnp.asarray(rng.standard_normal(n.dims), dtype)
             for n in tn.nodes if n.kind != "input"]
    return x, cores


def _layer_plan(steps, tiling, dataflow="OS", segments=None):
    return LayerPlan(
        name="l", path_index=0,
        path_steps=tuple(tuple(s) for s in steps),
        dataflow=dataflow, partitioning=(1, 1), backend="tt_gemm",
        tiling=tiling, segments=segments)


# ---------------------------------------------------------------------------
# property: fused-segment execution == per-step route, bit for bit
# ---------------------------------------------------------------------------

@given(
    prob=st.sampled_from(PROBLEMS),
    tokens=st.sampled_from((12, 64, 100)),
    path_idx=st.integers(0, 3),
    block_tokens=st.sampled_from((8, 32, 64)),
    budget_kib=st.sampled_from((2, 64, 8192)),
    dataflow=st.sampled_from(("OS", "WS", "IS")),
)
@settings(max_examples=12, deadline=None)
def test_fused_execution_bit_identical_property(
        prob, tokens, path_idx, block_tokens, budget_kib, dataflow):
    in_modes, out_modes, ranks = prob
    tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
    paths = find_topk_paths(tn, k=4)
    steps = tuple(tuple(s) for s in paths[min(path_idx, len(paths) - 1)].steps)
    # random segmentation: the VMEM budget draw varies how much fuses
    segs = fusion.segment_path(tn, steps, block_tokens=block_tokens,
                               budget_bytes=budget_kib * 1024)
    tiling = Tiling(block_tokens=block_tokens)
    x, cores = _layer_inputs(tn, in_modes, tokens)
    y_plain = planned_tt_linear(_layer_plan(steps, tiling, dataflow),
                                x, cores, in_modes, out_modes, ranks,
                                interpret=True)
    reset_execution_log()
    y_seg = planned_tt_linear(_layer_plan(steps, tiling, dataflow, segs),
                              x, cores, in_modes, out_modes, ranks,
                              interpret=True)
    a, b = np.asarray(y_plain), np.asarray(y_seg)
    assert np.array_equal(a.view(np.uint32), b.view(np.uint32)), (
        prob, tokens, path_idx, block_tokens, budget_kib, dataflow, segs)
    seg_recs = [r for r in execution_log() if "segment" in r]
    if fusion.has_fused(segs):
        assert len(seg_recs) == len(segs)
        assert [tuple(r["segment"]) for r in seg_recs] == list(segs)
    else:
        # all-singleton segmentations take the plain per-step route
        assert seg_recs == []


def test_fused_kernel_matches_per_step_contract_directly():
    """Kernel-level check, no plan machinery: ops.fused_segment returns
    exactly what the sequential gemm_contract steps would have."""
    tokens, in_modes, out_modes, ranks = 64, (8, 8), (8, 8), (4, 8, 4)
    tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
    segs = fusion.segment_path(tn, steps, block_tokens=64,
                               budget_bytes=8 * 2**20)
    assert fusion.has_fused(segs)
    x, cores = _layer_inputs(tn, in_modes, tokens)
    tensors = {"X": x.reshape((tokens,) + tuple(in_modes))}
    tensors.update(core_tensors(tn, cores))
    out_edges = ("b",) + tuple(f"i{t + 1}" for t in range(len(out_modes)))
    (s, e) = next(seg for seg in segs if seg[1] - seg[0] >= 2)
    work = [(n.edges, tensors[n.name]) for n in tn.nodes]
    # only check a leading fused run (s == 0 keeps indices literal)
    assert s == 0
    ec, val = ops.fused_segment(work, steps[s:e], block_tokens=64,
                                interpret=True)
    assert val.dtype == jnp.float32
    assert set(ec) <= {"b"} | {edge for n in tn.nodes for edge in n.edges}
    # sequential per-step reference over the same work list
    contract = ops.gemm_contract(dataflow="OS", interpret=True)
    w = list(work)
    for i, j in steps[s:e]:
        (ea, ta), (eb, tb) = w[i], w[j]
        shared = [x for x in ea if x in eb]
        seq = contract(ta, tb, (tuple(ea.index(x) for x in shared),
                                tuple(eb.index(x) for x in shared)))
        ecs = tuple(x for x in ea if x not in shared) + tuple(
            x for x in eb if x not in shared)
        w = [q for t, q in enumerate(w) if t not in (i, j)]
        w.append((ecs, seq))
    ec_ref, val_ref = w[-1]
    a, b = np.asarray(val), np.asarray(val_ref)
    if ec != ec_ref:
        b = np.transpose(b, [ec_ref.index(x) for x in ec])
    assert np.array_equal(a.view(np.uint32), b.view(np.uint32))


# ---------------------------------------------------------------------------
# sharded variant: fused routing inside the shard_map body
# ---------------------------------------------------------------------------

_SHARD_HARNESS = r"""
import jax
assert jax.device_count() == 4, jax.device_count()
import jax.numpy as jnp
import numpy as np
from repro.core import fusion
from repro.core.paths import find_topk_paths
from repro.core.tensor_network import tt_linear_network
from repro.plan import LayerPlan, Tiling, execution_log, reset_execution_log
from repro.plan.executor import planned_tt_linear
from repro.plan.sharded import shard_decision, sharded_tt_linear
from repro.sharding import ShardingRules

tokens, in_modes, out_modes, ranks = 64, (8, 8), (8, 8), (4, 8, 4)
tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
segs = fusion.segment_path(tn, steps, block_tokens=16,
                           budget_bytes=8 * 2**20)
assert fusion.has_fused(segs), segs
lp = LayerPlan(name="l", path_index=0, path_steps=steps, dataflow="OS",
               partitioning=(1, 1), backend="tt_gemm",
               tiling=Tiling(block_tokens=16), segments=segs)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((tokens, 64)), jnp.float32)
cores = [jnp.asarray(rng.standard_normal(n.dims), jnp.float32)
         for n in tn.nodes if n.kind != "input"]

mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
rules = ShardingRules(axis_sizes={"data": 4}, mesh=mesh)
dec = shard_decision(rules, tokens, (8, 8))
assert dec is not None and dec.n_shards == 4, dec

y_solo = planned_tt_linear(lp, x, cores, in_modes, out_modes, ranks,
                           interpret=True)
reset_execution_log()
y_shard = sharded_tt_linear(lp, x, cores, in_modes, out_modes, ranks,
                            rules=rules, decision=dec, interpret=True)
a, b = np.asarray(y_solo), np.asarray(y_shard)
assert np.array_equal(a.view(np.uint32), b.view(np.uint32))
recs = [r for r in execution_log() if "segment" in r]
assert recs and all(r["mesh"] == "data=4" for r in recs), recs
assert all(tuple(r["shard_shape"]) == (16, 64) for r in recs), recs
print("PASS")
"""


@pytest.mark.slow
def test_sharded_fused_execution_bit_identical():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARD_HARNESS],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0 and "PASS" in proc.stdout, (
        f"harness failed (rc={proc.returncode})\n{proc.stdout}\n"
        f"{proc.stderr[-4000:]}")


# ---------------------------------------------------------------------------
# WS/IS fp32 accumulation (satellite): bf16 results pinned to OS
# ---------------------------------------------------------------------------

@given(
    shape=st.sampled_from(((64, 256, 48), (100, 512, 33), (16, 640, 8))),
    dataflow=st.sampled_from(("WS", "IS")),
)
@settings(max_examples=6, deadline=None)
def test_ws_is_bf16_accumulation_matches_os(shape, dataflow):
    M, K, N = shape
    rng = np.random.default_rng(M * 31 + N)
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.bfloat16)
    ref = ops.gemm(a, b, dataflow="OS", interpret=True)
    out = ops.gemm(a, b, dataflow=dataflow, interpret=True)
    assert out.dtype == ref.dtype == jnp.bfloat16
    # cross-k partials accumulate in fp32 in every dataflow, so the
    # rounded bf16 outputs agree exactly — K large enough that output-
    # dtype accumulation would visibly drift
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(ref, np.float32)), (shape, dataflow)


# ---------------------------------------------------------------------------
# DSE: fused-aware costing flips the chosen path
# ---------------------------------------------------------------------------

def test_fused_costing_flips_chosen_path():
    hw = dataclasses.replace(FPGA_VU9P, name="hi_overhead",
                             gemm_overhead_cycles=200000)
    tn = tt_linear_network(256, (16, 16), (16, 16), (16, 8, 16))
    layer_paths = [find_topk_paths(tn, k=6)]
    base = build_cost_tables(layer_paths, hw,
                             ((1, 1), (2, 1), (1, 2), (2, 2)))
    fused = fused_cost_tables(layer_paths, [tn], hw, block_tokens=256,
                              budget_bytes=8 * 2**20, base=base)
    spill = global_search(layer_paths, hw, table=base.seconds).choices[0]
    aware = global_search(layer_paths, hw, table=fused.seconds).choices[0]
    # per-launch overhead dominates: fused chain runs pay ONE overhead,
    # so a monolithic fuseable path beats the split spill-always winner
    assert aware.path_index != spill.path_index, (spill, aware)
    assert aware.partitioning == (1, 1)
    # the fused table only discounts, never inflates
    assert all(fused.seconds[k] <= base.seconds[k] + 1e-12
               for k in base.seconds)


def test_fused_cost_tables_zero_interior_traffic():
    tn = tt_linear_network(64, (8, 8), (8, 8), (4, 8, 4))
    paths = find_topk_paths(tn, k=1)
    steps = tuple(tuple(s) for s in paths[0].steps)
    segs = fusion.segment_path(tn, steps, block_tokens=64,
                               budget_bytes=8 * 2**20)
    assert fusion.has_fused(segs)
    roles = fusion.step_roles(len(tn.nodes), steps, segs)
    rep = fused_layer_latency(paths[0], Dataflow.OS, FPGA_VU9P, segs, roles)
    spill = sum(
        gemm_latency(g, Dataflow.OS, FPGA_VU9P).traffic_words
        for g in paths[0].gemms)
    assert rep.traffic_words < spill
    # every interior output and chain operand of a fused run is VMEM-
    # resident: at least one step must have been zero-charged
    zeroed = [r for r in roles
              if r.interior_output or r.chain_operand is not None]
    assert zeroed


# ---------------------------------------------------------------------------
# schema: segments round-trip, absent-on-wire, validation
# ---------------------------------------------------------------------------

def _segmented_layer_plan():
    tn = tt_linear_network(64, (8, 8), (8, 8), (4, 8, 4))
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
    tiling = Tiling(block_tokens=64)
    segs = choose_segments(tn, steps, tiling)
    assert segs is not None
    return _layer_plan(steps, tiling, segments=segs), tn


def test_segments_json_roundtrip(tmp_path):
    from repro.plan import ExecutionPlan

    lp, _ = _segmented_layer_plan()
    plan = ExecutionPlan(arch="unit", hw="fpga_vu9p", objective="latency",
                         strategy="split", tokens=64, layers=(lp,))
    p = tmp_path / "plan.json"
    plan.save(str(p))
    loaded = load_plan(str(p))
    assert loaded.layers[0].segments == lp.segments
    # absent-on-wire: stripping the key loads as unsegmented (old plans)
    d = json.loads(p.read_text())
    for layer in d["layers"]:
        layer.pop("segments", None)
    p2 = tmp_path / "old.json"
    p2.write_text(json.dumps(d))
    old = load_plan(str(p2))
    assert old.layers[0].segments is None
    assert old.layers[0].path_steps == lp.path_steps


def test_segments_dropped_on_backend_change():
    lp, _ = _segmented_layer_plan()
    assert lp.with_backend("jnp").segments is None
    assert lp.with_backend("tt_gemm").segments == lp.segments


def test_segments_validation_rejects_bad_cover():
    lp, _ = _segmented_layer_plan()
    n = len(lp.path_steps)
    with pytest.raises(ValueError):
        dataclasses.replace(lp, segments=((0, n - 1),))  # gap at the end
    with pytest.raises(ValueError):
        dataclasses.replace(lp, segments=((1, n), (0, 1)))  # not ascending
    with pytest.raises(ValueError):
        dataclasses.replace(lp, backend="jnp")  # segments need tt_gemm


def test_chain_problems_catches_invalid_fusion():
    _, tn = _segmented_layer_plan()
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
    # a core-core step can never open a fused chain run
    core_steps = [
        t for t, (i, j) in enumerate(steps)
        if t == 0 and "b" not in tn.nodes[i].edges
        and "b" not in tn.nodes[j].edges
    ]
    if core_steps:
        bad = ((0, len(steps)),)
        assert fusion.chain_problems(tn, steps, bad)


# ---------------------------------------------------------------------------
# execution-log ring (satellite): bounded with a dropped counter
# ---------------------------------------------------------------------------

def test_execution_log_ring_bounded(monkeypatch):
    monkeypatch.setattr(plan_executor, "_EXEC_LOG_MAX", 16)
    lp, _ = _segmented_layer_plan()
    for _ in range(20):
        plan_executor.record_execution(lp, 64)
    log = execution_log()
    assert len(log) == 16
    assert execution_log_dropped() == 4
    reset_execution_log()
    assert list(execution_log()) == [] and execution_log_dropped() == 0


def test_segment_records_carry_range():
    lp, _ = _segmented_layer_plan()
    plan_executor.record_execution(lp, 64, segment=(0, 2))
    (rec,) = execution_log()
    assert rec["segment"] == [0, 2]
    assert rec["tiling"]["block_m"] == lp.tiling.block_m  # serve.py reads it


# ---------------------------------------------------------------------------
# backward-path cache (satellite): pow2 token bucket, stable + capped
# ---------------------------------------------------------------------------

def test_bwd_token_bucket_stability():
    bucket = plan_executor._bwd_token_bucket
    assert bucket(1) == 1 and bucket(2) == 2 and bucket(3) == 4
    assert bucket(65) == bucket(100) == bucket(128) == 128
    im, om, rk = (8, 8), (8, 8), (4, 8, 4)
    steps = {
        t: plan_executor._default_bwd_steps(bucket(t), im, om, rk)
        for t in (65, 100, 127, 128)
    }
    # one bucket -> one cache entry -> identical backward paths
    assert len({id(v) for v in steps.values()}) == 1
    assert plan_executor._default_bwd_steps.cache_info().maxsize == 256


# ---------------------------------------------------------------------------
# autotuner: fused vs per-step sweep (injected measurements)
# ---------------------------------------------------------------------------

def test_tune_fused_sweep_and_cache_replay():
    from repro.tune import Autotuner, TuningCache

    tn = tt_linear_network(64, (8, 8), (8, 8), (4, 8, 4))
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
    segs = fusion.segment_path(tn, steps, block_tokens=64,
                               budget_bytes=8 * 2**20)
    assert fusion.has_fused(segs)
    calls = []

    def fake_fused(tn_, steps_, segs_, bt, **kw):
        calls.append(("fused", bt))
        return 1.0 / bt  # larger blocks measure faster

    def fake_per_step(tn_, steps_, **kw):
        calls.append(("per_step", None))
        return 1.0

    def make(cache):
        return Autotuner(cache, "cache", device_kind="test", interpret=True,
                         kernel_fp="deadbeef", measure_fused_fn=fake_fused,
                         measure_per_step_fn=fake_per_step)

    cache = TuningCache()
    tuner = make(cache)
    res = tuner.tune_fused(tn, steps, segs, 64, include=(64,))
    assert res is not None
    assert res["block_tokens"] == 64  # largest feasible block wins
    assert res["per_step_s"] == 1.0 and res["fused_s"] == 1.0 / 64
    assert tuner.n_measured == len(calls) > 0
    # warm replay: a fresh tuner over the same cache measures nothing
    n_calls = len(calls)
    tuner2 = make(cache)
    res2 = tuner2.tune_fused(tn, steps, segs, 64, include=(64,))
    assert res2 == res
    assert tuner2.n_measured == 0 and len(calls) == n_calls


def test_fused_token_variants_preserve_segmentation():
    from repro.tune import fused_token_variants

    tn = tt_linear_network(64, (8, 8), (8, 8), (4, 8, 4))
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=1)[0].steps)
    segs = fusion.segment_path(tn, steps, block_tokens=64,
                               budget_bytes=8 * 2**20)
    variants = fused_token_variants(tn, steps, segs, 64, include=(64,))
    assert variants, "heuristic block must be feasible"
    for bt in variants:
        assert fusion.segment_path(tn, steps, block_tokens=bt,
                                   budget_bytes=8 * 2**20) == segs


# ---------------------------------------------------------------------------
# dse_cli: --fused-cost report section + compatibility gauntlet
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_dse_fused_cost_report():
    from repro.dse_cli import run_dse

    report = run_dse("tt-lm-100m", smoke=True, tokens=64, fused_cost=True)
    fc = report["fused_cost"]
    assert fc["enabled"] and fc["n_fused_cells"] > 0
    assert fc["n_fused_layers"] > 0
    assert fc["block_tokens"] == 64
    # spill-always runs keep the section absent-but-present (None)
    base = run_dse("tt-lm-100m", smoke=True, tokens=64)
    assert base["fused_cost"] is None


def test_run_dse_fused_cost_rejects_incompatible_modes():
    from repro.dse_cli import run_dse

    for kw in ({"mode": "train"}, {"objective": "throughput"},
               {"engine": "scalar"}, {"hw_search": "budget"},
               {"search": "guided"}, {"rank_search": "budget"},
               {"mode": "both"}):
        with pytest.raises(ValueError):
            run_dse("tt-lm-100m", smoke=True, tokens=64, fused_cost=True,
                    **kw)


# ---------------------------------------------------------------------------
# compiler: emitted tt_gemm plans carry segments that validate
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_emitted_plan_carries_valid_segments():
    from repro.configs import get_config
    from repro.plan.compiler import check_plan_for_config

    from repro.dse_cli import run_dse_plan

    _, plan = run_dse_plan("tt-lm-100m", smoke=True, tokens=64,
                           plan_backend="tt_gemm", top_k=2)
    segged = [lp for lp in plan.layers if lp.segments is not None]
    assert segged, "expected at least one segmented tt_gemm layer"
    for lp in segged:
        assert any(e - s >= 2 for s, e in lp.segments)
    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    assert check_plan_for_config(plan, "tt-lm-100m", cfg) == []
