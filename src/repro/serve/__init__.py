"""Production serving loop: request queue, continuous batching, and
phase-specialized execution plans.

See ``docs/serving.md`` for the architecture: requests flow through a
FIFO ready queue into a batch-1 *prefill stream* (under the prefill
plan), are slot-written into a fixed-width decode cache, and advance one
token per tick in the *decode stream* (under the decode plan).
"""

from .engine import ServeEngine
from .metrics import percentile, summarize
from .request import (
    Completion,
    Request,
    load_trace,
    save_trace,
    synthetic_trace,
)
from .scheduler import SCHEDULES, Scheduler, ServePolicy, ServeResult

__all__ = [
    "ServeEngine",
    "Scheduler", "ServePolicy", "ServeResult", "SCHEDULES",
    "Request", "Completion", "synthetic_trace", "load_trace", "save_trace",
    "percentile", "summarize",
]
