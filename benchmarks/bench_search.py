"""BENCH_search — guided-vs-exhaustive quality/efficiency trajectory.

Writes ``results/benchmarks/BENCH_search.json``: per arch, the full
VU9P-space exhaustive co-search optimum next to the budgeted guided
search — evaluation counts, the eval at which the optimum was found,
the latency gap, and whether the acceptance bar holds (within 2% of the
exhaustive best at <= 25% of the exhaustive evaluation count; on
current models the guided search finds the *exact* optimum).  Also
records multi-seed robustness so a lucky seed cannot mask a quality
regression.

  PYTHONPATH=src python -m benchmarks.run --only bench_search
"""

from __future__ import annotations

from repro.core import global_search
from repro.dse_cli import dse_problems, model_layer_paths
from repro.hw import ArchSpace, get_target
from repro.search import DEFAULT_BUDGET_FRACTION, guided_search

from .common import emit, timed

ARCHS = ["resnet18/cifar10", "tt-lm-100m"]
TOP_K = 4
SEEDS = (0, 1, 2, 3)


def run() -> list[dict]:
    rows = []
    base = get_target("fpga_vu9p")
    cands = ArchSpace(base=base).candidates()
    for arch in ARCHS:
        named, _ = dse_problems(arch)
        layer_paths = model_layer_paths(named, TOP_K)

        exhaustive, exhaustive_s = timed(
            global_search, layer_paths, base, hw_space=cands, repeat=1)

        seed_rows = []
        for seed in SEEDS:
            guided, guided_s = timed(
                guided_search, layer_paths, base, hw_space=cands,
                seed=seed, repeat=1)
            gap_pct = 100.0 * (guided.total_latency_s /
                               exhaustive.total_latency_s - 1.0)
            seed_rows.append({
                "seed": seed,
                "evals": guided.evals,
                "found_at_eval": guided.found_at_eval,
                "latency_s": guided.total_latency_s,
                "gap_pct": gap_pct,
                "chosen_arch": guided.hw.name,
                "archs_visited": len(guided.hw_candidates),
                "wall_s": guided_s,
            })
        worst_gap = max(r["gap_pct"] for r in seed_rows)
        worst_evals = max(r["evals"] for r in seed_rows)
        rows.append({
            "arch": arch,
            "n_layers": len(layer_paths),
            "hw_space_size": len(cands),
            "exhaustive_evals": exhaustive.evals,
            "exhaustive_latency_s": exhaustive.total_latency_s,
            "exhaustive_wall_s": exhaustive_s,
            "budget_fraction": DEFAULT_BUDGET_FRACTION,
            "guided_worst_gap_pct": worst_gap,
            "guided_worst_evals": worst_evals,
            "guided_worst_eval_fraction": worst_evals / exhaustive.evals,
            "meets_bar": (worst_gap <= 2.0 and
                          worst_evals <= 0.25 * exhaustive.evals),
            "seeds": seed_rows,
        })
    emit("BENCH_search", rows,
         keys=["arch", "n_layers", "hw_space_size", "exhaustive_evals",
               "guided_worst_evals", "guided_worst_eval_fraction",
               "guided_worst_gap_pct", "meets_bar"])
    return rows


if __name__ == "__main__":
    run()
