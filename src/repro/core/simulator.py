"""Analytic systolic-array latency simulator (paper 3.3, simulator of [18]).

SCALE-Sim-style closed-form model of a parameterizable ``R x C`` systolic
array with IS / OS / WS dataflows, double-buffered scratchpads and a DRAM
bandwidth roof.  Every target is a ``repro.hw.HardwareConfig``: the
paper-faithful FPGA setup (32x32 PEs @ 200 MHz, INT8), the TPU-v5e
adaptation, and every candidate of the searched architecture space
(``repro.hw.space``) all drive this one model.

Per-GEMM latency = max(compute_cycles, dram_traffic / bandwidth): each GEMM
is either pipeline-bound or memory-bound, which is exactly the asymmetry
that makes the MAC-optimal contraction path differ from the latency-optimal
one (paper Fig. 3).

Core partitioning (paper 4.2): the array may be split into two half-cores
(``1x2``: two R x C/2, ``2x1``: two R/2 x C).  Independent contraction
branches run concurrently on the halves; dependent stages run *jointly*,
each half-core taking half of the widest GEMM dimension.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Sequence

import numpy as np

from ..hw.config import HardwareConfig
from ..hw.targets import FPGA_VU9P
from .paths import CandidatePath
from .tensor_network import GemmShape


class Dataflow(str, enum.Enum):
    IS = "IS"  # input-stationary
    OS = "OS"  # output-stationary
    WS = "WS"  # weight-stationary


#: the paper's dataflow space D_l
ALL_DATAFLOWS: tuple[Dataflow, ...] = (Dataflow.IS, Dataflow.OS, Dataflow.WS)

#: core-partitioning options C_all = {1x1, 1x2, 2x1} (rows_split, cols_split)
Partitioning = tuple[int, int]
ALL_PARTITIONINGS: tuple[Partitioning, ...] = ((1, 1), (1, 2), (2, 1))

#: global strategy space H (paper 3.2): each strategy constrains C to C_h
STRATEGY_SPACE: dict[str, tuple[Partitioning, ...]] = {
    "monolithic": ((1, 1),),
    "split": ((1, 2), (2, 1)),
}


@dataclasses.dataclass(frozen=True)
class GemmReport:
    cycles: float
    compute_cycles: float
    traffic_words: float
    utilization: float  # MACs / (cycles * array MACs/cycle)


def _cdiv(a, b):
    """Exact ceil-division; elementwise over Python ints or integer ndarrays."""
    return -(-a // b)


def _reads(operand_words, reuse_folds, hw: HardwareConfig):
    """DRAM words read for an operand reused across ``reuse_folds`` passes.

    If the operand fits on-chip it is read once; otherwise every pass
    re-streams it (double-buffered, so no write-back cost for read operands).
    Elementwise over Python ints or integer ndarrays.
    """
    if isinstance(operand_words, np.ndarray):
        return np.where(
            operand_words * hw.bytes_per_word <= hw.sram_input_bytes,
            operand_words,
            operand_words * reuse_folds,
        )
    if operand_words * hw.bytes_per_word <= hw.sram_input_bytes:
        return operand_words
    return operand_words * reuse_folds


def gemm_cost_model(M, K, N, df: Dataflow, R, C, hw: HardwareConfig):
    """The closed-form per-GEMM cost model, expressed exactly once.

    Elementwise over Python ints (the scalar oracle, ``gemm_latency``) or
    int64 ndarrays (the batched engine, ``repro.core.cost_table``);
    ``tpu_cost.TPU_V5E`` re-parameterizes the same model via
    ``HardwareConfig`` constants.

    Returns ``(cycles, compute_cycles, traffic_words)`` as float64, where
    cycles = max(compute, traffic / bandwidth) + per-GEMM overhead — the
    pipeline-vs-memory roof of paper 3.3.
    """
    a_words, b_words, c_words = M * K, K * N, M * N
    if df is Dataflow.OS:
        # each PE owns one output; K streams through the array
        compute = _cdiv(M, R) * _cdiv(N, C) * (K + R + C - 2)
        traffic = (
            _reads(a_words, _cdiv(N, C), hw)
            + _reads(b_words, _cdiv(M, R), hw)
            + c_words  # written once
        )
    elif df is Dataflow.WS:
        # a K x N weight tile is pinned; M activations stream past it
        # (R-cycle weight preload per fold)
        compute = _cdiv(K, R) * _cdiv(N, C) * (R + M + C - 1)
        traffic = (
            _reads(a_words, _cdiv(N, C), hw)
            + b_words  # each weight element loaded exactly once
            # partial outputs spill/reload once per extra K fold
            + c_words * (2 * _cdiv(K, R) - 1)
        )
    elif df is Dataflow.IS:
        # an M x K input tile is pinned; N weight columns stream past it
        compute = _cdiv(M, R) * _cdiv(K, C) * (R + N + C - 1)
        traffic = (
            a_words  # each input element loaded exactly once
            + _reads(b_words, _cdiv(M, R), hw)
            + c_words * (2 * _cdiv(K, C) - 1)
        )
    else:  # pragma: no cover
        raise ValueError(df)
    if isinstance(compute, np.ndarray):
        compute = np.asarray(compute, np.float64)
        traffic = np.asarray(traffic, np.float64)
        mem_cycles = traffic / hw.dram_words_per_cycle
        cycles = np.maximum(compute, mem_cycles) + hw.gemm_overhead_cycles
        return cycles, compute, traffic
    # Python-int fast path (the per-cell scalar oracle): the same IEEE
    # double ops as the array path, so results stay bit-identical
    compute = float(compute)
    traffic = float(traffic)
    cycles = max(compute, traffic / hw.dram_words_per_cycle) + hw.gemm_overhead_cycles
    return cycles, compute, traffic


def gemm_latency(
    g: GemmShape,
    df: Dataflow,
    hw: HardwareConfig,
    rows: int | None = None,
    cols: int | None = None,
) -> GemmReport:
    """Closed-form latency of one (M x K) @ (K x N) GEMM on an R x C array."""
    R = rows if rows is not None else hw.pe_rows
    C = cols if cols is not None else hw.pe_cols
    cycles, compute, traffic = gemm_cost_model(g.M, g.K, g.N, df, R, C, hw)
    cycles = float(cycles)
    util = g.macs / (cycles * R * C) if cycles > 0 else 0.0
    return GemmReport(cycles, float(compute), float(traffic), util)


# ---------------------------------------------------------------------------
# Path-level scheduling with core partitioning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerReport:
    cycles: float
    seconds: float
    macs: int
    utilization: float
    traffic_words: float
    n_parallel_stages: int  # stages where both half-cores ran distinct GEMMs


def _split_gemm(g: GemmShape, part: Partitioning) -> GemmShape:
    """Half of a GEMM executed jointly by both half-cores.

    ``1x2`` splits the N dimension (column halves), ``2x1`` splits M.
    """
    if part == (1, 2):
        return GemmShape(g.M, g.K, math.ceil(g.N / 2), g.a_is_input, g.b_is_input)
    if part == (2, 1):
        return GemmShape(math.ceil(g.M / 2), g.K, g.N, g.a_is_input, g.b_is_input)
    return g


def _dependency_levels(path: CandidatePath, n_leaves: int) -> list[list[int]]:
    """Group path steps into dependency levels (steps in a level are
    mutually independent).  Step t contracts two entries of the current
    node list; merged results are appended, mirroring
    ``TensorNetwork.contract_pair``.
    """
    # node id -> producing step (None for leaves); current list holds ids
    current: list[tuple[int, int | None]] = [(i, None) for i in range(n_leaves)]
    next_id = n_leaves
    dep_of_step: list[set[int]] = []
    producer: dict[int, int] = {}
    for t, (i, j) in enumerate(path.steps):
        (_, pa), (_, pb) = current[i], current[j]
        deps = set()
        if pa is not None:
            deps.add(pa)
        if pb is not None:
            deps.add(pb)
        dep_of_step.append(deps)
        producer[next_id] = t
        current = [c for s, c in enumerate(current) if s not in (i, j)]
        current.append((next_id, t))
        next_id += 1
    # longest-path level of each step
    level = [0] * len(path.steps)
    for t in range(len(path.steps)):
        level[t] = 1 + max((level[d] for d in dep_of_step[t]), default=-1)
    levels: list[list[int]] = [[] for _ in range(max(level, default=-1) + 1)]
    for t, lv in enumerate(level):
        levels[lv].append(t)
    return levels


def layer_latency(
    path: CandidatePath,
    df: Dataflow,
    part: Partitioning,
    hw: HardwareConfig,
    n_leaves: int | None = None,
) -> LayerReport:
    """End-to-end latency of a contraction path under (dataflow, partition).

    Monolithic (1,1): GEMMs run sequentially on the full array.
    Split (1,2)/(2,1): per dependency level, independent GEMMs pair up on
    the two half-cores (concurrent); leftovers run jointly (dimension split
    across both halves) — paper 4.2 semantics.
    """
    if n_leaves is None:
        n_leaves = len(path.steps) + 1
    gemms = path.gemms
    total_macs = sum(g.macs for g in gemms)
    traffic = 0.0

    if part == (1, 1):
        cycles = 0.0
        for g in gemms:
            rep = gemm_latency(g, df, hw)
            cycles += rep.cycles
            traffic += rep.traffic_words
        util = total_macs / (cycles * hw.macs_per_cycle) if cycles else 0.0
        return LayerReport(cycles, cycles / hw.freq_hz, total_macs, util, traffic, 0)

    rsplit, csplit = part
    half_rows = hw.pe_rows // rsplit
    half_cols = hw.pe_cols // csplit
    levels = _dependency_levels(path, n_leaves)
    cycles = 0.0
    n_parallel = 0
    for level in levels:
        # pair up independent GEMMs on the two half-cores
        idx = 0
        while idx + 1 < len(level):
            ga = gemms[level[idx]]
            gb = gemms[level[idx + 1]]
            ra = gemm_latency(ga, df, hw, half_rows, half_cols)
            rb = gemm_latency(gb, df, hw, half_rows, half_cols)
            cycles += max(ra.cycles, rb.cycles)
            traffic += ra.traffic_words + rb.traffic_words
            n_parallel += 1
            idx += 2
        if idx < len(level):  # leftover runs jointly, split across halves
            g = gemms[level[idx]]
            half = _split_gemm(g, part)
            rep = gemm_latency(half, df, hw, half_rows, half_cols)
            cycles += rep.cycles
            traffic += 2 * rep.traffic_words
    util = total_macs / (cycles * hw.macs_per_cycle) if cycles else 0.0
    return LayerReport(cycles, cycles / hw.freq_hz, total_macs, util, traffic, n_parallel)


def simulate(
    path: CandidatePath,
    part: Partitioning,
    df: Dataflow,
    hw: HardwareConfig = FPGA_VU9P,
) -> float:
    """Latency in seconds — the ``Simulate(p, c, d)`` oracle of Algorithm 1."""
    return layer_latency(path, df, part, hw).seconds


# ---------------------------------------------------------------------------
# fused-segment accounting (repro.core.fusion chain runs)
# ---------------------------------------------------------------------------

def fused_segment_cost(
    gemms: Sequence[GemmShape],
    roles: Sequence,            # Sequence[fusion.StepRole] slice
    hw: HardwareConfig,
) -> tuple[float, float]:
    """``(cycles, traffic_words)`` of one fused chain run.

    Every step inside a fused run executes OS-style (the fused kernel's
    in-VMEM fallback, see ``kernels/fused_path.py``): compute is the OS
    pipeline term per step, but the HBM traffic drops the terms the
    fusion keeps on-chip — the chain operand's reads (it is the previous
    step's VMEM-resident result) and every interior output's writes
    (fp32 VMEM scratch).  The whole run pays ONE per-GEMM launch
    overhead, not one per step.
    """
    R, C = hw.pe_rows, hw.pe_cols
    compute = 0.0
    traffic = 0.0
    for g, role in zip(gemms, roles):
        compute += float(_cdiv(g.M, R) * _cdiv(g.N, C) * (g.K + R + C - 2))
        a = (0.0 if role.chain_operand == "a"
             else float(_reads(g.M * g.K, _cdiv(g.N, C), hw)))
        b = (0.0 if role.chain_operand == "b"
             else float(_reads(g.K * g.N, _cdiv(g.M, R), hw)))
        c = 0.0 if role.interior_output else float(g.M * g.N)
        traffic += a + b + c
    cycles = (max(compute, traffic / hw.dram_words_per_cycle)
              + hw.gemm_overhead_cycles)
    return cycles, traffic


def fused_layer_latency(
    path: CandidatePath,
    df: Dataflow,
    hw: HardwareConfig,
    segments: Sequence[tuple[int, int]],
    roles: Sequence,            # Sequence[fusion.StepRole], one per step
) -> LayerReport:
    """Monolithic-layer latency under a fusion segmentation.

    Singleton segments keep the per-step model with dataflow ``df``
    (their kernels run stand-alone, exactly as in :func:`layer_latency`);
    fused runs use :func:`fused_segment_cost`.  Only the monolithic
    ``(1, 1)`` partitioning is modeled — fused runs serialize a chain, so
    half-core pairing never applies inside one.
    """
    gemms = path.gemms
    total_macs = sum(g.macs for g in gemms)
    cycles = 0.0
    traffic = 0.0
    for (s, e) in segments:
        if e - s >= 2:
            cyc, tra = fused_segment_cost(gemms[s:e], roles[s:e], hw)
        else:
            rep = gemm_latency(gemms[s], df, hw)
            cyc, tra = rep.cycles, rep.traffic_words
        cycles += cyc
        traffic += tra
    util = total_macs / (cycles * hw.macs_per_cycle) if cycles else 0.0
    return LayerReport(cycles, cycles / hw.freq_hz, total_macs, util,
                       traffic, 0)
