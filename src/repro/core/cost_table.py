"""Batched cost-table engine — Algorithm 1 line 2, vectorized.

``dse.build_cost_table`` populates ``T[l, p, c, d]`` over every (layer,
path, partitioning, dataflow) tuple.  The scalar engine calls
``simulate()`` once per cell: a Python quadruple loop that re-walks each
path's dependency structure and re-evaluates each GEMM for every cell.
This module replaces it with three passes that exploit the structure of
the space:

1. **Dedup.** Candidate paths of the same layer share most GEMM shapes,
   identical layers repeat across the model (transformer stacks), and a
   split partitioning evaluates every GEMM on the same half-core geometry
   — so the set of *unique* ``(M, K, N, R, C)`` evaluations is far
   smaller than ``L x P x C x D x steps``.  Layers with identical
   candidate-path sets collapse to one representative.
2. **Batch evaluation.** All unique rows go through the shared
   closed-form model (``simulator.gemm_cost_model``) as int64 arrays —
   one vectorized NumPy evaluation per dataflow instead of per-cell
   Python calls.
3. **Assembly.** Each (path, partitioning) is compiled once into a short
   program of ``seq`` / ``pair`` / ``joint`` ops over registry row ids
   (mirroring ``simulator.layer_latency``'s scheduling exactly), then
   replayed with gather views vectorized over the dataflow axis.  The
   accumulation order matches the scalar oracle op for op, so the table
   is bit-identical to ``simulate()``.

The engine also returns per-cell DRAM traffic and per-path MACs, which
the ``repro.dse`` CLI combines into the energy-delay-product objective.

**Hardware axis.**  The same three passes batch over *hardware
candidates* (``build_cost_tables_hw``): candidates sharing an array
geometry share compiled programs, candidates sharing a memory profile
(SRAM capacity, bandwidth, word width, per-GEMM overhead) share one
vectorized model evaluation, and each program is replayed once over the
``(profile, dataflow)`` axes.  Per candidate the result is bit-identical
to a scalar ``simulate()`` sweep with that candidate — the joint
(architecture, path, dataflow) search of ``dse.global_search(hw_space=
...)`` therefore inherits the exhaustive-optimality guarantee.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional, Sequence

import numpy as np

from . import fusion
from .paths import CandidatePath
from .simulator import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS,
    Dataflow,
    HardwareConfig,
    Partitioning,
    _dependency_levels,
    _split_gemm,
    fused_layer_latency,
    gemm_cost_model,
)

#: cost-table key — (layer, path_index, partitioning, dataflow)
Key = tuple[int, int, Partitioning, Dataflow]

# ---------------------------------------------------------------------------
# energy constants for the EDP objective (rough INT8-era figures: a MAC is
# ~0.3 pJ in 16 nm, DRAM access ~15 pJ/byte — the *ratio* is what steers
# the argmin, and it matches the common "DRAM is ~50-100x a MAC" rule)
# ---------------------------------------------------------------------------
MAC_ENERGY_J = 0.3e-12
DRAM_ENERGY_J_PER_BYTE = 15e-12


@dataclasses.dataclass(frozen=True)
class CostTables:
    """Vectorized build output: the latency table plus EDP ingredients."""

    seconds: dict[Key, float]
    traffic_words: dict[Key, float]
    macs: dict[tuple[int, int], int]  # (l, p) -> total path MACs
    build_seconds: float
    n_cells: int
    n_unique_gemm_evals: int
    n_unique_layers: int

    def energy_joules(self, key: Key, hw: HardwareConfig) -> float:
        """Energy of one configuration under the simple MAC+DRAM model."""
        return (
            self.macs[key[:2]] * MAC_ENERGY_J
            + self.traffic_words[key] * hw.bytes_per_word * DRAM_ENERGY_J_PER_BYTE
        )

    def edp(self, hw: HardwareConfig) -> dict[Key, float]:
        """Energy-delay product table over the same keys as ``seconds``."""
        return {
            k: s * self.energy_joules(k, hw) for k, s in self.seconds.items()
        }


class _GemmRegistry:
    """Deduplicated (M, K, N, R, C) rows, batch-evaluated per dataflow."""

    def __init__(self) -> None:
        self._index: dict[tuple[int, int, int, int, int], int] = {}
        self.rows: list[tuple[int, int, int, int, int]] = []

    def add(self, M: int, K: int, N: int, R: int, C: int) -> int:
        key = (M, K, N, R, C)
        idx = self._index.get(key)
        if idx is None:
            idx = len(self.rows)
            self._index[key] = idx
            self.rows.append(key)
        return idx

    def evaluate(
        self,
        dataflows: Sequence[Dataflow],
        profiles: Sequence[HardwareConfig],
    ) -> tuple[np.ndarray, np.ndarray]:
        """(cycles, traffic_words) as [n_rows, n_profiles, n_dataflows].

        A *profile* is a representative hw candidate for everything the
        per-GEMM model reads besides the array geometry (which lives in
        the rows): SRAM capacity, word width, bandwidth, overhead.
        """
        rows = np.asarray(self.rows, dtype=np.int64).reshape(-1, 5)
        M, K, N, R, C = (rows[:, i] for i in range(5))
        cyc = np.empty((rows.shape[0], len(profiles), len(dataflows)))
        tra = np.empty_like(cyc)
        for p_idx, prof_hw in enumerate(profiles):
            for d_idx, df in enumerate(dataflows):
                cycles, _, traffic = gemm_cost_model(M, K, N, df, R, C,
                                                     prof_hw)
                cyc[:, p_idx, d_idx] = cycles
                tra[:, p_idx, d_idx] = traffic
        return cyc, tra


# one compiled op: ("seq", row) | ("pair", row_a, row_b) | ("joint", row)
_Program = list[tuple]


def _compile_path(
    path: CandidatePath,
    part: Partitioning,
    hw: HardwareConfig,
    reg: _GemmRegistry,
) -> _Program:
    """Compile one (path, partitioning) into registry-id ops.

    Mirrors ``simulator.layer_latency``: monolithic runs GEMMs in path
    order on the full array; split pairs up independent GEMMs per
    dependency level on the half-cores, leftovers run jointly on a
    dimension-split shape.
    """
    gemms = path.gemms
    if part == (1, 1):
        R, C = hw.pe_rows, hw.pe_cols
        return [("seq", reg.add(g.M, g.K, g.N, R, C)) for g in gemms]
    rsplit, csplit = part
    R, C = hw.pe_rows // rsplit, hw.pe_cols // csplit
    ops: _Program = []
    for level in _dependency_levels(path, len(path.steps) + 1):
        idx = 0
        while idx + 1 < len(level):
            ga, gb = gemms[level[idx]], gemms[level[idx + 1]]
            ops.append(
                ("pair", reg.add(ga.M, ga.K, ga.N, R, C),
                 reg.add(gb.M, gb.K, gb.N, R, C))
            )
            idx += 2
        if idx < len(level):
            h = _split_gemm(gemms[level[idx]], part)
            ops.append(("joint", reg.add(h.M, h.K, h.N, R, C)))
    return ops


def _layer_key(paths: Sequence[CandidatePath]) -> tuple:
    """Identity of a layer's DSE subproblem: path structure + GEMM shapes."""
    return tuple(
        (p.steps, tuple(g.as_tuple() for g in p.gemms)) for p in paths
    )


def build_cost_tables_hw(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw_list: Sequence[HardwareConfig],
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
) -> tuple[CostTables, ...]:
    """Populate T[l, p, c, d] for every hardware candidate in one build.

    The hw axis shares all three passes: candidates with the same array
    geometry ``(pe_rows, pe_cols)`` share compiled programs and registry
    rows, candidates with the same memory profile share one vectorized
    model evaluation, and each program replays once over the
    ``(profile, dataflow)`` axes before broadcasting to the candidates.
    Returns one :class:`CostTables` per candidate, aligned with
    ``hw_list`` — each bit-identical to a scalar ``simulate()`` sweep of
    that candidate.  ``build_seconds`` / ``n_unique_gemm_evals`` report
    the *shared* batched build on every element.
    """
    t0 = time.perf_counter()
    hw_list = tuple(hw_list)
    if not hw_list:
        raise ValueError("hw_list must name at least one hardware candidate")
    partitionings = tuple(partitionings)
    dataflows = tuple(dataflows)

    # pass 1 — dedup layers; compile programs once per array geometry
    unique_layers: dict[tuple, list[int]] = {}
    for l, paths in enumerate(layer_paths):
        unique_layers.setdefault(_layer_key(paths), []).append(l)

    geom_index: dict[tuple[int, int], int] = {}
    geom_reps: list[HardwareConfig] = []
    geom_of_hw: list[int] = []
    for hw in hw_list:
        g = (hw.pe_rows, hw.pe_cols)
        if g not in geom_index:
            geom_index[g] = len(geom_reps)
            geom_reps.append(hw)
        geom_of_hw.append(geom_index[g])
    hw_by_geom: dict[int, list[int]] = {}
    for h, g in enumerate(geom_of_hw):
        hw_by_geom.setdefault(g, []).append(h)

    reg = _GemmRegistry()
    # programs[key][p_idx][part][g_idx] -> _Program
    programs: dict[tuple, list[dict[Partitioning, list[_Program]]]] = {}
    for key, members in unique_layers.items():
        paths = layer_paths[members[0]]
        programs[key] = [
            {part: [_compile_path(path, part, rep, reg) for rep in geom_reps]
             for part in partitionings}
            for path in paths
        ]

    # pass 2 — one vectorized model evaluation per (memory profile,
    # dataflow); the array geometry is part of the registry rows
    prof_index: dict[tuple, int] = {}
    prof_reps: list[HardwareConfig] = []
    prof_of_hw: list[int] = []
    for hw in hw_list:
        p = (hw.sram_input_bytes, hw.bytes_per_word,
             hw.dram_words_per_cycle, hw.gemm_overhead_cycles)
        if p not in prof_index:
            prof_index[p] = len(prof_reps)
            prof_reps.append(hw)
        prof_of_hw.append(prof_index[p])
    cyc, tra = reg.evaluate(dataflows, prof_reps)

    # pass 3 — replay programs (vectorized over (profile, dataflow),
    # scalar-ordered accumulation so every candidate's table is
    # bit-identical to its sequential oracle), broadcast per candidate
    seconds: list[dict[Key, float]] = [{} for _ in hw_list]
    traffic: list[dict[Key, float]] = [{} for _ in hw_list]
    macs: list[dict[tuple[int, int], int]] = [{} for _ in hw_list]
    for key, members in unique_layers.items():
        paths = layer_paths[members[0]]
        for p_idx, per_part in enumerate(programs[key]):
            for part, per_geom in per_part.items():
                for g_idx, prog in enumerate(per_geom):
                    tot_c = np.zeros((len(prof_reps), len(dataflows)))
                    tot_t = np.zeros_like(tot_c)
                    for op in prog:
                        if op[0] == "seq":
                            tot_c = tot_c + cyc[op[1]]
                            tot_t = tot_t + tra[op[1]]
                        elif op[0] == "pair":
                            tot_c = tot_c + np.maximum(cyc[op[1]], cyc[op[2]])
                            tot_t = tot_t + (tra[op[1]] + tra[op[2]])
                        else:  # joint: both half-cores stream the split GEMM
                            tot_c = tot_c + cyc[op[1]]
                            tot_t = tot_t + 2.0 * tra[op[1]]
                    for h in hw_by_geom[g_idx]:
                        secs = tot_c[prof_of_hw[h]] / hw_list[h].freq_hz
                        tw = tot_t[prof_of_hw[h]]
                        for d_idx, d in enumerate(dataflows):
                            s, t = float(secs[d_idx]), float(tw[d_idx])
                            for l in members:
                                seconds[h][(l, p_idx, part, d)] = s
                                traffic[h][(l, p_idx, part, d)] = t
            for h in range(len(hw_list)):
                for l in members:
                    macs[h][(l, p_idx)] = paths[p_idx].macs

    build_s = time.perf_counter() - t0
    return tuple(
        CostTables(
            seconds=seconds[h],
            traffic_words=traffic[h],
            macs=macs[h],
            build_seconds=build_s,
            n_cells=len(seconds[h]),
            n_unique_gemm_evals=len(reg.rows),
            n_unique_layers=len(unique_layers),
        )
        for h in range(len(hw_list))
    )


def fused_cost_tables(
    layer_paths: Sequence[Sequence[CandidatePath]],
    layer_networks: Sequence,        # Sequence[TensorNetwork], aligned
    hw: HardwareConfig,
    *,
    block_tokens: int,
    budget_bytes: int,
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    base: Optional[CostTables] = None,
) -> CostTables:
    """Fusion-aware cost tables: fused chain runs charge no interior HBM.

    For every (layer, path) whose steps segment into at least one fused
    chain run under ``budget_bytes`` (``repro.core.fusion.segment_path``
    at ``block_tokens`` — the same rule the plan compiler stamps
    ``LayerPlan.segments`` with), the monolithic ``(1, 1)`` cells are
    replaced by :func:`simulator.fused_layer_latency`: interior
    intermediates charge zero HBM bytes, the chain operand's reads are
    free, and each run pays one launch overhead.  Split-partitioning
    cells and unfusable paths keep the spill-per-step numbers, so the
    result is a drop-in ``global_search(table=...)`` override — paths
    that *segment well* win cells they would otherwise lose to
    lower-MAC but spill-heavy orders.

    ``layer_networks`` supplies the edge structure the segmentation
    reads (any batch size — the batch dim is re-blocked to
    ``block_tokens``).  Pass ``base`` to reuse an already-built
    spill-always table.
    """
    t0 = time.perf_counter()
    partitionings = tuple(partitionings)
    dataflows = tuple(dataflows)
    if len(layer_paths) != len(layer_networks):
        raise ValueError(
            f"{len(layer_paths)} path lists vs {len(layer_networks)} networks")
    if base is None:
        base = build_cost_tables(layer_paths, hw, partitionings, dataflows)
    seconds = dict(base.seconds)
    traffic = dict(base.traffic_words)

    # identical layers share the segmentation (and the fused cells): the
    # segmentation depends only on the re-blocked entry dims and the steps
    seg_cache: dict[tuple, tuple] = {}
    for l, (paths, tn) in enumerate(zip(layer_paths, layer_networks)):
        entries = tuple(fusion._entry_dims(tn, block_tokens,
                                           fusion.BATCH_EDGE))
        for p_idx, path in enumerate(paths):
            ck = (entries, tuple(path.steps))
            segs = seg_cache.get(ck)
            if segs is None:
                segs = fusion.segment_path(
                    tn, path.steps, block_tokens=block_tokens,
                    budget_bytes=budget_bytes)
                seg_cache[ck] = segs
            if not fusion.has_fused(segs):
                continue
            roles = fusion.step_roles(len(tn.nodes), path.steps, segs)
            for d in dataflows:
                rep = fused_layer_latency(path, d, hw, segs, roles)
                if (1, 1) in partitionings:
                    seconds[(l, p_idx, (1, 1), d)] = rep.seconds
                    traffic[(l, p_idx, (1, 1), d)] = rep.traffic_words
    return CostTables(
        seconds=seconds,
        traffic_words=traffic,
        macs=dict(base.macs),
        build_seconds=base.build_seconds + (time.perf_counter() - t0),
        n_cells=base.n_cells,
        n_unique_gemm_evals=base.n_unique_gemm_evals,
        n_unique_layers=base.n_unique_layers,
    )


def shard_streamed_tokens(tokens: int, n_shards: int) -> int:
    """Per-device token count for an ``n_shards`` data-parallel mesh.

    The shard_map executor (``repro.plan.sharded``) streams
    ``tokens / n_shards`` rows per device, so cost tables and tilings
    must be evaluated at this count for the searched mapping to match
    what executes.  Non-divisible counts floor (the executor would fall
    back to the jnp path for those, but the search still wants the
    closest per-shard problem); never below 1.
    """
    if n_shards <= 1:
        return tokens
    return max(1, tokens // n_shards)


def build_cost_tables(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig,
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
) -> CostTables:
    """Populate T[l, p, c, d] (plus traffic/MACs) with batched evaluation."""
    return build_cost_tables_hw(layer_paths, (hw,), partitionings,
                                dataflows)[0]


def build_cost_table_vectorized(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig,
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
) -> dict[Key, float]:
    """Drop-in replacement for the scalar ``dse.build_cost_table`` loop."""
    return build_cost_tables(layer_paths, hw, partitionings, dataflows).seconds


def table_cells(
    layer_paths: Sequence[Sequence[CandidatePath]],
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
) -> int:
    """Number of T[l, p, c, d] cells one architecture's table holds.

    The evaluation-accounting unit of the guided search: an exhaustive
    co-search reads ``len(space) * table_cells(...)`` cells, a budgeted
    one stops early.  Counting cells (not batched GEMM evaluations, which
    dedup across repeated layers) keeps the unit comparable between the
    exhaustive and guided drivers regardless of layer dedup.
    """
    per_layer = len(partitionings) * len(dataflows)
    return sum(len(paths) * per_layer for paths in layer_paths)


# ---------------------------------------------------------------------------
# training cost tables: fwd + bwd + grad-update (paper's training objective)
# ---------------------------------------------------------------------------

#: backward-table key — (layer, partitioning, dataflow); the backward term
#: is independent of the *forward* path choice (gradients contract directly
#: from X / dY / cores, no stashed forward intermediates)
BwdKey = tuple[int, Partitioning, Dataflow]


@dataclasses.dataclass(frozen=True)
class BackwardChoice:
    """Argmin path for one backward problem under a fixed (c, d)."""

    wrt: str                      # "dx" | core node name
    path_index: int
    path: CandidatePath
    latency_s: float


@dataclasses.dataclass(frozen=True)
class TrainCostTables:
    """Fwd + bwd + update decomposition of the training-latency objective.

    ``fwd`` is the usual inference table; ``bwd_seconds[(l, c, d)]`` is the
    sum over the layer's backward problems of each problem's best candidate
    path, evaluated on the *same* partitioning/dataflow as the forward (one
    hardware configuration per layer per step — the per-problem *path* is
    free, the dataflow is the layer's).  ``bwd_choices`` records those
    per-problem argmin paths; ``update_seconds[l]`` is the DRAM-bound
    optimizer update.  ``bwd_traffic_words`` mirrors the forward table's
    traffic field (the EDP ingredient) so a train-EDP objective can be
    assembled without rebuilding; nothing consumes it yet.
    """

    fwd: CostTables
    bwd_seconds: dict[BwdKey, float]
    bwd_traffic_words: dict[BwdKey, float]
    bwd_choices: dict[BwdKey, tuple[BackwardChoice, ...]]
    bwd_macs: dict[int, int]           # l -> sum of each problem's min-MAC path
    update_seconds: dict[int, float]
    weights: "TrainCostWeights"
    build_seconds: float

    def train_seconds(self) -> dict[Key, float]:
        """The joint objective over the forward table's key space:

        ``T[l, p, c, d] = w_f * fwd + w_b * bwd(l, c, d) + w_u * update(l)``
        """
        w = self.weights
        return {
            (l, p, c, d): (w.fwd * s
                           + w.bwd * self.bwd_seconds[(l, c, d)]
                           + w.update * self.update_seconds[l])
            for (l, p, c, d), s in self.fwd.seconds.items()
        }


def _flatten_backwards(
    layer_backwards: Sequence,
) -> tuple[list[Sequence[CandidatePath]], list[tuple[int, int]]]:
    """(layer, problem) -> pseudo-layer rows for the batched engine."""
    flat_paths: list[Sequence[CandidatePath]] = []
    flat_owner: list[tuple[int, int]] = []     # (layer, problem index)
    for l, lb in enumerate(layer_backwards):
        for m, prob in enumerate(lb.problems):
            flat_paths.append(prob.paths)
            flat_owner.append((l, m))
    return flat_paths, flat_owner


def _assemble_bwd(
    layer_backwards: Sequence,
    flat_owner: Sequence[tuple[int, int]],
    bwd_tables: CostTables,
    partitionings: Sequence[Partitioning],
    dataflows: Sequence[Dataflow],
) -> tuple[dict[BwdKey, float], dict[BwdKey, float],
           dict[BwdKey, tuple[BackwardChoice, ...]]]:
    """Per (layer, c, d): sum of each backward problem's argmin path."""
    bwd_seconds: dict[BwdKey, float] = {}
    bwd_traffic: dict[BwdKey, float] = {}
    bwd_choices: dict[BwdKey, tuple[BackwardChoice, ...]] = {}
    for c in partitionings:
        for d in dataflows:
            per_layer: dict[int, list[BackwardChoice]] = {}
            per_layer_traffic: dict[int, float] = {}
            for flat_l, (l, m) in enumerate(flat_owner):
                prob = layer_backwards[l].problems[m]
                lat, q = min(
                    (bwd_tables.seconds[(flat_l, q, c, d)], q)
                    for q in range(len(prob.paths))
                )
                per_layer.setdefault(l, []).append(
                    BackwardChoice(prob.wrt, q, prob.paths[q], lat))
                per_layer_traffic[l] = (
                    per_layer_traffic.get(l, 0.0)
                    + bwd_tables.traffic_words[(flat_l, q, c, d)])
            for l, choices in per_layer.items():
                key = (l, c, d)
                bwd_seconds[key] = sum(ch.latency_s for ch in choices)
                bwd_choices[key] = tuple(choices)
                bwd_traffic[key] = per_layer_traffic[l]
    return bwd_seconds, bwd_traffic, bwd_choices


def build_train_cost_tables_hw(
    layer_paths: Sequence[Sequence[CandidatePath]],
    layer_backwards: Sequence,            # Sequence[backward.LayerBackward]
    hw_list: Sequence[HardwareConfig],
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    weights: Optional["TrainCostWeights"] = None,
) -> tuple[TrainCostTables, ...]:
    """The training-latency decomposition for every hardware candidate.

    The forward and (flattened) backward tables are built hw-batched —
    shared registry rows, one vectorized evaluation per memory profile —
    then assembled per candidate (the per-problem backward argmin and
    the DRAM-bound update term both depend on the candidate).
    """
    from .backward import TrainCostWeights, update_seconds as _upd

    t0 = time.perf_counter()
    if len(layer_paths) != len(layer_backwards):
        raise ValueError(
            f"{len(layer_paths)} forward layers vs "
            f"{len(layer_backwards)} backward layer problems")
    hw_list = tuple(hw_list)
    weights = weights or TrainCostWeights()
    partitionings = tuple(partitionings)
    dataflows = tuple(dataflows)

    fwd_list = build_cost_tables_hw(layer_paths, hw_list, partitionings,
                                    dataflows)
    flat_paths, flat_owner = _flatten_backwards(layer_backwards)
    bwd_list = build_cost_tables_hw(flat_paths, hw_list, partitionings,
                                    dataflows)

    bwd_macs: dict[int, int] = {}
    for l, lb in enumerate(layer_backwards):
        bwd_macs[l] = sum(
            min(p.macs for p in prob.paths) for prob in lb.problems)

    assembled = []
    for h, hw in enumerate(hw_list):
        bwd_seconds, bwd_traffic, bwd_choices = _assemble_bwd(
            layer_backwards, flat_owner, bwd_list[h], partitionings,
            dataflows)
        upd = {l: _upd(lb.n_params, hw)
               for l, lb in enumerate(layer_backwards)}
        assembled.append((fwd_list[h], bwd_seconds, bwd_traffic,
                          bwd_choices, upd))
    build_s = time.perf_counter() - t0
    return tuple(
        TrainCostTables(
            fwd=fwd,
            bwd_seconds=bwd_seconds,
            bwd_traffic_words=bwd_traffic,
            bwd_choices=bwd_choices,
            bwd_macs=dict(bwd_macs),
            update_seconds=upd,
            weights=weights,
            build_seconds=build_s,
        )
        for fwd, bwd_seconds, bwd_traffic, bwd_choices, upd in assembled
    )


def build_train_cost_tables(
    layer_paths: Sequence[Sequence[CandidatePath]],
    layer_backwards: Sequence,            # Sequence[backward.LayerBackward]
    hw: HardwareConfig,
    partitionings: Sequence[Partitioning] = ALL_PARTITIONINGS,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    weights: Optional["TrainCostWeights"] = None,
) -> TrainCostTables:
    """Populate the training-latency decomposition with batched evaluation.

    Backward problems are flattened into one pseudo-layer list and pushed
    through the same vectorized engine as the forward table, so identical
    backward networks across a transformer stack (and across problems)
    dedup exactly like forward layers do.
    """
    return build_train_cost_tables_hw(
        layer_paths, layer_backwards, (hw,), partitionings, dataflows,
        weights=weights)[0]
