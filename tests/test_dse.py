"""Algorithm 1 (global latency-driven DSE) vs brute-force oracle."""

from repro.core import (
    ALL_DATAFLOWS,
    FPGA_VU9P,
    STRATEGY_SPACE,
    TPU_V5E,
    brute_force_search,
    explore_model,
    find_topk_paths,
    global_search,
    pareto_front,
    tt_linear_network,
)


def _layer_paths(sizes):
    nets = [tt_linear_network(*s) for s in sizes]
    return [find_topk_paths(tn, k=3) for tn in nets]


SIZES = [
    (4, (4, 4), (4, 4), (4, 4, 4)),
    (4, (2, 8), (8, 2), (4, 4, 4)),
]


def test_global_search_matches_brute_force():
    lp = _layer_paths(SIZES)
    res = global_search(lp, FPGA_VU9P)
    bf = brute_force_search(lp, FPGA_VU9P)
    assert abs(res.total_latency_s - bf) < 1e-12


def test_strategy_constraint_honored():
    lp = _layer_paths(SIZES)
    res = global_search(lp, FPGA_VU9P)
    allowed = set(STRATEGY_SPACE[res.strategy])
    for choice in res.choices:
        assert choice.partitioning in allowed


def test_cost_table_complete():
    lp = _layer_paths(SIZES)
    res = global_search(lp, FPGA_VU9P)
    parts = sorted({c for cs in STRATEGY_SPACE.values() for c in cs})
    for l, paths in enumerate(lp):
        for p in range(len(paths)):
            for c in parts:
                for d in ALL_DATAFLOWS:
                    assert (l, p, c, d) in res.cost_table


def test_explore_model_end_to_end():
    nets = [tt_linear_network(*s) for s in SIZES]
    res = explore_model(nets, TPU_V5E, top_k=2)
    assert res.total_latency_s > 0
    assert len(res.choices) == len(nets)


def test_total_is_sum_of_choices():
    lp = _layer_paths(SIZES)
    res = global_search(lp, FPGA_VU9P)
    assert abs(sum(res.per_layer_latency) - res.total_latency_s) < 1e-12


def test_pareto_front():
    pts = [(1.0, 5.0), (2.0, 1.0), (3.0, 4.0), (0.5, 6.0), (2.5, 0.5)]
    front = pareto_front(pts)
    assert 3 in front and 1 in front and 4 in front
    assert 2 not in front  # dominated by (2.0, 1.0)
