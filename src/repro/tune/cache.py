"""Persistent tuning cache: measured kernel latencies, keyed by machine.

One :class:`TuningEntry` per tuning *problem* — a unique ``(GEMM shape,
dataflow)`` pair for the ``tt_gemm`` backend, or a unique
``(layer network, token count)`` pair for the ``streaming_tt`` backend —
holding every variant measured so far plus the deterministic argmin.
Entries are keyed by (problem, backend, device kind, interpret flag):
measurements taken on one machine never leak onto another, and
interpret-mode (CPU validation) numbers never masquerade as compiled-TPU
numbers.

Serialization is canonical (sorted keys, fixed indentation, trailing
newline) so that save -> load -> save is byte-identical — the same
round-trip property the plan schema guarantees, asserted by
``tests/test_tune.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Mapping, Optional, Sequence

CACHE_FORMAT = "repro.tuning_cache"
CACHE_VERSION = 1

#: default on-disk location (``repro.tune`` / ``repro.dse --tune``)
DEFAULT_CACHE_PATH = os.path.join("results", "tuning_cache.json")

#: kernel modules whose source participates in the cache key — a cached
#: latency is a property of the *kernel implementation* as much as of the
#: machine, so editing any of these must invalidate old measurements
KERNEL_MODULES = (
    "repro.kernels.ops",
    "repro.kernels.tt_gemm",
    "repro.kernels.streaming_tt",
    "repro.kernels.fused_path",
)


def kernel_fingerprint(paths: Optional[Sequence[str]] = None) -> str:
    """Short content hash of the kernel source files (staleness guard).

    Measurements are taken *through* the kernels in
    :data:`KERNEL_MODULES`; if any of their sources change, every cached
    number is suspect.  Embedding this hash in the cache key makes stale
    entries unreachable (they simply stop matching) rather than silently
    replayed — ROADMAP gap (d).  ``paths`` overrides the file set for
    tests.
    """
    if paths is None:
        import importlib

        paths = []
        for mod_name in KERNEL_MODULES:
            mod = importlib.import_module(mod_name)
            if getattr(mod, "__file__", None):
                paths.append(mod.__file__)
    h = hashlib.sha1()
    for p in sorted(paths):
        with open(p, "rb") as f:
            h.update(f.read())
        h.update(b"\0")
    return h.hexdigest()[:12]


def variant_key(blocks: tuple[int, ...]) -> str:
    """``(256, 128, 64)`` -> ``"256x128x64"`` (a JSON-safe dict key)."""
    return "x".join(str(int(b)) for b in blocks)


def parse_variant(key: str) -> tuple[int, ...]:
    return tuple(int(p) for p in key.split("x"))


@dataclasses.dataclass
class TuningEntry:
    """Measurements for one tuning problem on one device.

    ``measured_s`` maps variant keys (``variant_key`` of the block
    tuple: ``(block_m, block_k, block_n)`` for GEMMs,
    ``(block_tokens,)`` for streaming sweeps) to median seconds.
    ``best`` is the deterministic argmin — ties resolve to the
    numerically smallest variant tuple, so replaying a cache always
    reproduces the same tiling.
    """

    key: str
    kind: str                      # "gemm" | "streaming"
    backend: str                   # "tt_gemm" | "streaming_tt"
    device_kind: str
    interpret: bool
    problem: dict                  # shape / network signature provenance
    measured_s: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def best(self) -> Optional[tuple[int, ...]]:
        if not self.measured_s:
            return None
        return min(self.measured_s,
                   key=lambda k: (self.measured_s[k], parse_variant(k)))

    @property
    def best_blocks(self) -> Optional[tuple[int, ...]]:
        b = self.best
        return parse_variant(b) if b is not None else None

    @property
    def best_seconds(self) -> Optional[float]:
        b = self.best
        return self.measured_s[b] if b is not None else None

    def to_json(self) -> dict:
        return {
            "kind": self.kind,
            "backend": self.backend,
            "device_kind": self.device_kind,
            "interpret": self.interpret,
            "problem": self.problem,
            "measured_s": dict(self.measured_s),
            "best": self.best,
            "best_s": self.best_seconds,
        }

    @classmethod
    def from_json(cls, key: str, d: Mapping) -> "TuningEntry":
        return cls(
            key=key,
            kind=str(d["kind"]),
            backend=str(d["backend"]),
            device_kind=str(d["device_kind"]),
            interpret=bool(d["interpret"]),
            problem=dict(d["problem"]),
            measured_s={str(k): float(v)
                        for k, v in d.get("measured_s", {}).items()},
        )


class TuningCache:
    """In-memory view of the persistent tuning cache file."""

    def __init__(self, entries: Optional[dict[str, TuningEntry]] = None):
        self.entries: dict[str, TuningEntry] = dict(entries or {})

    # -- lookup / update ---------------------------------------------------
    def get(self, key: str) -> Optional[TuningEntry]:
        return self.entries.get(key)

    def ensure(self, key: str, *, kind: str, backend: str, device_kind: str,
               interpret: bool, problem: dict) -> TuningEntry:
        e = self.entries.get(key)
        if e is None:
            e = TuningEntry(key=key, kind=kind, backend=backend,
                            device_kind=device_kind, interpret=interpret,
                            problem=problem)
            self.entries[key] = e
        return e

    def __len__(self) -> int:
        return len(self.entries)

    # -- canonical JSON round-trip ----------------------------------------
    def to_json(self) -> dict:
        return {
            "format": CACHE_FORMAT,
            "version": CACHE_VERSION,
            "entries": {k: e.to_json() for k, e in self.entries.items()},
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def loads(cls, text: str) -> "TuningCache":
        d = json.loads(text)
        fmt = d.get("format")
        if fmt != CACHE_FORMAT:
            raise ValueError(f"not a tuning cache (format={fmt!r})")
        version = int(d.get("version", -1))
        if version != CACHE_VERSION:
            raise ValueError(
                f"tuning cache version {version} unsupported "
                f"(this build reads version {CACHE_VERSION})")
        return cls({k: TuningEntry.from_json(k, e)
                    for k, e in d.get("entries", {}).items()})

    def save(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            return cls.loads(f.read())

    @classmethod
    def load_or_empty(cls, path: str) -> "TuningCache":
        if os.path.exists(path):
            return cls.load(path)
        return cls()


def entry_fingerprint(key: str) -> Optional[str]:
    """The kernel-source hash embedded in a cache key, or None.

    Keys end in ``:k<hash>`` since PR 7 (the staleness guard); older
    keys carry no fingerprint and are treated as stale by the merger.
    """
    _, sep, tail = key.rpartition(":k")
    if not sep or not tail or not all(c in "0123456789abcdef" for c in tail):
        return None
    return tail


_SHARD_SEGMENT = re.compile(r":s(\d+):k[0-9a-f]+$")


def entry_shards(key: str) -> Optional[int]:
    """The shard count embedded in a cache key, or None.

    Keys carry an ``:s<n>`` segment since the sharded-search PR: a
    measurement of a per-shard problem (tokens split across an n-way
    mesh) must never answer a lookup for a different mesh width, because
    the per-shard shapes differ.  Pre-shard keys carry no segment and
    parse as None — the merger treats them as shard-mismatched when a
    shard filter is active.
    """
    m = _SHARD_SEGMENT.search(key)
    return int(m.group(1)) if m else None


def merge_caches(
    caches: Sequence["TuningCache"],
    *,
    fingerprint: Optional[str] = None,
    shards: Optional[int] = None,
) -> tuple["TuningCache", int, int]:
    """Union tuning caches from several hosts into one (ROADMAP gap d).

    Entries merge per problem key; colliding *variant* measurements
    resolve last-writer-wins (later caches in the sequence override
    earlier ones — pass them oldest-first), as does the entry's
    provenance metadata.  Entries whose key carries a kernel-source
    fingerprint different from ``fingerprint`` (default: the current
    :func:`kernel_fingerprint`) were measured through edited kernels —
    they are dropped rather than merged.  With ``shards`` set, entries
    measured at a different mesh width (:func:`entry_shards`, including
    legacy keys with no shard tag) are also dropped: their per-shard
    problem shapes do not match the target mesh.  Returns ``(merged,
    n_dropped_stale, n_dropped_shards)``.

    Distinct machines never collide by construction (device kind,
    interpret flag and shard count are part of the key), so merging
    caches from a heterogeneous fleet is lossless.
    """
    if fingerprint is None:
        fingerprint = kernel_fingerprint()
    merged: dict[str, TuningEntry] = {}
    dropped = 0
    dropped_shards = 0
    for cache in caches:
        for key, e in cache.entries.items():
            if entry_fingerprint(key) != fingerprint:
                dropped += 1
                continue
            if shards is not None and entry_shards(key) != shards:
                dropped_shards += 1
                continue
            prev = merged.get(key)
            if prev is None:
                merged[key] = dataclasses.replace(
                    e, problem=dict(e.problem),
                    measured_s=dict(e.measured_s))
            else:
                # last writer wins on identical variant keys AND metadata
                measured = {**prev.measured_s, **e.measured_s}
                merged[key] = dataclasses.replace(
                    e, problem=dict(e.problem), measured_s=measured)
    return TuningCache(merged), dropped, dropped_shards
