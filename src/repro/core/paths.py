"""MAC-guided contraction-path search (paper 3.2).

Depth-first search over pairwise contraction orders of a tensor network,
keeping the top-K lowest-MAC complete paths.  Two prunes make this
tractable (the paper's "redundancy-pruning strategy"):

  1. *Canonical-state memoisation* — two partial orders that reach the same
     set of intermediate tensors are equivalent going forward.  For top-K
     search we keep up to K distinct arrival costs per state: a revisit is
     pruned only if it duplicates a recorded arrival cost or is no better
     than the K-th cheapest recorded arrival (arriving costlier than K
     cheaper prefixes cannot contribute a top-K completion).
  2. *Branch-and-bound* — a partial path whose accumulated MACs already
     meet or exceed the current K-th best complete cost is abandoned.

Additionally, complete paths whose multiset of GEMM shapes matches an
already-kept candidate are dropped as *computationally equivalent*,
keeping the candidate set diverse (distinct hardware behaviours).
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Optional, Sequence

from .tensor_network import GemmShape, TensorNetwork


@dataclasses.dataclass(frozen=True)
class CandidatePath:
    """A complete contraction path with its cost summary."""

    steps: tuple[tuple[int, int], ...]  # pairwise (i, j) in current-index space
    macs: int
    gemms: tuple[GemmShape, ...]

    @property
    def signature(self) -> frozenset:
        """Multiset of GEMM shapes — equivalence class for diversity pruning."""
        counted: dict[tuple[int, int, int], int] = {}
        for g in self.gemms:
            counted[g.as_tuple()] = counted.get(g.as_tuple(), 0) + 1
        return frozenset(counted.items())


def find_topk_paths(
    tn: TensorNetwork,
    k: int = 4,
    max_states: int = 200_000,
    connected_only: bool = True,
) -> list[CandidatePath]:
    """Return up to ``k`` lowest-MAC contraction paths, ascending by MACs.

    ``connected_only`` restricts to pairs sharing at least one edge; for
    non-degenerate TT ranks (>= 2) the connected space contains the MAC
    optimum (property-tested against exhaustive enumeration), and outer
    products blow up the search space.  Known limitation: with rank-1
    interior edges the chain is effectively disconnected and an outer
    product can be marginally cheaper.  ``max_states`` caps DFS work.
    """
    if len(tn) < 2:
        raise ValueError("network must contain at least two nodes")

    # heap of (-macs, counter, CandidatePath): max-heap on cost, size <= k
    best: list[tuple[int, int, CandidatePath]] = []
    seen_signatures: set[frozenset] = set()
    visited: dict[frozenset, list[int]] = {}  # state -> sorted arrival costs (<= k)
    counter = [0]
    states = [0]

    def kth_cost() -> Optional[int]:
        if len(best) < k:
            return None
        return -best[0][0]

    def offer(cand: CandidatePath) -> None:
        if cand.signature in seen_signatures:
            # computationally equivalent to a kept candidate -> redundant
            return
        counter[0] += 1
        heapq.heappush(best, (-cand.macs, counter[0], cand))
        seen_signatures.add(cand.signature)
        if len(best) > k:
            _, _, dropped = heapq.heappop(best)
            seen_signatures.discard(dropped.signature)

    def dfs(
        cur: TensorNetwork,
        acc_macs: int,
        steps: list[tuple[int, int]],
        gemms: list[GemmShape],
    ) -> None:
        if states[0] > max_states:
            return
        states[0] += 1
        bound = kth_cost()
        if bound is not None and acc_macs >= bound:
            return  # branch-and-bound
        key = cur.state_key()
        arrivals = visited.setdefault(key, [])
        if acc_macs in arrivals:
            return  # identical-cost prefix to this state already explored
        if len(arrivals) >= k and acc_macs >= arrivals[k - 1]:
            return  # k cheaper prefixes already reached this state
        bisect.insort(arrivals, acc_macs)
        del arrivals[k:]
        n = len(cur)
        if n == 1:
            offer(CandidatePath(tuple(steps), acc_macs, tuple(gemms)))
            return
        pairs = []
        for i in range(n):
            for j in range(i + 1, n):
                shared = cur.shared_edges(i, j)
                if connected_only and not shared:
                    continue
                pairs.append((i, j))
        if not pairs:  # disconnected network: allow one outer product
            pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        # visit cheapest-GEMM pairs first so the bound tightens early
        scored = []
        for (i, j) in pairs:
            nxt, g = cur.contract_pair(i, j)
            scored.append((g.macs, i, j, nxt, g))
        scored.sort(key=lambda t: t[0])
        for macs, i, j, nxt, g in scored:
            bound = kth_cost()
            if bound is not None and acc_macs + macs >= bound:
                continue
            steps.append((i, j))
            gemms.append(g)
            dfs(nxt, acc_macs + macs, steps, gemms)
            steps.pop()
            gemms.pop()

    dfs(tn, 0, [], [])
    out = sorted((c for _, _, c in best), key=lambda c: c.macs)
    return out


def greedy_path(tn: TensorNetwork) -> CandidatePath:
    """Cheapest-pair-first greedy path (baseline; not necessarily optimal)."""
    cur = tn
    steps: list[tuple[int, int]] = []
    gemms: list[GemmShape] = []
    macs = 0
    while len(cur) > 1:
        n = len(cur)
        options = []
        for i in range(n):
            for j in range(i + 1, n):
                if not cur.shared_edges(i, j):
                    continue
                nxt, g = cur.contract_pair(i, j)
                options.append((g.macs, i, j, nxt, g))
        if not options:
            for i in range(n):
                for j in range(i + 1, n):
                    nxt, g = cur.contract_pair(i, j)
                    options.append((g.macs, i, j, nxt, g))
        options.sort(key=lambda t: t[0])
        c, i, j, cur, g = options[0]
        steps.append((i, j))
        gemms.append(g)
        macs += c
    return CandidatePath(tuple(steps), macs, tuple(gemms))


def reconstruction_path(tn: TensorNetwork) -> CandidatePath:
    """The naive 'reconstruct W then multiply' order (paper Fig. 3 left).

    Contracts all weight cores together first (materialising the full
    weight), then applies the input — the strawman baseline.
    """
    cur = tn
    steps: list[tuple[int, int]] = []
    gemms: list[GemmShape] = []
    macs = 0
    while len(cur) > 1:
        n = len(cur)
        core_idx = [t for t in range(n) if cur.nodes[t].kind == "core"]
        if len(core_idx) >= 2:
            # contract the first adjacent core pair (chain order)
            pair = None
            for a in core_idx:
                for b in core_idx:
                    if a < b and cur.shared_edges(a, b):
                        pair = (a, b)
                        break
                if pair:
                    break
            if pair is None:
                pair = (core_idx[0], core_idx[1])
            i, j = pair
        else:
            i, j = 0, 1
            if n > 2:
                raise AssertionError("unexpected network shape")
        cur, g = cur.contract_pair(i, j)
        steps.append((i, j))
        gemms.append(g)
        macs += g.macs
    return CandidatePath(tuple(steps), macs, tuple(gemms))
