"""Shared benchmark plumbing: timing, CSV/JSON emission."""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def emit(name: str, rows: list[dict], keys: list[str] | None = None) -> None:
    """Print a CSV block and persist JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    if not rows:
        print(f"# {name}: (no rows)")
        return
    keys = keys or list(rows[0].keys())
    print(f"# --- {name} ---")
    print(",".join(keys))
    for r in rows:
        print(",".join(_fmt(r.get(k)) for k in keys))
    print()


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, best_seconds)"""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
