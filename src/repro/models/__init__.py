"""Model zoo + family-dispatched API.

``api(cfg)`` returns the family's (init_params, train_loss, prefill,
decode_step, init_caches) callables with a uniform signature, and
``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins the
multi-pod dry-run lowers against (no device allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import encdec as _encdec
from . import lm as _lm
from .config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ENC_LEN_CAP = 4096   # encoder frame length for enc-dec decode shapes


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_caches: Callable


def api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            init_params=lambda rng: _encdec.init_params(rng, cfg),
            train_loss=lambda p, b: _encdec.train_loss(cfg, p, b),
            prefill=lambda p, b, max_seq: _encdec.prefill(cfg, p, b, max_seq),
            decode_step=lambda p, t, c, pos: _encdec.decode_step(cfg, p, t, c, pos),
            init_caches=lambda batch, max_seq: _encdec.init_caches(
                cfg, batch, max_seq, min(ENC_LEN_CAP, max_seq), jnp.dtype(cfg.dtype)),
        )
    return ModelAPI(
        init_params=lambda rng: _lm.init_params(rng, cfg),
        train_loss=lambda p, b: _lm.train_loss(cfg, p, b),
        prefill=lambda p, b, max_seq: _lm.prefill(cfg, p, b, max_seq),
        decode_step=lambda p, t, c, pos: _lm.decode_step(cfg, p, t, c, pos),
        init_caches=lambda batch, max_seq: _lm.init_caches(
            cfg, batch, max_seq, jnp.dtype(cfg.dtype)),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step.

    train:   {tokens, labels[, frontend]}
    prefill: {tokens[, frontend]}
    decode:  {token, cache_pos, caches}
    """
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((gb, s), i32)

    def frontend_spec(seq: int):
        if cfg.family == "vlm":
            n = cfg.n_frontend_tokens or 256
            return jax.ShapeDtypeStruct((gb, n, cfg.d_model), dt)
        if cfg.family == "encdec":
            n = min(ENC_LEN_CAP, seq)
            return jax.ShapeDtypeStruct((gb, n, cfg.d_model), dt)
        return None

    if shape.step == "train":
        batch = {"tokens": tok, "labels": jax.ShapeDtypeStruct((gb, s), i32)}
        fe = frontend_spec(s)
        if fe is not None:
            batch["frontend"] = fe
        return batch
    if shape.step == "prefill":
        batch = {"tokens": tok}
        fe = frontend_spec(s)
        if fe is not None:
            batch["frontend"] = fe
        return batch
    if shape.step == "decode":
        max_seq = s + (cfg.n_frontend_tokens or 256 if cfg.family == "vlm" else 0)
        caches = jax.eval_shape(lambda: api(cfg).init_caches(gb, max_seq))
        return {
            "token": jax.ShapeDtypeStruct((gb, 1), i32),
            "cache_pos": jax.ShapeDtypeStruct((), i32),
            "caches": caches,
        }
    raise ValueError(shape.step)


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "ModelAPI", "api", "input_specs", "ENC_LEN_CAP",
]
