"""BENCH_dse — the machine-readable DSE perf trajectory across PRs.

Writes ``results/benchmarks/BENCH_dse.json``: per arch, the cost-table
build time (fixed target and hw-batched over the architecture space),
the searched-space size, and the best latency per architecture axis
(fixed vs co-searched) — so regressions in the search engine or in the
quality of the co-searched optimum show up as diffs in one file.

  PYTHONPATH=src python -m benchmarks.run --only bench_dse
"""

from __future__ import annotations

from repro.core import build_cost_tables, build_cost_tables_hw, global_search
from repro.dse_cli import VISION_ARCHS, dse_problems, model_layer_paths
from repro.hw import ArchSpace, get_target

from .common import emit, timed

ARCHS = list(VISION_ARCHS) + ["tt-lm-100m"]
TOP_K = 4


def run() -> list[dict]:
    rows = []
    for arch in ARCHS:
        named, _ = dse_problems(arch)
        layer_paths = model_layer_paths(named, TOP_K)

        base = get_target("fpga_vu9p")
        _, fixed_build_s = timed(build_cost_tables, layer_paths, base)
        space = ArchSpace(base=base)
        cands = space.candidates()
        per_hw, hw_build_s = timed(
            build_cost_tables_hw, layer_paths, cands, repeat=1)
        co = global_search(layer_paths, hw_space=cands,
                           hw_tables=[t.seconds for t in per_hw])
        fixed = next(c for c in co.hw_candidates if c.hw.name == base.name)
        rows.append({
            "arch": arch,
            "n_layers": len(layer_paths),
            "n_cells": per_hw[0].n_cells,
            "n_unique_gemm_evals": per_hw[0].n_unique_gemm_evals,
            "table_build_s": fixed_build_s,
            "hw_space_size": len(cands),
            "hw_batched_build_s": hw_build_s,
            "best_latency_fixed_s": fixed.total_latency_s,
            "best_latency_cosearch_s": co.total_latency_s,
            "cosearch_improvement_pct": (
                100.0 * (1.0 - co.total_latency_s / fixed.total_latency_s)),
            "chosen_arch": co.hw.name,
        })
    emit("BENCH_dse", rows)
    return rows


if __name__ == "__main__":
    run()
