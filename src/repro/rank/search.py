"""Joint (decomposition, path, partitioning, dataflow) frontier search.

Each rank candidate re-derives the model's per-layer tensor networks
under its factorizations and reuses the *existing* DSE machinery —
top-K path search, batched cost tables, the hierarchical global argmin
(or the PR 7 guided explorer, or the hw-batched architecture co-search)
— to get its end-to-end latency.  Together with the accuracy proxy
(``repro.rank.proxy``) every candidate becomes a (latency, compression,
accuracy) triple; the result reports the (latency, accuracy) Pareto
frontier and a chosen candidate:

- no ``accuracy_budget``: the lowest-latency candidate whose proxy is
  no worse than the frozen decomposition's — "free" speedups only;
- with ``accuracy_budget=EPS``: the lowest-latency candidate with proxy
  <= EPS (ValueError if none qualifies — the budget is infeasible).

``python -m repro.dse --rank-search budget`` drives this and embeds the
chosen factorizations in the emitted v4 plan.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.core import ALL_PARTITIONINGS, build_cost_tables, global_search
from repro.core.dse import pareto_front

from .proxy import candidate_proxy, family_proxy
from .space import RankCandidate, RankSpace, vision_rank_space

RANK_SEARCH_MODES = ("off", "budget")

#: proxy comparisons tolerate float noise up to this slack
PROXY_EPS = 1e-12


@dataclasses.dataclass
class CandidateEval:
    """One evaluated rank candidate."""

    candidate: RankCandidate
    named: list                    # [(instance name, TensorNetwork)]
    res: object                    # repro.core.dse.DSEResult
    total_latency_s: float
    accuracy_proxy: float
    family_proxies: dict[str, float]
    eval_seconds: float


@dataclasses.dataclass
class RankSearchResult:
    """Frontier + chosen candidate of one rank search."""

    arch: str
    tokens: int
    evals: list[CandidateEval]
    frontier: tuple[int, ...]      # indices into evals, latency-sorted
    chosen: int
    frozen: int
    accuracy_budget: Optional[float]
    param_budget_ratio: float

    @property
    def chosen_eval(self) -> CandidateEval:
        return self.evals[self.chosen]

    @property
    def frozen_eval(self) -> CandidateEval:
        return self.evals[self.frozen]

    @property
    def dominates_frozen(self) -> bool:
        """True when some non-frozen candidate is strictly faster at
        equal-or-better accuracy proxy than the frozen decomposition."""
        fz = self.frozen_eval
        return any(
            e.total_latency_s < fz.total_latency_s
            and e.accuracy_proxy <= fz.accuracy_proxy + PROXY_EPS
            for i, e in enumerate(self.evals) if i != self.frozen
        )

    @property
    def improvement_pct(self) -> Optional[float]:
        fz = self.frozen_eval
        if fz.total_latency_s <= 0:
            return None
        return 100.0 * (1.0 - self.chosen_eval.total_latency_s
                        / fz.total_latency_s)


def _evaluate(
    named: list,
    hw_cfg,
    *,
    top_k: int,
    hw_space=None,
    search: str = "exhaustive",
    search_budget: Optional[int] = None,
    search_seed: int = 0,
):
    """One candidate through the existing DSE stack; returns DSEResult."""
    from repro.dse_cli import model_layer_paths

    layer_paths = model_layer_paths(named, top_k)
    if search == "guided":
        from repro.search import guided_search

        return guided_search(
            layer_paths, hw_cfg, objective="latency",
            hw_space=hw_space, budget=search_budget, seed=search_seed)
    if hw_space is not None:
        from repro.core import build_cost_tables_hw

        per_hw = build_cost_tables_hw(layer_paths, hw_space,
                                      ALL_PARTITIONINGS)
        return global_search(layer_paths, hw_space=hw_space,
                             hw_tables=[t.seconds for t in per_hw])
    tables = build_cost_tables(layer_paths, hw_cfg, ALL_PARTITIONINGS)
    return global_search(layer_paths, hw_cfg, table=tables.seconds)


def _candidate_layers(arch, cfg, cand: RankCandidate, tokens: int) -> list:
    """Per-layer problems for one candidate.

    Config archs rebuild every tensorized projection under the
    candidate's explicit factorizations; vision archs rebuild through
    ``model_layers(rank=...)`` (their mode splits are structural).
    """
    from repro.dse_cli import VISION_ARCHS, model_dse_layers

    if arch in VISION_ARCHS:
        from repro.models.vision import model_layers

        model, dataset = arch.split("/")
        return [(l.name, l.tt_network)
                for l in model_layers(model, dataset, batch=max(1, tokens),
                                      rank=cand.rank)]
    return model_dse_layers(cfg, tokens,
                            factorizations=cand.factorization_map())


def rank_search(
    arch: str,
    hw_cfg,
    *,
    top_k: int = 4,
    tokens: Optional[int] = None,
    smoke: bool = False,
    hw_space=None,
    search: str = "exhaustive",
    search_budget: Optional[int] = None,
    search_seed: int = 0,
    accuracy_budget: Optional[float] = None,
    param_budget_ratio: Optional[float] = None,
    calibration_weights=None,
    space: Optional[RankSpace] = None,
) -> RankSearchResult:
    """Search the decomposition axis jointly with the mapping axes.

    ``hw_space`` (a sequence of HardwareConfig candidates) composes the
    rank search with the architecture co-search — each rank candidate
    picks its own best architecture; ``search="guided"`` routes each
    candidate through the budgeted explorer.  ``space`` overrides the
    default candidate grid (tests shrink it); ``calibration_weights``
    (from :func:`repro.rank.proxy.activation_calibration`) reweights
    the accuracy proxy by measured activation RMS.
    """
    from repro.configs import get_config
    from repro.dse_cli import VISION_ARCHS

    if accuracy_budget is not None and accuracy_budget <= 0:
        raise ValueError("accuracy_budget must be positive "
                         "(a relative Frobenius error)")
    kw = {}
    if param_budget_ratio is not None:
        kw["param_budget_ratio"] = param_budget_ratio
    if arch in VISION_ARCHS:
        cfg = None
        tokens = 1 if tokens is None else tokens
        if space is None:
            space = vision_rank_space(arch, **kw)
    else:
        cfg = get_config(arch, smoke=smoke)
        tokens = 1024 if tokens is None else tokens
        if space is None:
            space = RankSpace.from_config(cfg, **kw)

    evals: list[CandidateEval] = []
    for cand in space.candidates():
        t0 = time.perf_counter()
        named = _candidate_layers(arch, cfg, cand, tokens)
        res = _evaluate(named, hw_cfg, top_k=top_k, hw_space=hw_space,
                        search=search, search_budget=search_budget,
                        search_seed=search_seed)
        evals.append(CandidateEval(
            candidate=cand,
            named=named,
            res=res,
            total_latency_s=res.total_latency_s,
            accuracy_proxy=candidate_proxy(cand, calibration_weights),
            family_proxies={f.name: family_proxy(f)
                            for f in cand.families},
            eval_seconds=time.perf_counter() - t0,
        ))

    frozen = 0  # RankSpace always yields the frozen candidate first
    front = pareto_front([(e.total_latency_s, e.accuracy_proxy)
                          for e in evals])
    cap = (accuracy_budget if accuracy_budget is not None
           else evals[frozen].accuracy_proxy)
    eligible = [i for i, e in enumerate(evals)
                if e.accuracy_proxy <= cap + PROXY_EPS]
    if not eligible:
        best = min(e.accuracy_proxy for e in evals)
        raise ValueError(
            f"--accuracy-budget {accuracy_budget:g} is infeasible: the "
            f"best candidate proxy is {best:.6g}")
    chosen = min(eligible,
                 key=lambda i: (evals[i].total_latency_s,
                                evals[i].candidate.name))
    return RankSearchResult(
        arch=arch,
        tokens=tokens,
        evals=evals,
        frontier=tuple(front),
        chosen=chosen,
        frozen=frozen,
        accuracy_budget=accuracy_budget,
        param_budget_ratio=space.param_budget_ratio,
    )
