"""qwen1.5-110b [dense] — QKV bias, the largest dense assigned arch.

Assigned dims: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064
[hf:Qwen/Qwen1.5-0.5B; hf].
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="qwen1.5-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab=256,
    head_dim=16,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
