"""The autotuner: measured variant sweeps + the DSE calibration table.

:class:`Autotuner` is the handle everything else consumes:

- ``plan/compiler.compile_plan(..., tilings="measured", tuner=...)`` asks
  it for the best measured ``(block_m, block_k, block_n)`` per unique
  (dominant GEMM, dataflow) and the best measured ``block_tokens`` per
  unique streaming-layer problem;
- ``repro.dse --tune`` asks it for per-dataflow measured seconds of the
  model's dominant GEMM shapes, from which :func:`measured_calibration`
  builds the per-dataflow rescale table ``dse.global_search`` applies;
- ``python -m repro.tune`` drives it directly to warm the cache.

Two modes: ``"cache"`` measures only what the persistent cache misses
(the normal mode — a warm cache replays with **zero** measurements, so
re-emitting a plan is deterministic and bit-identical), ``"measure"``
re-measures every requested variant and overwrites the cached numbers.
``n_measured`` / ``n_cache_hits`` make "the second run measured nothing"
an assertable property.

Deduplication mirrors ``core/cost_table``: repeated transformer blocks
share one cache entry per unique (GEMM shape, dataflow) and per unique
(layer network, token count), so the measurement count scales with the
number of *distinct* problems, not with model depth.
"""

from __future__ import annotations

import hashlib
import math
from typing import Optional, Sequence

from repro.core.simulator import ALL_DATAFLOWS, Dataflow, gemm_cost_model
from repro.hw import HardwareConfig
from repro.plan.compiler import (
    VMEM_BUDGET_BYTES,
    default_blocks,
    rebatch,
)
from repro.core.tensor_network import TensorNetwork

from . import measure as _measure
from .cache import TuningCache, TuningEntry, kernel_fingerprint, variant_key
from .variants import (
    GEMM_BLOCK_CAPS,
    STREAM_BLOCK_CAPS,
    dominant_gemm,
    fused_token_variants,
    gemm_variants,
    network_signature,
    streaming_variants,
)

TUNE_MODES = ("cache", "measure")

#: the compiler's default (fixed-target) tiling for one GEMM shape —
#: literally ``plan/compiler.default_blocks``, so the calibration's
#: operating point is the tiling the analytic argmin would deploy
heuristic_blocks = default_blocks


class Autotuner:
    """Measured-variant sweeps over a persistent :class:`TuningCache`."""

    def __init__(
        self,
        cache: Optional[TuningCache] = None,
        mode: str = "cache",
        *,
        cache_path: Optional[str] = None,
        device_kind: Optional[str] = None,
        interpret: Optional[bool] = None,
        warmup: int = _measure.WARMUP,
        repeats: int = _measure.REPEATS,
        measure_gemm_fn=None,
        measure_streaming_fn=None,
        measure_fused_fn=None,
        measure_per_step_fn=None,
        kernel_fp: Optional[str] = None,
        shards: int = 1,
    ) -> None:
        if mode not in TUNE_MODES:
            raise ValueError(f"unknown tune mode {mode!r}; have {TUNE_MODES}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.cache = cache if cache is not None else TuningCache()
        self.mode = mode
        self.cache_path = cache_path
        self.device_kind = (device_kind if device_kind is not None
                            else _measure.device_kind())
        self.interpret = (interpret if interpret is not None
                          else _measure.default_interpret())
        # staleness guard (ROADMAP gap d): keys carry the kernel-source
        # hash, so entries measured through edited kernels stop matching
        self.kernel_fp = (kernel_fp if kernel_fp is not None
                          else kernel_fingerprint())
        # sharded search measures per-shard problems whose shapes depend
        # on the mesh width — a 4-shard entry must never answer a
        # single-device lookup, so the shard count is part of every key
        self.shards = int(shards)
        self.warmup = warmup
        self.repeats = repeats
        # injection points for tests (no real kernels, no real clocks)
        self._measure_gemm = measure_gemm_fn or _measure.measure_gemm
        self._measure_streaming = (measure_streaming_fn
                                   or _measure.measure_streaming)
        self._measure_fused = measure_fused_fn or _measure.measure_fused
        self._measure_per_step = (measure_per_step_fn
                                  or _measure.measure_per_step)
        self.n_measured = 0
        self.n_cache_hits = 0
        self._measured_this_run: set[str] = set()

    def save(self, path: Optional[str] = None) -> None:
        """Persist the cache (to ``path`` or the constructor's path)."""
        target = path or self.cache_path
        if target is None:
            raise ValueError("no cache path to save to")
        self.cache.save(target)

    # -- keys --------------------------------------------------------------
    def _suffix(self) -> str:
        interp = "interp" if self.interpret else "native"
        return f"{self.device_kind}:{interp}:s{self.shards}:k{self.kernel_fp}"

    def gemm_key(self, M: int, K: int, N: int, dataflow: str) -> str:
        return f"gemm:{M}x{K}x{N}:{dataflow}:{self._suffix()}"

    def streaming_key(self, tn: TensorNetwork, steps, tokens: int) -> str:
        sig = network_signature(rebatch(tn, 1), steps)
        digest = hashlib.sha1(sig.encode()).hexdigest()[:16]
        return f"stream:{digest}:t{tokens}:{self._suffix()}"

    def fused_key(self, tn: TensorNetwork, steps, segments,
                  tokens: int) -> str:
        sig = network_signature(rebatch(tn, 1), steps)
        seg = "_".join(f"{s}-{e}" for s, e in segments)
        digest = hashlib.sha1(f"{sig}|{seg}".encode()).hexdigest()[:16]
        return f"fused:{digest}:t{tokens}:{self._suffix()}"

    # -- GEMM sweeps -------------------------------------------------------
    def _gemm_entry(self, M: int, K: int, N: int,
                    dataflow: str) -> TuningEntry:
        return self.cache.ensure(
            self.gemm_key(M, K, N, dataflow),
            kind="gemm", backend="tt_gemm",
            device_kind=self.device_kind, interpret=self.interpret,
            problem={"M": int(M), "K": int(K), "N": int(N),
                     "dataflow": str(dataflow)},
        )

    def _measure_into(self, entry: TuningEntry, vk: str, measure) -> float:
        run_key = f"{entry.key}#{vk}"
        fresh = run_key in self._measured_this_run
        if vk in entry.measured_s and (self.mode == "cache" or fresh):
            # "measure" re-measures stale cache entries, but at most once
            # per process — calibration and the family sweeps share points
            self.n_cache_hits += 1
            return entry.measured_s[vk]
        s = float(measure())
        entry.measured_s[vk] = s
        self._measured_this_run.add(run_key)
        self.n_measured += 1
        return s

    # -- cache-only lookups (no measuring) ---------------------------------
    def cached_gemm_blocks(
        self, M: int, K: int, N: int, dataflow: str
    ) -> Optional[tuple[int, ...]]:
        """Best *already-measured* blocks for one GEMM problem, or None.

        Never measures: the plan compiler uses this for backward-op
        tilings (train plans reuse forward measurements when the cache
        holds them, analytic heuristic otherwise — ROADMAP gap b).
        """
        entry = self.cache.get(self.gemm_key(M, K, N, dataflow))
        return entry.best_blocks if entry is not None else None

    def cached_streaming_tokens(
        self, tn: TensorNetwork, steps, tokens: int
    ) -> Optional[int]:
        """Best already-measured ``block_tokens`` for one streaming
        problem, or None (cache-only — see :meth:`cached_gemm_blocks`)."""
        entry = self.cache.get(self.streaming_key(tn, steps, tokens))
        blocks = entry.best_blocks if entry is not None else None
        return blocks[0] if blocks else None

    def gemm_seconds(self, M: int, K: int, N: int, dataflow: str,
                     blocks: tuple[int, int, int]) -> float:
        """Measured seconds of one (shape, dataflow, tiling) variant."""
        entry = self._gemm_entry(M, K, N, dataflow)
        return self._measure_into(
            entry, variant_key(blocks),
            lambda: self._measure_gemm(
                M, K, N, dataflow, blocks, interpret=self.interpret,
                warmup=self.warmup, repeats=self.repeats))

    def tune_gemm(
        self,
        M: int, K: int, N: int,
        dataflow: str,
        *,
        include: Sequence[tuple[int, int, int]] = (),
        caps: Sequence[int] = GEMM_BLOCK_CAPS,
    ) -> tuple[int, int, int]:
        """Best measured ``(block_m, block_k, block_n)`` for one GEMM.

        Sweeps the feasible variant space (plus ``include`` — pass the
        compiler's heuristic tiling so the result can never lose to it),
        measuring cache misses; returns the argmin over the swept set,
        ties to the numerically smallest variant.
        """
        variants = gemm_variants(M, K, N, caps=caps, include=include)
        entry = self._gemm_entry(M, K, N, dataflow)
        measured = {
            v: self._measure_into(
                entry, variant_key(v),
                lambda v=v: self._measure_gemm(
                    M, K, N, dataflow, v, interpret=self.interpret,
                    warmup=self.warmup, repeats=self.repeats))
            for v in variants
        }
        return min(measured, key=lambda v: (measured[v], v))

    # -- streaming sweeps --------------------------------------------------
    def tune_streaming(
        self,
        tn: TensorNetwork,
        steps,
        tokens: int,
        *,
        include: Sequence[int] = (),
        budget_bytes: int = VMEM_BUDGET_BYTES,
        caps: Sequence[int] = STREAM_BLOCK_CAPS,
    ) -> Optional[int]:
        """Best measured ``block_tokens`` for one streaming-layer problem.

        ``tn`` is the full-batch layer network; each variant rebatches it
        to the candidate block and times the padded streaming call over
        ``tokens`` rows.  Returns ``None`` when the network does not fit
        the single-streamed-operand kernel layout (the caller keeps the
        heuristic tiling).
        """
        variants = streaming_variants(tn, steps, tokens, caps=caps,
                                      budget_bytes=budget_bytes,
                                      include=include)
        if not variants:
            return None
        key = self.streaming_key(tn, steps, tokens)
        entry = self.cache.ensure(
            key, kind="streaming", backend="streaming_tt",
            device_kind=self.device_kind, interpret=self.interpret,
            problem={"signature": network_signature(rebatch(tn, 1), steps),
                     "tokens": int(tokens)},
        )
        measured: dict[int, float] = {}
        for bt in variants:
            tn_block = rebatch(tn, bt)
            try:
                s = self._measure_into(
                    entry, variant_key((bt,)),
                    lambda: self._measure_streaming(
                        tn_block, steps, tokens, bt,
                        interpret=self.interpret,
                        warmup=self.warmup, repeats=self.repeats))
            except ValueError:
                # network layout unsupported by the streaming kernel
                # (e.g. trailing conv patch edge) — nothing to tune
                return None
            measured[bt] = s
        return min(measured, key=lambda bt: (measured[bt], bt))

    # -- fused-segment sweeps ----------------------------------------------
    def tune_fused(
        self,
        tn: TensorNetwork,
        steps,
        segments,
        tokens: int,
        *,
        include: Sequence[int] = (),
        budget_bytes: int = VMEM_BUDGET_BYTES,
        caps: Sequence[int] = STREAM_BLOCK_CAPS,
        block_k: int = 128,
    ) -> Optional[dict]:
        """Measured fused vs per-step seconds for one segmented layer.

        Sweeps the feasible ``block_tokens`` ladder of the fused chain
        runs (``variants.fused_token_variants`` — only blocks that
        reproduce exactly the priced segmentation), measures the
        spill-always per-step route once as the baseline, and returns
        ``{"block_tokens", "fused_s", "per_step_s"}`` (``None`` when no
        variant reproduces the segmentation).  Both routes land in the
        persistent cache, so ``--tune cache`` replays without measuring.
        """
        tn = rebatch(tn, tokens)
        steps = tuple(tuple(s) for s in steps)
        segments = tuple((int(s), int(e)) for s, e in segments)
        variants = fused_token_variants(
            tn, steps, segments, tokens, caps=caps,
            budget_bytes=budget_bytes, include=include)
        if not variants:
            return None
        sig = network_signature(rebatch(tn, 1), steps)
        entry = self.cache.ensure(
            self.fused_key(tn, steps, segments, tokens),
            kind="fused", backend="tt_gemm",
            device_kind=self.device_kind, interpret=self.interpret,
            problem={"signature": sig, "tokens": int(tokens),
                     "segments": [list(s) for s in segments]},
        )
        measured = {
            bt: self._measure_into(
                entry, variant_key((bt,)),
                lambda bt=bt: self._measure_fused(
                    tn, steps, segments, bt, block_k=block_k,
                    interpret=self.interpret,
                    warmup=self.warmup, repeats=self.repeats))
            for bt in variants
        }
        best = min(measured, key=lambda bt: (measured[bt], bt))
        base_entry = self.cache.ensure(
            f"fusedbase:{self.fused_key(tn, steps, segments, tokens)[6:]}",
            kind="fused_base", backend="tt_gemm",
            device_kind=self.device_kind, interpret=self.interpret,
            problem={"signature": sig, "tokens": int(tokens)},
        )
        per_step_s = self._measure_into(
            base_entry, variant_key((tokens,)),
            lambda: self._measure_per_step(
                tn, steps, interpret=self.interpret,
                warmup=self.warmup, repeats=self.repeats))
        return {"block_tokens": int(best), "fused_s": measured[best],
                "per_step_s": per_step_s}


# ---------------------------------------------------------------------------
# model-level work items + the DSE calibration table
# ---------------------------------------------------------------------------

def gemm_work_items(
    layer_paths: Sequence[Sequence],
    max_shapes: Optional[int] = None,
) -> list[tuple[int, int, int]]:
    """Unique dominant-GEMM shapes of a model's candidate paths.

    One work item per unique shape (the measurement dedup), ordered by
    the shape's own MAC volume descending (the heaviest GEMMs carry the
    calibration signal), optionally truncated to ``max_shapes``.
    """
    shapes = {dominant_gemm(p) for paths in layer_paths for p in paths}
    order = sorted(shapes, key=lambda s: (-(s[0] * s[1] * s[2]), s))
    return order[:max_shapes] if max_shapes is not None else order


def analytic_gemm_seconds(
    M: int, K: int, N: int, dataflow, hw: HardwareConfig
) -> float:
    """The closed-form model's prediction for one monolithic GEMM."""
    df = dataflow if isinstance(dataflow, Dataflow) else Dataflow(dataflow)
    cycles, _, _ = gemm_cost_model(M, K, N, df, hw.pe_rows, hw.pe_cols, hw)
    return float(cycles) / hw.freq_hz


def measured_calibration(
    shapes: Sequence[tuple[int, int, int]],
    tuner: Autotuner,
    hw: HardwareConfig,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
) -> dict[str, float]:
    """Per-dataflow measured/analytic scale factors over ``shapes``.

    Each shape is measured once per dataflow at the compiler's heuristic
    tiling (the operating point the analytic argmin would deploy), and
    the per-dataflow scale is the geometric mean of measured/analytic
    ratios — robust to the absolute-magnitude gap between the modeled
    accelerator and the measuring host, sensitive exactly to the
    *relative* per-dataflow disagreement that can flip an argmin.
    """
    if not shapes:
        raise ValueError("measured_calibration needs at least one shape")
    scales: dict[str, float] = {}
    for d in dataflows:
        logs = []
        for (M, K, N) in shapes:
            measured = tuner.gemm_seconds(
                M, K, N, d.value, heuristic_blocks(M, K, N))
            analytic = analytic_gemm_seconds(M, K, N, d, hw)
            if measured > 0 and analytic > 0:
                logs.append(math.log(measured / analytic))
        scales[d.value] = math.exp(sum(logs) / len(logs)) if logs else 1.0
    return scales
