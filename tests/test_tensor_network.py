"""Tensor-network representation invariants (unit + hypothesis)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    GemmShape,
    Node,
    TensorNetwork,
    dense_linear_network,
    factorize,
    tt_conv_network,
    tt_linear_network,
)


def test_node_validation():
    with pytest.raises(ValueError):
        Node("a", ("x", "y"), (2,))
    with pytest.raises(ValueError):
        Node("a", ("x", "x"), (2, 2))


def test_edge_dim_mismatch_rejected():
    with pytest.raises(ValueError):
        TensorNetwork([
            Node("a", ("s",), (3,)),
            Node("b", ("s",), (4,)),
        ])


def test_hyperedge_rejected():
    with pytest.raises(ValueError):
        TensorNetwork([
            Node("a", ("s",), (3,)),
            Node("b", ("s",), (3,)),
            Node("c", ("s",), (3,)),
        ])


def test_contract_pair_gemm_shape():
    tn = dense_linear_network(batch=8, n_in=16, n_out=32)
    # nodes: W (j,i), X (b,j)
    reduced, gemm = tn.contract_pair(0, 1)
    assert gemm.K == 16
    assert {gemm.M, gemm.N} == {32, 8}
    assert gemm.macs == 8 * 16 * 32
    assert len(reduced) == 1
    assert set(reduced.nodes[0].edges) == {"b", "i"}


def test_tt_linear_network_structure():
    tn = tt_linear_network(4, (2, 3), (5, 7), (4, 4, 4))
    assert len(tn) == 5  # 4 cores + X
    out = tn.output_dims()
    assert out["b"] == 4
    assert out["i1"] == 5 and out["i2"] == 7
    assert "j1" not in out  # input modes contracted


def test_tt_conv_network_structure():
    tn = tt_conv_network(10, (4, 4), (8, 8), 9, (4, 4, 4, 4))
    out = tn.output_dims()
    assert out["o1"] == 8 and out["o2"] == 8 and out["l"] == 10


def test_gemm_sequence_full_contraction():
    tn = tt_linear_network(4, (2, 2), (2, 2), (2, 2, 2))
    # chain order: contract adjacent cores then input
    path = [(0, 1), (0, 1), (0, 1), (0, 1)]
    gemms = tn.gemm_sequence(path)
    assert len(gemms) == 4
    assert all(g.macs > 0 for g in gemms)


def test_gemm_sequence_incomplete_path_raises():
    tn = tt_linear_network(4, (2, 2), (2, 2), (2, 2, 2))
    with pytest.raises(ValueError):
        tn.gemm_sequence([(0, 1)])


@given(st.integers(2, 10_000), st.integers(1, 4))
@settings(max_examples=200, deadline=None)
def test_factorize_properties(n, d):
    f = factorize(n, d)
    assert len(f) == d
    assert math.prod(f) == n
    assert list(f) == sorted(f, reverse=True)


@given(
    st.integers(1, 8),          # batch
    st.lists(st.integers(2, 5), min_size=1, max_size=3),
    st.lists(st.integers(2, 5), min_size=1, max_size=3),
    st.integers(1, 6),          # rank
)
@settings(max_examples=50, deadline=None)
def test_tt_network_output_dims_invariant(batch, in_modes, out_modes, rank):
    ranks = (rank,) * (len(in_modes) + len(out_modes) - 1)
    tn = tt_linear_network(batch, tuple(in_modes), tuple(out_modes), ranks)
    out = tn.output_dims()
    assert math.prod(d for e, d in out.items() if e != "b") == math.prod(out_modes)


def test_state_key_order_independent():
    # nodes: [G1, G2, G3, G4, X]; do {G1*G2, G4*X} in both orders
    tn = tt_linear_network(4, (2, 2), (2, 2), (2, 2, 2))
    a, _ = tn.contract_pair(0, 1)    # -> [G3, G4, X, G1G2]
    a2, _ = a.contract_pair(1, 2)    # G4 * X
    b, _ = tn.contract_pair(3, 4)    # -> [G1, G2, G3, G4X]
    b2, _ = b.contract_pair(0, 1)    # G1 * G2
    assert a2.state_key() == b2.state_key()
