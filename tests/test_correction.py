"""Learned cost-correction: fit from synthetic caches with a *known*
per-bucket ground truth, recovery within tolerance, and the fallback
chain (bucket -> per-dataflow geomean -> 1.0) on sparse buckets.

The fixtures plant measurements at exactly ``analytic * truth(bucket,
dataflow)`` so the fitted geomeans are exact up to float rounding; noise
variants check the geomean actually averages.  ``apply_calibration``
dispatch for the model object (vs the flat mapping) is covered here
too, including the layer_paths requirement and the positive-scale
check.
"""

import math

import pytest

from repro.core import find_topk_paths, tt_linear_network
from repro.core.cost_table import build_cost_table_vectorized
from repro.core.dse import apply_calibration
from repro.core.simulator import ALL_DATAFLOWS, ALL_PARTITIONINGS
from repro.hw import FPGA_VU9P
from repro.tune import (
    CostCorrection,
    MIN_BUCKET_SAMPLES,
    SHAPE_BUCKET_LOG2_WIDTH,
    TuningCache,
    TuningEntry,
    analytic_gemm_seconds,
    fit_cost_correction,
    heuristic_blocks,
    shape_bucket,
    variant_key,
)
from repro.tune.variants import dominant_gemm


def _gemm_entry(M, K, N, dataflow, scale, device_kind="cpu",
                interpret=True, at_heuristic=True, hw=FPGA_VU9P):
    """Synthetic cache entry measuring ``analytic * scale`` seconds."""
    blocks = heuristic_blocks(M, K, N) if at_heuristic else (1, 1, 1)
    key = f"gemm:{M}x{K}x{N}:{dataflow}:{device_kind}:i:ktest"
    return TuningEntry(
        key=key, kind="gemm", backend="tt_gemm",
        device_kind=device_kind, interpret=interpret,
        problem={"M": M, "K": K, "N": N, "dataflow": dataflow},
        measured_s={variant_key(blocks):
                    analytic_gemm_seconds(M, K, N, dataflow, hw) * scale},
    )


def _cache(entries):
    return TuningCache({e.key: e for e in entries})


# ---------------------------------------------------------------------------
# shape_bucket
# ---------------------------------------------------------------------------

def test_shape_bucket_quantizes_log2_volume():
    assert shape_bucket(2, 2, 1) == 1        # log2(4)=2 -> bucket 1
    assert shape_bucket(4, 4, 4) == 3        # log2(64)=6 -> bucket 3
    # volumes within one 4x band share a bucket (2^4 and 2^5)
    assert shape_bucket(4, 2, 2) == shape_bucket(8, 2, 2)
    # a 4x volume step moves exactly one bucket
    b = shape_bucket(64, 64, 64)
    assert shape_bucket(256, 64, 64) == b + 1
    with pytest.raises(ValueError, match="positive"):
        shape_bucket(0, 4, 4)


# ---------------------------------------------------------------------------
# fit: exact recovery of a known per-bucket correction
# ---------------------------------------------------------------------------

def test_fit_recovers_known_bucket_scales():
    # two shapes per (bucket, dataflow) so every bucket clears
    # MIN_BUCKET_SAMPLES; truth differs by bucket AND dataflow
    small = [(16, 16, 16), (32, 16, 16)]     # bucket 6
    large = [(256, 256, 256), (512, 256, 256)]
    truth = {(shape_bucket(*small[0]), "IS"): 3.0,
             (shape_bucket(*small[0]), "OS"): 1.5,
             (shape_bucket(*large[0]), "IS"): 0.25,
             (shape_bucket(*large[0]), "OS"): 8.0}
    entries = []
    for shapes in (small, large):
        for (M, K, N) in shapes:
            for d in ("IS", "OS"):
                entries.append(_gemm_entry(
                    M, K, N, d, truth[(shape_bucket(M, K, N), d)]))
    model = fit_cost_correction(_cache(entries), FPGA_VU9P)
    for (b, d), s in truth.items():
        assert model.bucket_scales[(b, d)] == pytest.approx(s, rel=1e-12)
        assert model.bucket_samples[(b, d)] == 2
    # scale() routes through the bucket, not the flat fallback
    assert model.scale(16, 16, 16, "IS") == pytest.approx(3.0)
    assert model.scale(256, 256, 256, "IS") == pytest.approx(0.25)
    assert model.n_ratios == 8


def test_fit_geomean_averages_noisy_ratios():
    # same bucket, ratios 2 and 8 -> geomean 4 (not arithmetic mean 5)
    entries = [_gemm_entry(16, 16, 16, "WS", 2.0),
               _gemm_entry(32, 16, 16, "WS", 8.0)]
    model = fit_cost_correction(_cache(entries), FPGA_VU9P)
    b = shape_bucket(16, 16, 16)
    assert model.bucket_scales[(b, "WS")] == pytest.approx(4.0, rel=1e-12)
    assert model.dataflow_scales["WS"] == pytest.approx(4.0, rel=1e-12)


# ---------------------------------------------------------------------------
# fallback chain on sparse buckets
# ---------------------------------------------------------------------------

def test_sparse_bucket_falls_back_to_dataflow_geomean():
    # bucket A: 2 samples (trusted); bucket B: 1 sample (sparse)
    entries = [_gemm_entry(16, 16, 16, "IS", 2.0),
               _gemm_entry(32, 16, 16, "IS", 2.0),
               _gemm_entry(512, 512, 512, "IS", 32.0)]
    model = fit_cost_correction(_cache(entries), FPGA_VU9P)
    b_dense = shape_bucket(16, 16, 16)
    b_sparse = shape_bucket(512, 512, 512)
    assert (b_dense, "IS") in model.bucket_scales
    assert (b_sparse, "IS") not in model.bucket_scales      # below min_samples
    assert model.bucket_samples[(b_sparse, "IS")] == 1      # but counted
    # sparse bucket's scale() = the per-dataflow geomean over ALL ratios
    geo = math.exp((math.log(2.0) + math.log(2.0) + math.log(32.0)) / 3)
    assert model.scale(512, 512, 512, "IS") == pytest.approx(geo, rel=1e-12)
    # unmeasured dataflow -> identity
    assert model.scale(512, 512, 512, "OS") == 1.0


def test_unmeasured_model_is_identity():
    model = fit_cost_correction(_cache([]), FPGA_VU9P)
    assert model.scale(64, 64, 64, "IS") == 1.0
    assert model.n_ratios == 0
    assert model.bucket_scales == {}


def test_min_samples_threshold_is_tunable():
    entries = [_gemm_entry(512, 512, 512, "IS", 32.0)]
    trusting = fit_cost_correction(_cache(entries), FPGA_VU9P, min_samples=1)
    b = shape_bucket(512, 512, 512)
    assert trusting.bucket_scales[(b, "IS")] == pytest.approx(32.0)
    assert trusting.min_samples == 1
    assert MIN_BUCKET_SAMPLES == 2  # the documented default stays strict


# ---------------------------------------------------------------------------
# fit filters: device, interpret, shape set, operating point
# ---------------------------------------------------------------------------

def test_fit_filters_device_interpret_and_shapes():
    keep = _gemm_entry(16, 16, 16, "IS", 2.0)
    wrong_dev = _gemm_entry(32, 16, 16, "IS", 100.0, device_kind="tpu")
    wrong_interp = _gemm_entry(16, 32, 16, "IS", 100.0, interpret=False)
    entries = [keep, wrong_dev, wrong_interp]
    model = fit_cost_correction(_cache(entries), FPGA_VU9P,
                                device_kind="cpu", interpret=True)
    assert model.n_ratios == 1
    assert model.dataflow_scales["IS"] == pytest.approx(2.0)
    # shape pinning: an extra measured shape outside the set is invisible
    extra = _gemm_entry(64, 64, 64, "IS", 100.0)
    pinned = fit_cost_correction(_cache([keep, extra]), FPGA_VU9P,
                                 shapes=[(16, 16, 16)])
    assert pinned.n_ratios == 1
    assert pinned.dataflow_scales["IS"] == pytest.approx(2.0)


def test_fit_reads_only_the_heuristic_blocks_variant():
    """Sweep-only variants (e.g. from a measured-tilings compile) must
    not perturb the fit — warm-cache re-emission stays bit-identical."""
    clean = _gemm_entry(16, 16, 16, "IS", 2.0)
    sweep_only = _gemm_entry(32, 16, 16, "IS", 100.0, at_heuristic=False)
    model = fit_cost_correction(_cache([clean, sweep_only]), FPGA_VU9P)
    assert model.n_ratios == 1
    assert model.dataflow_scales["IS"] == pytest.approx(2.0)


def test_describe_is_json_friendly_summary():
    entries = [_gemm_entry(16, 16, 16, "IS", 2.0),
               _gemm_entry(32, 16, 16, "IS", 2.0)]
    model = fit_cost_correction(_cache(entries), FPGA_VU9P,
                                device_kind="cpu", interpret=True)
    d = model.describe()
    assert d["model"] == "shape-bucket-geomean"
    assert d["bucket_log2_width"] == SHAPE_BUCKET_LOG2_WIDTH
    assert d["n_ratios"] == 2
    assert d["device_kind"] == "cpu"
    b = shape_bucket(16, 16, 16)
    assert d["bucket_scales"][f"b{b}:IS"] == pytest.approx(2.0)
    import json
    json.dumps(d)  # must serialize as-is into the DSE report


# ---------------------------------------------------------------------------
# apply_calibration dispatch for the model object
# ---------------------------------------------------------------------------

def _layer_paths():
    return [
        find_topk_paths(tt_linear_network(64, (2, 8), (8, 2), (4, 4, 4)), k=3),
        find_topk_paths(tt_linear_network(4, (4, 4), (4, 4), (4, 4, 4)), k=2),
    ]


def test_apply_calibration_with_model_scales_by_dominant_gemm():
    layer_paths = _layer_paths()
    table = build_cost_table_vectorized(layer_paths, FPGA_VU9P,
                                        ALL_PARTITIONINGS)
    model = CostCorrection(bucket_scales={}, dataflow_scales={"IS": 2.0},
                           bucket_samples={})
    scaled = apply_calibration(table, model, layer_paths=layer_paths)
    for (l, p, c, d), v in table.items():
        factor = 2.0 if getattr(d, "value", d) == "IS" else 1.0
        assert scaled[(l, p, c, d)] == pytest.approx(factor * v)


def test_apply_calibration_model_uses_shape_buckets():
    layer_paths = _layer_paths()
    table = build_cost_table_vectorized(layer_paths, FPGA_VU9P,
                                        ALL_PARTITIONINGS)
    # put every dominant GEMM's bucket in the model with a known scale
    buckets = {}
    for l, paths in enumerate(layer_paths):
        for p, path in enumerate(paths):
            M, K, N = dominant_gemm(path)
            for d in ALL_DATAFLOWS:
                buckets[(shape_bucket(M, K, N), d.value)] = 5.0
    model = CostCorrection(bucket_scales=buckets,
                           dataflow_scales={}, bucket_samples={})
    scaled = apply_calibration(table, model, layer_paths=layer_paths)
    for k, v in table.items():
        assert scaled[k] == pytest.approx(5.0 * v)


def test_apply_calibration_model_requires_layer_paths():
    layer_paths = _layer_paths()
    table = build_cost_table_vectorized(layer_paths, FPGA_VU9P,
                                        ALL_PARTITIONINGS)
    model = CostCorrection(bucket_scales={}, dataflow_scales={},
                           bucket_samples={})
    with pytest.raises(ValueError, match="layer_paths"):
        apply_calibration(table, model)


def test_apply_calibration_model_rejects_nonpositive_scale():
    layer_paths = _layer_paths()
    table = build_cost_table_vectorized(layer_paths, FPGA_VU9P,
                                        ALL_PARTITIONINGS)
    model = CostCorrection(bucket_scales={}, dataflow_scales={"IS": -1.0},
                           bucket_samples={})
    with pytest.raises(ValueError, match="positive"):
        apply_calibration(table, model, layer_paths=layer_paths)
