"""TPU-v5e re-parameterization of the systolic latency model.

The paper's cost oracle is an FPGA systolic simulator; our deployment
target is TPU v5e.  The MXU *is* a 128x128 systolic array, so the same
closed-form model applies with TPU constants:

  * peak 197 TFLOP/s bf16 per chip  ->  98.5e12 MAC/s
  * on a 128x128 array that is an effective 6.01 GHz MAC issue rate
    (the real chip reaches it with multiple MXU passes per clock; the
    effective-frequency abstraction preserves the peak roofline)
  * HBM 819 GB/s  ->  819e9 / 2 B (bf16) / 6.01e9 Hz ~= 68 words/cycle
  * VMEM ~128 MiB split ~3:1 between operand and output buffering,
    mirroring the paper's 3072/1024 KiB SRAM split.

Dataflows map onto Pallas grid iteration orders (see
``repro.kernels.tt_gemm``): the stationary operand is the block that stays
VMEM-resident across consecutive grid steps.  The traffic asymmetry between
IS/OS/WS is therefore identical in kind to the FPGA model — only the
constants change.
"""

from __future__ import annotations

from .simulator import HardwareConfig

_PEAK_FLOPS_BF16 = 197e12
_MXU = 128
_EFF_FREQ = (_PEAK_FLOPS_BF16 / 2.0) / (_MXU * _MXU)  # ~6.01e9
_HBM_BYTES_PER_S = 819e9
_BYTES_PER_WORD = 2  # bf16

TPU_V5E = HardwareConfig(
    name="tpu_v5e",
    pe_rows=_MXU,
    pe_cols=_MXU,
    freq_hz=_EFF_FREQ,
    sram_input_bytes=96 * 1024 * 1024,
    sram_output_bytes=32 * 1024 * 1024,
    dram_words_per_cycle=_HBM_BYTES_PER_S / _BYTES_PER_WORD / _EFF_FREQ,
    bytes_per_word=_BYTES_PER_WORD,
    gemm_overhead_cycles=256,  # kernel-dispatch / pipeline-warmup constant
)

#: interconnect constants used by the roofline analysis (per chip)
ICI_BYTES_PER_S_PER_LINK = 50e9
HBM_BYTES_PER_S = _HBM_BYTES_PER_S
PEAK_FLOPS_BF16 = _PEAK_FLOPS_BF16
VMEM_BYTES = 128 * 1024 * 1024
HBM_CAPACITY_BYTES = 16 * 1024**3
