"""Request / trace model for the serving layer.

A :class:`Request` is one generation job: a prompt (token ids), a
generation budget, and an *arrival time* measured in engine decode steps
(the scheduler's virtual clock — deterministic, replayable, independent
of wall-clock jitter).  Traces are plain JSON lists so CI jobs and
benchmarks can pin workloads; :func:`synthetic_trace` draws a
deterministic sustained-load trace from a seed.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Union

import numpy as np

#: int = fixed value; (lo, hi) = inclusive uniform range per request
Span = Union[int, tuple[int, int]]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``arrival`` is in scheduler *steps* (virtual time): the request
    becomes admissible once the engine's step clock reaches it.
    ``max_new_tokens`` counts the prefill's first token, so a value of 1
    completes at admission without any decode step.
    """

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival: float = 0.0

    def __post_init__(self) -> None:
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1 "
                f"(got {self.max_new_tokens})")
        if self.arrival < 0:
            raise ValueError(f"request {self.rid}: negative arrival time")


@dataclasses.dataclass(frozen=True)
class Completion:
    """A served request: generated ids + step/wall-clock provenance.

    ``tokens`` are the generated ids (length ``max_new_tokens``);
    ``admitted_step``/``done_step`` are virtual-clock stamps (replay-
    deterministic), the ``t_*`` fields are ``time.perf_counter`` stamps
    (``t_ready`` = entered the ready queue, ``t_first`` = first token,
    ``t_done`` = last token).
    """

    rid: int
    prompt_len: int
    tokens: tuple[int, ...]
    arrival: float
    admitted_step: int
    done_step: int
    t_ready: float
    t_first: float
    t_done: float

    @property
    def replay_key(self) -> tuple:
        """The deterministic part (everything but wall-clock stamps)."""
        return (self.rid, self.prompt_len, self.tokens, self.arrival,
                self.admitted_step, self.done_step)


def _draw(rng: np.random.Generator, span: Span) -> int:
    if isinstance(span, int):
        return span
    lo, hi = span
    return int(rng.integers(lo, hi + 1))


def synthetic_trace(
    n_requests: int,
    vocab: int,
    *,
    prompt_len: Span = 32,
    gen: Span = 8,
    arrival_rate: float = 0.0,
    seed: int = 0,
) -> list[Request]:
    """Deterministic sustained-load trace.

    ``arrival_rate`` is the mean inter-arrival gap in decode steps
    (exponential gaps; 0 = every request arrives at t=0 — the full-queue
    burst).  ``prompt_len``/``gen`` accept a fixed int or an inclusive
    ``(lo, hi)`` range drawn per request.
    """
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    t = 0.0
    for i in range(n_requests):
        p = _draw(rng, prompt_len)
        g = _draw(rng, gen)
        prompt = tuple(int(x) for x in rng.integers(0, vocab, size=p))
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=g, arrival=t))
        if arrival_rate > 0:
            t += float(rng.exponential(arrival_rate))
    return out


def save_trace(path: str, requests: Sequence[Request]) -> None:
    """Write a trace JSON (prompts inlined — fully self-contained)."""
    rows = [
        {"arrival": r.arrival, "prompt": list(r.prompt),
         "gen": r.max_new_tokens}
        for r in requests
    ]
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
        f.write("\n")


def load_trace(path: str, vocab: int, *, seed: int = 0) -> list[Request]:
    """Load a request-trace JSON.

    Each row: ``{"arrival": float, "gen": int, "prompt": [ids...]}`` or
    ``{"arrival": ..., "gen": ..., "prompt_len": int}`` — when only the
    length is given, token ids are drawn deterministically from
    ``(seed, row index)`` so a length-only trace still replays
    bit-identically.
    """
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise ValueError(f"{path}: trace must be a JSON list of requests")
    out: list[Request] = []
    for i, row in enumerate(rows):
        prompt: Optional[Sequence[int]] = row.get("prompt")
        if prompt is None:
            p = int(row["prompt_len"])
            rng = np.random.default_rng((seed, i))
            prompt = [int(x) for x in rng.integers(0, vocab, size=p)]
        out.append(Request(
            rid=i,
            prompt=tuple(int(t) for t in prompt),
            max_new_tokens=int(row.get("gen", 8)),
            arrival=float(row.get("arrival", 0.0)),
        ))
    return out
