"""Execution-plan subsystem: search -> compile -> install -> execute.

``python -m repro.dse --emit-plan plan.json`` compiles the DSE result
into an :class:`ExecutionPlan`; ``repro.nn.install_plan(load_plan(path))``
installs it; the TT projections then contract along the planned path
through the planned kernel backend.  Format spec: ``docs/plan_format.md``.
"""

from .schema import (
    BACKENDS,
    PHASES,
    PLAN_FORMAT_VERSION,
    SUPPORTED_VERSIONS,
    TILING_MODES,
    BackwardOp,
    ExecutionPlan,
    Factorization,
    LayerPlan,
    PlanSharding,
    Tiling,
    load_plan,
    migrate_plan_json,
)
from .compiler import (
    base_name,
    batch_dim,
    check_plan_for_config,
    choose_segments,
    compile_plan,
    streaming_fits,
    validate_plan,
)
from .executor import (
    as_candidate_path,
    execution_log,
    execution_log_dropped,
    execution_stream,
    planned_tt_linear,
    record_execution,
    reset_execution_log,
    shard_execution,
)
from .sharded import ShardDecision, shard_decision, sharded_tt_linear

__all__ = [
    "BACKENDS", "PHASES", "PLAN_FORMAT_VERSION", "SUPPORTED_VERSIONS",
    "TILING_MODES",
    "BackwardOp",
    "ExecutionPlan", "Factorization", "LayerPlan", "PlanSharding",
    "Tiling", "load_plan", "migrate_plan_json",
    "base_name", "batch_dim", "check_plan_for_config", "choose_segments",
    "compile_plan", "streaming_fits", "validate_plan",
    "as_candidate_path", "execution_log", "execution_log_dropped",
    "execution_stream",
    "planned_tt_linear", "record_execution", "reset_execution_log",
    "shard_execution",
    "ShardDecision", "shard_decision", "sharded_tt_linear",
]
