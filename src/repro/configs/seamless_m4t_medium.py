"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

Assigned dims: 12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf].  12 encoder + 12 decoder layers; the audio
frontend is a STUB — ``input_specs()`` supplies precomputed frame
embeddings (B, S_enc, D).

vocab 256206 is not divisible by the 16-way model axis, so logits cannot
vocab-shard; ``loss_chunk`` bounds the train-time logits buffer instead
(fused head + cross-entropy over sequence chunks).
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    head_dim=64,
    mlp_kind="gelu",
    frontend="frames",
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="seamless-smoke",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=254,        # deliberately odd-sized: exercises the chunked loss
    head_dim=16,
    loss_chunk=8,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
