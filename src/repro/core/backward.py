"""Backward-pass contraction networks for tensorized layers (training DSE).

The forward pass of a TT layer is one tensor network; its backward pass is
a *family* of tensor networks, one per gradient (FETTA, arXiv 2504.06474):

  * ``dL/dX``   — replace the input node by the output gradient ``dY``
    (which carries the forward network's free edges) and contract against
    the unchanged weight cores.  The free edges of this network are
    exactly the input node's edges, so the result has dX's shape.
  * ``dL/dG_k`` — remove core ``G_k`` and add ``dY``; the batch edges are
    now shared between ``X`` and ``dY`` (the sum over the batch that
    weight gradients perform), and the free edges are exactly ``G_k``'s
    edges.

Each backward network has its own candidate contraction paths and its own
latency-optimal dataflow/path — generally *different* from the forward's
(the asymmetry the training DSE exploits).  No activation stashing is
modelled: gradients contract directly from ``X``, ``dY`` and the cores,
which is both how the executor computes them (``repro.plan.executor``)
and what keeps the cost model path-independent of the forward choice.

The grad-update term models the optimizer's elementwise parameter update
as a DRAM-bound streaming pass over the parameter state.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .paths import CandidatePath, find_topk_paths
from .simulator import HardwareConfig
from .tensor_network import Node, TensorNetwork

#: name of the output-gradient node injected into backward networks
GRAD_NODE = "dY"

#: DRAM words moved per parameter by one AdamW-style update step:
#: read {param, grad, m, v}, write {param, m, v} — 7 words, +1 headroom
#: for the scheduler/padding slop of a real streaming update kernel.
UPDATE_WORDS_PER_PARAM = 8.0


def _input_node(tn: TensorNetwork) -> Node:
    inputs = [n for n in tn.nodes if n.kind == "input"]
    if len(inputs) != 1:
        raise ValueError(
            f"backward derivation needs exactly one input node, found "
            f"{[n.name for n in inputs]}")
    return inputs[0]


def _grad_output_node(tn: TensorNetwork) -> Node:
    """The ``dY`` node: carries the forward network's free edges.

    Edge order is batch edges (the input node's free edges) first, then
    the weight-core free edges in node order — matching the row-major
    layout of the forward output ``(tokens, d_out)``.
    """
    x = _input_node(tn)
    free = set(tn.free_edges)
    batch = [(e, d) for e, d in zip(x.edges, x.dims) if e in free]
    out = [
        (e, d)
        for n in tn.nodes if n.kind != "input"
        for e, d in zip(n.edges, n.dims) if e in free
    ]
    edges = tuple(e for e, _ in batch + out)
    dims = tuple(d for _, d in batch + out)
    return Node(GRAD_NODE, edges, dims, kind="input")


def grad_input_network(tn: TensorNetwork) -> TensorNetwork:
    """The ``dL/dX`` network: weight cores + ``dY``.

    Free edges are exactly the forward input node's edges, so contracting
    this network yields a tensor of dX's shape.
    """
    cores = [n for n in tn.nodes if n.kind != "input"]
    return TensorNetwork(cores + [_grad_output_node(tn)])


def grad_core_network(tn: TensorNetwork, core_name: str) -> TensorNetwork:
    """The ``dL/dG_k`` network: all nodes except ``G_k``, plus ``dY``.

    The batch edges become shared (``X``–``dY``) — the weight gradient's
    sum over the batch — and the free edges are exactly ``G_k``'s edges.
    """
    keep = [n for n in tn.nodes if n.name != core_name]
    if len(keep) == len(tn.nodes):
        raise ValueError(f"no node named {core_name!r} in {tn!r}")
    return TensorNetwork(keep + [_grad_output_node(tn)])


def backward_networks(tn: TensorNetwork) -> list[tuple[str, TensorNetwork]]:
    """All backward problems of a layer: ``[("dx", net), (core_name, net)...]``.

    ``"dx"`` is the activation gradient (the only one that propagates to
    the previous layer); the remaining entries are the per-core weight
    gradients, keyed by the forward network's node names.
    """
    out: list[tuple[str, TensorNetwork]] = [("dx", grad_input_network(tn))]
    for n in tn.nodes:
        if n.kind != "input":
            out.append((n.name, grad_core_network(tn, n.name)))
    return out


# ---------------------------------------------------------------------------
# per-layer backward DSE problem
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackwardProblem:
    """One gradient's contraction problem with its candidate paths."""

    wrt: str                                  # "dx" | core node name
    network: TensorNetwork
    paths: tuple[CandidatePath, ...]


@dataclasses.dataclass(frozen=True)
class LayerBackward:
    """All backward problems of one layer + its update-cost parameters."""

    problems: tuple[BackwardProblem, ...]
    n_params: int                             # total weight-core elements

    @property
    def dx(self) -> BackwardProblem:
        return self.problems[0]


def layer_backward(tn: TensorNetwork, k: int = 4) -> LayerBackward:
    """Derive a layer's full backward DSE problem (top-``k`` paths each)."""
    problems = tuple(
        BackwardProblem(wrt, net, tuple(find_topk_paths(net, k=k)))
        for wrt, net in backward_networks(tn)
    )
    n_params = sum(n.size for n in tn.nodes if n.kind != "input")
    return LayerBackward(problems, n_params)


def update_seconds(n_params: int, hw: HardwareConfig,
                   words_per_param: float = UPDATE_WORDS_PER_PARAM) -> float:
    """Optimizer-update latency: a DRAM-bound elementwise streaming pass."""
    cycles = n_params * words_per_param / hw.dram_words_per_cycle
    return cycles / hw.freq_hz


@dataclasses.dataclass(frozen=True)
class TrainCostWeights:
    """Relative weights of the train-latency decomposition (paper's
    ``T_train = w_f * T_fwd + w_b * T_bwd + w_u * T_update``).

    Defaults weight all three at 1 (one fwd + one bwd + one update per
    step); gradient-accumulation or multi-micro-batch schedules rescale.
    """

    fwd: float = 1.0
    bwd: float = 1.0
    update: float = 1.0


def memoised_layer_backwards(
    networks: Sequence[TensorNetwork], k: int = 4
) -> list[LayerBackward]:
    """``layer_backward`` over a model, deduping identical layer networks
    (transformer stacks repeat the same projection geometry L times)."""
    memo: dict[tuple, LayerBackward] = {}
    out = []
    for tn in networks:
        key = tuple((n.edges, n.dims, n.kind) for n in tn.nodes)
        if key not in memo:
            memo[key] = layer_backward(tn, k=k)
        out.append(memo[key])
    return out
