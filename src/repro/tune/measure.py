"""Wall-clock measurement harness for kernel variants.

The analytic simulator predicts; this module *measures*.  Each variant
is timed on the machine actually running the kernels: one untimed
warmup call (absorbs jit compilation), then ``repeats`` timed calls with
``block_until_ready`` inside the timed region, keeping the median —
robust to the one-off scheduler hiccup that poisons a mean or a min.

Inputs are synthesized deterministically (fixed NumPy seed per shape),
so measured numerics never depend on model parameters and two tuning
runs of the same shape time the same arithmetic.

On non-TPU hosts the kernels run in ``interpret=True`` mode: the timings
then rank Python-level kernel-body evaluation (grid-step count dominates)
rather than TPU performance — which is exactly what the serving path on
that host executes, so the argmin is still the right tiling *for the
machine serving traffic*.  The cache keys every measurement by device
kind and interpret flag (``repro.tune.cache``) so the two regimes never
mix.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tensor_network import TensorNetwork
from repro.kernels import ops
from repro.plan.executor import as_candidate_path

#: defaults for the median-of-k protocol
WARMUP = 1
REPEATS = 5


def device_kind() -> str:
    """A cache-key-safe identity of the device measurements run on."""
    d = jax.devices()[0]
    return str(getattr(d, "device_kind", d.platform)).replace(" ", "_")


def default_interpret() -> bool:
    """Whether kernels on this host run in interpret mode (non-TPU)."""
    return jax.default_backend() != "tpu"


def measure_callable(
    fn: Callable[[], jax.Array],
    *,
    warmup: int = WARMUP,
    repeats: int = REPEATS,
) -> float:
    """Median wall-clock seconds of ``fn`` (which must block on its result)."""
    for _ in range(max(1, warmup)):
        fn()
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(statistics.median(times))


def _seed_for(*dims: int) -> int:
    return abs(hash(tuple(int(d) for d in dims))) % (2**31)


def measure_gemm(
    M: int, K: int, N: int,
    dataflow: str,
    blocks: tuple[int, int, int],
    *,
    interpret: bool | None = None,
    warmup: int = WARMUP,
    repeats: int = REPEATS,
) -> float:
    """Median seconds of one ``ops.gemm`` call at the given tiling."""
    rng = np.random.default_rng(_seed_for(M, K, N))
    a = jnp.asarray(rng.standard_normal((M, K), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((K, N), dtype=np.float32))
    bm, bk, bn = blocks

    def run():
        return ops.gemm(a, b, dataflow=dataflow, block_m=bm, block_k=bk,
                        block_n=bn, interpret=interpret).block_until_ready()

    return measure_callable(run, warmup=warmup, repeats=repeats)


def synthesize_tensors(tn: TensorNetwork) -> tuple[jax.Array, list[jax.Array]]:
    """Deterministic (streamed operand, cores) for a layer network.

    The streamed node (``kind == "input"``) becomes a 2-d
    ``(batch, prod(inner modes))`` operand; every other node becomes a
    random core of its literal dims, in node order — the operand layout
    ``ops.tt_linear`` / the streaming kernel expect.
    """
    stream = next(n for n in tn.nodes if n.kind == "input")
    rng = np.random.default_rng(_seed_for(*stream.dims))
    inner = 1
    for d in stream.dims[1:]:
        inner *= d
    x = jnp.asarray(
        rng.standard_normal((stream.dims[0], inner), dtype=np.float32))
    cores = [
        jnp.asarray(rng.standard_normal(n.dims, dtype=np.float32))
        for n in tn.nodes if n.kind != "input"
    ]
    return x, cores


def synthesize_network_tensors(tn: TensorNetwork) -> dict[str, jax.Array]:
    """Deterministic full-dims tensors for every node of a network."""
    return {
        n.name: jnp.asarray(
            np.random.default_rng(_seed_for(*n.dims))
            .standard_normal(n.dims, dtype=np.float32))
        for n in tn.nodes
    }


def measure_fused(
    tn: TensorNetwork,
    steps: Sequence[tuple[int, int]],
    segments: Sequence[tuple[int, int]],
    block_tokens: int,
    *,
    block_k: int = 128,
    interpret: bool | None = None,
    warmup: int = WARMUP,
    repeats: int = REPEATS,
) -> float:
    """Median seconds of the fused-segment route over one layer network.

    ``tn`` is the layer network at the streamed token count; multi-step
    segments run through ``ops.fused_segment`` (one ``pallas_call``,
    fp32 VMEM intermediates), singletons through the per-step Pallas
    GEMM — the same walk ``plan/executor._execute_segmented`` performs,
    minus the provenance records.
    """
    steps = tuple(tuple(s) for s in steps)
    segments = tuple((int(s), int(e)) for s, e in segments)
    tensors = synthesize_network_tensors(tn)
    contract = ops.gemm_contract(interpret=interpret)

    @jax.jit
    def apply(ts):
        work: list = [(n.edges, ts[n.name]) for n in tn.nodes]
        for s, e in segments:
            if e - s >= 2:
                ec, val = ops.fused_segment(
                    work, steps[s:e], block_tokens=block_tokens,
                    block_k=block_k, interpret=interpret)
                for i, j in steps[s:e]:
                    work = [w for k, w in enumerate(work) if k not in (i, j)]
                    work.append(None)
                work[-1] = (ec, val)
            else:
                i, j = steps[s]
                (ea, ta), (eb, tb) = work[i], work[j]
                shared = [x for x in ea if x in eb]
                val = contract(ta, tb,
                               (tuple(ea.index(x) for x in shared),
                                tuple(eb.index(x) for x in shared)))
                ec = tuple(x for x in ea if x not in shared) + tuple(
                    x for x in eb if x not in shared)
                work = [w for k, w in enumerate(work) if k not in (i, j)]
                work.append((ec, val))
        return work[-1][1]

    def run():
        return apply(tensors).block_until_ready()

    return measure_callable(run, warmup=warmup, repeats=repeats)


def measure_per_step(
    tn: TensorNetwork,
    steps: Sequence[tuple[int, int]],
    *,
    interpret: bool | None = None,
    warmup: int = WARMUP,
    repeats: int = REPEATS,
) -> float:
    """Median seconds of the spill-always per-step route (one Pallas GEMM
    launch per path step) — the baseline the fused variant is judged
    against, over the same synthesized tensors."""
    from repro.core.contraction import execute_path

    steps = tuple(tuple(s) for s in steps)
    tensors = synthesize_network_tensors(tn)
    contract = ops.gemm_contract(interpret=interpret)

    @jax.jit
    def apply(ts):
        return execute_path(tn, steps, ts, contract_fn=contract)

    def run():
        return apply(tensors).block_until_ready()

    return measure_callable(run, warmup=warmup, repeats=repeats)


def measure_streaming(
    tn_block: TensorNetwork,
    steps: Sequence[tuple[int, int]],
    tokens: int,
    block_tokens: int,
    *,
    interpret: bool | None = None,
    warmup: int = WARMUP,
    repeats: int = REPEATS,
) -> float:
    """Median seconds of one streaming TT call at ``block_tokens``.

    ``tn_block`` must be the layer network rebatched to ``block_tokens``
    (the per-block network the kernel contracts); ``tokens`` streamed
    rows are synthesized and padded by the ``ops`` wrapper as at serve
    time.
    """
    path = as_candidate_path(tn_block, steps)
    x_full, cores = synthesize_tensors(tn_block)
    inner = x_full.shape[1]
    rng = np.random.default_rng(_seed_for(tokens, inner))
    x = jnp.asarray(rng.standard_normal((tokens, inner), dtype=np.float32))

    # jit the whole padded call, as the serve/train steps do — the timed
    # region is kernel execution, not per-call tracing
    @jax.jit
    def apply(xv, cs):
        return ops.tt_linear(xv, list(cs), tn_block, path,
                             block_tokens=block_tokens, interpret=interpret)

    def run():
        return apply(x, tuple(cores)).block_until_ready()

    return measure_callable(run, warmup=warmup, repeats=repeats)
