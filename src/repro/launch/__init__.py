"""Launchers: production mesh, jitted steps, dry-run, train/serve drivers."""
