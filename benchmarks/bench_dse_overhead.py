"""DSE search overhead — the paper claims "minimal overhead" for the
hierarchical search vs brute force.  Times the three stages (top-K path
search, cost-table fill, global argmin) per model and the brute-force
alternative's combinatorial size.
"""

from __future__ import annotations

import time

from repro.core import (
    ALL_DATAFLOWS,
    FPGA_VU9P,
    STRATEGY_SPACE,
    find_topk_paths,
    global_search,
)
from repro.models.vision import model_layers
from .common import emit


def run() -> list[dict]:
    rows = []
    for model, dataset in [("resnet18", "cifar10"), ("vit_ti4", "cifar10")]:
        layers = model_layers(model, dataset, batch=1)
        t0 = time.perf_counter()
        layer_paths = [find_topk_paths(l.tt_network, k=4) for l in layers]
        t_paths = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = global_search(layer_paths, FPGA_VU9P)
        t_search = time.perf_counter() - t0
        per_layer = max(len(p) for p in layer_paths) * 3 * 3  # p x c x d
        brute = 0
        for h, cs in STRATEGY_SPACE.items():
            combo = 1
            for p in layer_paths:
                combo *= len(p) * len(cs) * len(ALL_DATAFLOWS)
            brute += combo
        rows.append({
            "model": f"{model}/{dataset}",
            "layers": len(layers),
            "path_search_s": t_paths,
            "table_plus_argmin_s": t_search,
            "hierarchical_evals": sum(
                len(p) * 3 * 3 for p in layer_paths),
            "brute_force_combos": float(brute),
        })
    emit("bench_dse_overhead", rows)
    return rows


if __name__ == "__main__":
    run()
