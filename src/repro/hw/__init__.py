"""Hardware-architecture subsystem: targets, registry, searched space.

Three layers:

- :mod:`repro.hw.config` — :class:`HardwareConfig`, the single
  parameterization of the closed-form systolic cost model (JSON-embeddable
  since plan schema v3);
- :mod:`repro.hw.targets` — the named-target registry (``fpga_vu9p``,
  ``tpu_v5e``) behind ``python -m repro.dse --hw`` / ``--list-hw``;
- :mod:`repro.hw.space` — :class:`ArchSpace`, the feasible architecture
  variants of a target under a MAC/DSP budget, searched jointly with
  contraction paths and dataflows by
  ``repro.core.dse.global_search(hw_space=...)``.
"""

from .config import HardwareConfig
from .targets import (
    FPGA_VU9P,
    HW_TARGETS,
    TPU_V5E,
    get_target,
    list_targets,
    register_target,
)
from .space import ArchSpace, arch_coordinates

__all__ = [
    "ArchSpace",
    "arch_coordinates",
    "FPGA_VU9P",
    "HW_TARGETS",
    "HardwareConfig",
    "TPU_V5E",
    "get_target",
    "list_targets",
    "register_target",
]
