"""`python -m repro.dse` CLI: report schema, objectives, module hook."""

import json
import os
import subprocess
import sys

import pytest

from repro.dse_cli import main, model_dse_layers, run_dse
from repro.configs import get_config

REQUIRED_KEYS = {
    "arch", "hw", "objective", "top_k", "tokens", "engine", "strategy",
    "total_latency_s", "total_objective", "n_layers", "timings", "table",
    "layers",
}


def test_cli_smoke_json(capsys):
    assert main(["--arch", "tt-lm-100m", "--top-k", "2"]) == 0
    report = json.loads(capsys.readouterr().out)  # must be valid JSON
    assert REQUIRED_KEYS <= set(report)
    assert report["strategy"] in ("monolithic", "split")
    assert report["n_layers"] == len(report["layers"]) > 0
    assert report["total_latency_s"] > 0
    for layer in report["layers"]:
        assert layer["dataflow"] in ("IS", "OS", "WS")
        assert tuple(layer["partitioning"]) in ((1, 1), (1, 2), (2, 1))
        assert 0 <= layer["path_index"] < 2
        assert layer["latency_s"] > 0
    assert pytest.approx(report["total_latency_s"]) == sum(
        l["latency_s"] for l in report["layers"])


def test_cli_out_file(tmp_path, capsys):
    out = tmp_path / "report.json"
    assert main(["--arch", "tt-lm-100m", "--top-k", "2", "--tokens", "64",
                 "--out", str(out)]) == 0
    capsys.readouterr()
    report = json.loads(out.read_text())
    assert report["tokens"] == 64


def test_edp_objective_consistent():
    lat = run_dse("tt-lm-100m", top_k=2, tokens=128)
    edp = run_dse("tt-lm-100m", top_k=2, tokens=128, objective="edp")
    assert edp["total_objective"] <= edp["total_latency_s"] * 1  # joule-seconds, tiny
    # the EDP argmin can only match or exceed the latency argmin's latency
    assert edp["total_latency_s"] >= lat["total_latency_s"] - 1e-15


def test_tpu_target_and_vision_arch():
    r = run_dse("tt-lm-100m", hw="tpu_v5e", top_k=2, tokens=64)
    assert r["hw"] == "tpu_v5e" and r["total_latency_s"] > 0
    v = run_dse("vit_ti4/cifar10", top_k=2)
    assert v["n_layers"] > 0 and v["tokens"] == 1


def test_unknown_arch_and_hw_raise():
    with pytest.raises(KeyError):
        run_dse("no-such-model")
    # unknown --hw lists the registered choices in the error
    with pytest.raises(KeyError, match="fpga_vu9p"):
        run_dse("tt-lm-100m", hw="no-such-hw")


def test_list_hw_flag(capsys):
    assert main(["--list-hw"]) == 0
    out = capsys.readouterr().out.split()
    assert "fpga_vu9p" in out and "tpu_v5e" in out


def test_hw_search_report_and_guarantee():
    """--hw-search budget: >= 64 feasible candidates, co-searched optimum
    <= the fixed-target optimum, per-candidate rows sorted best-first."""
    r = run_dse("vit_ti4/cifar10", top_k=2, hw_search="budget")
    hs = r["hw_search"]
    assert hs["mode"] == "budget" and hs["n_candidates"] >= 64
    assert len(hs["candidates"]) == hs["n_candidates"]
    lats = [c["total_latency_s"] for c in hs["candidates"]]
    assert lats == sorted(lats)
    assert hs["chosen"]["total_latency_s"] == lats[0]
    assert hs["chosen"]["total_latency_s"] <= hs["fixed"]["total_latency_s"]
    assert r["total_latency_s"] == pytest.approx(
        hs["chosen"]["total_latency_s"], rel=1e-12)
    # the top-level label names the architecture the numbers describe
    assert r["hw_chosen"] == hs["chosen"]["name"]
    assert r["hw"] == "fpga_vu9p"  # the requested base target, unchanged
    # fixed-target run agrees with the space's row for the base target
    fixed = run_dse("vit_ti4/cifar10", top_k=2)
    assert fixed["hw_search"] is None
    assert fixed["total_latency_s"] == hs["fixed"]["total_latency_s"]


def test_hw_search_emit_plan_v3_tilings():
    """--hw-search --emit-plan embeds the winning architecture and caps
    the kernel tilings by its array shape."""
    from repro.dse_cli import run_dse_plan

    report, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2,
                                tokens=32, hw_search="budget")
    assert plan.version == 4
    assert plan.hardware is not None
    assert plan.hardware.name == report["hw_search"]["chosen"]["name"]
    assert plan.hw == plan.hardware.name
    for lp in plan.layers:
        assert lp.tiling.block_m <= max(8, plan.hardware.pe_rows)
        assert lp.tiling.block_n <= max(8, plan.hardware.pe_cols)
        assert lp.tiling.block_k <= max(
            8, plan.hardware.pe_rows, plan.hardware.pe_cols)


def test_fixed_target_plan_keeps_default_tiling_caps():
    """Without --hw-search the cost-model target must NOT shrink the
    Pallas tiling caps: the FPGA model is not the execution substrate,
    and pre-existing fixed-target plans tiled for the 128-wide MXU."""
    from repro.dse_cli import run_dse_plan

    _, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=32)
    assert plan.hardware is not None and plan.hardware.pe_rows == 32
    caps = {max(lp.tiling.block_m, lp.tiling.block_k, lp.tiling.block_n)
            for lp in plan.layers}
    assert max(caps) > 32  # FPGA's 32x32 array did not cap the blocks


def test_hw_search_mode_both_flags_arch_divergence():
    """Each leg of --mode both co-searches its own architecture; the
    combined report names both winners and flags when they differ."""
    r = run_dse("vit_ti4/cifar10", top_k=2, mode="both", hw_search="budget")
    hs = r["hw_search"]
    assert hs["infer_chosen"] == r["infer"]["hw_search"]["chosen"]["name"]
    assert hs["train_chosen"] == r["train"]["hw_search"]["chosen"]["name"]
    assert hs["hw_divergent"] == (hs["infer_chosen"] != hs["train_chosen"])


def test_hw_search_validation():
    with pytest.raises(KeyError, match="hw_search"):
        run_dse("tt-lm-100m", hw_search="exhaustive")
    with pytest.raises(ValueError, match="edp"):
        run_dse("tt-lm-100m", hw_search="budget", objective="edp")
    with pytest.raises(ValueError, match="vectorized"):
        run_dse("tt-lm-100m", hw_search="budget", engine="scalar")


def test_model_dse_layers_covers_families():
    """Every config family enumerates at least its head projection when
    tensorized; tt-lm-100m covers attn+mlp+head."""
    cfg = get_config("tt-lm-100m")
    names = [n for n, _ in model_dse_layers(cfg, tokens=64)]
    assert any(n.startswith("attn.") for n in names)
    assert any(n.startswith("mlp.") for n in names)
    assert "head" in names


def test_mode_train_report_and_plan():
    """--mode train: decomposed per-layer latencies + a train-aware plan
    with backward entries."""
    from repro.dse_cli import run_dse_plan

    report, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                                mode="train")
    assert report["mode"] == "train"
    assert report["objective"] == "train-latency"
    for layer in report["layers"]:
        assert layer["latency_s"] == pytest.approx(
            layer["fwd_latency_s"] + layer["bwd_latency_s"]
            + layer["update_latency_s"], rel=1e-12)
        assert layer["bwd_latency_s"] > 0
        assert {b["wrt"] for b in layer["backward"]} >= {"dx"}
    assert report["total_latency_s"] == pytest.approx(
        report["total_fwd_latency_s"] + report["total_bwd_latency_s"]
        + report["total_update_latency_s"], rel=1e-12)
    assert plan.version == 4
    assert all(lp.backward for lp in plan.layers)
    assert plan.objective == "train-latency"


def test_mode_both_reports_divergence():
    r = run_dse("vit_ti4/cifar10", top_k=4, mode="both")
    assert r["mode"] == "both"
    assert r["infer"]["mode"] == "infer" and r["train"]["mode"] == "train"
    assert r["n_divergent_layers"] == len(r["divergent_layers"]) > 0
    named = {l["name"] for l in r["infer"]["layers"]}
    assert all(d["name"] in named for d in r["divergent_layers"])


def test_mode_and_backend_validation():
    from repro.dse_cli import run_dse_plan

    with pytest.raises(KeyError, match="mode"):
        run_dse("tt-lm-100m", mode="no-such-mode")
    with pytest.raises(ValueError, match="train-latency"):
        run_dse("tt-lm-100m", mode="train", objective="edp")
    with pytest.raises(ValueError, match="vectorized"):
        run_dse("tt-lm-100m", mode="train", engine="scalar")
    # early validation — before any search work happens
    with pytest.raises(ValueError, match="backend"):
        run_dse_plan("tt-lm-100m", smoke=True, plan_backend="cuda")


def test_api_rejects_unknown_plan_backend():
    """models.api(cfg, plan_backend=...) validates the backend up front."""
    from repro.models import api
    from repro.nn import install_plan

    cfg = get_config("tt-lm-100m", smoke=True)
    with pytest.raises(ValueError, match="plan_backend"):
        api(cfg, plan={"attn.wq": 0}, plan_backend="no-such-backend")
    with pytest.raises(ValueError, match="force_backend"):
        install_plan({"attn.wq": 0}, force_backend="no-such-backend")
    install_plan(None)


@pytest.mark.slow
def test_module_invocation_subprocess():
    """The documented entry point: PYTHONPATH=src python -m repro.dse ..."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dse", "--arch", "tt-lm-100m",
         "--top-k", "2", "--tokens", "64"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    report = json.loads(proc.stdout)
    assert report["arch"] == "tt-lm-100m"
