"""Analytic systolic simulator properties (the paper's cost oracle)."""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    FPGA_VU9P,
    GemmShape,
    HardwareConfig,
    TPU_V5E,
    find_topk_paths,
    gemm_latency,
    layer_latency,
    simulate,
    tt_linear_network,
)


@given(
    st.integers(1, 2048), st.integers(1, 2048), st.integers(1, 2048),
    st.sampled_from(list(ALL_DATAFLOWS)),
)
@settings(max_examples=100, deadline=None)
def test_gemm_latency_positive_and_util_bounded(m, k, n, df):
    rep = gemm_latency(GemmShape(m, k, n), df, FPGA_VU9P)
    assert rep.cycles > 0
    assert 0 <= rep.utilization <= 1.0 + 1e-9


@given(st.integers(64, 1024), st.integers(64, 1024), st.integers(64, 1024))
@settings(max_examples=50, deadline=None)
def test_bigger_array_not_slower(m, k, n):
    small = HardwareConfig(pe_rows=16, pe_cols=16)
    big = HardwareConfig(pe_rows=64, pe_cols=64)
    g = GemmShape(m, k, n)
    for df in ALL_DATAFLOWS:
        assert gemm_latency(g, df, big).cycles <= gemm_latency(g, df, small).cycles * 1.5


def test_dataflows_differ_on_skewed_shapes():
    """The IS/OS/WS traffic asymmetry (paper 4.1): a tall-skinny GEMM must
    NOT cost the same under every dataflow."""
    g = GemmShape(4096, 64, 64)
    cycles = {df: gemm_latency(g, df, FPGA_VU9P).cycles for df in ALL_DATAFLOWS}
    assert len({round(c) for c in cycles.values()}) > 1


def test_memory_bound_vs_compute_bound():
    """The model has two regimes; with the paper's generous 256 words/cycle
    most GEMMs are compute-bound, so the memory regime is exercised with a
    narrow-DRAM variant of the same hardware."""
    hw = FPGA_VU9P
    fat = GemmShape(2048, 2048, 2048)
    rf = gemm_latency(fat, Dataflow.OS, hw)
    assert rf.compute_cycles >= rf.traffic_words / hw.dram_words_per_cycle
    slow_dram = dataclasses.replace(hw, dram_words_per_cycle=2.0)
    thin = GemmShape(8, 1_000_000, 8)
    rt = gemm_latency(thin, Dataflow.OS, slow_dram)
    assert rt.compute_cycles < rt.traffic_words / slow_dram.dram_words_per_cycle
    assert rt.cycles > rt.compute_cycles  # latency picked the memory roof


def test_split_partitioning_helps_parallel_branches():
    """A TT layer with independent branches should gain from 1x2/2x1 split
    (paper 4.2 dual-core) in at least one dataflow."""
    tn = tt_linear_network(64, (8, 8), (8, 8), (8, 8, 8))
    path = find_topk_paths(tn, k=1)[0]
    for df in ALL_DATAFLOWS:
        mono = layer_latency(path, df, (1, 1), FPGA_VU9P)
        split = layer_latency(path, df, (1, 2), FPGA_VU9P)
        if split.n_parallel_stages > 0 and split.cycles < mono.cycles:
            return
    pytest.skip("no parallel win on this tiny layer (acceptable)")


def test_simulate_seconds_scale_with_frequency():
    hw2 = dataclasses.replace(FPGA_VU9P, freq_hz=FPGA_VU9P.freq_hz * 2)
    tn = tt_linear_network(16, (4, 4), (4, 4), (4, 4, 4))
    path = find_topk_paths(tn, k=1)[0]
    s1 = simulate(path, (1, 1), Dataflow.OS, FPGA_VU9P)
    s2 = simulate(path, (1, 1), Dataflow.OS, hw2)
    assert abs(s1 / s2 - 2.0) < 1e-6


def test_tpu_config_is_faster_than_fpga():
    tn = tt_linear_network(256, (8, 8, 8), (8, 8, 8), (16,) * 5)
    path = find_topk_paths(tn, k=1)[0]
    assert simulate(path, (1, 1), Dataflow.OS, TPU_V5E) < \
        simulate(path, (1, 1), Dataflow.OS, FPGA_VU9P)


def test_latency_optimal_path_can_differ_from_mac_optimal():
    """The paper's central observation (Fig. 3): with hardware in the loop
    the argmin over paths x dataflows is not always the MAC-optimal path.
    We assert the *mechanism*: simulated latency order need not follow MACs
    for at least one (partitioning, dataflow) on some layer in a sweep."""
    found = False
    for modes in [(8, 8), (4, 16), (16, 4)]:
        tn = tt_linear_network(512, modes, modes, (8, 8, 8))
        paths = find_topk_paths(tn, k=4)
        if len(paths) < 2:
            continue
        for df in ALL_DATAFLOWS:
            lat = [simulate(p, (1, 1), df, FPGA_VU9P) for p in paths]
            if min(range(len(lat)), key=lat.__getitem__) != 0:
                found = True
    assert found, "latency-optimal == MAC-optimal everywhere (unexpected)"
