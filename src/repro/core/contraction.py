"""Execute a searched contraction path as a jit-safe jnp einsum tree.

The path (Python-level, static) is unrolled into a sequence of
``jnp.tensordot`` calls at trace time — no dynamic control flow enters the
jaxpr, so the executor composes with jit / scan / shard_map / grad.

Edge bookkeeping mirrors ``TensorNetwork.contract_pair``: result axes are
A's free edges followed by B's free edges, and the merged node is appended
to the working list.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax.numpy as jnp

from .paths import CandidatePath
from .tensor_network import TensorNetwork


def execute_path(
    tn: TensorNetwork,
    path: CandidatePath | Sequence[tuple[int, int]],
    tensors: Mapping[str, jnp.ndarray],
    out_edges: Sequence[str] | None = None,
    preferred_dtype=None,
    constrain=None,
    contract_fn=None,
) -> jnp.ndarray:
    """Contract ``tn`` along ``path`` using ``tensors[name]`` per node.

    ``out_edges`` fixes the axis order of the result (defaults to the
    network's free edges in first-appearance order).  ``constrain``, if
    given, is called as ``constrain(edges, tensor) -> tensor`` after every
    pairwise contraction — the hook the distributed layer uses to pin
    sharding onto intermediates (GSPMD loses it through merged dims).
    ``contract_fn``, if given, replaces the per-step ``jnp.tensordot`` —
    called as ``contract_fn(ta, tb, (ax_a, ax_b))`` and expected to return
    the tensordot-ordered result (A's free axes then B's free axes); the
    plan executor uses it to lower each step to a Pallas GEMM.
    """
    steps = path.steps if isinstance(path, CandidatePath) else tuple(path)
    work: list[tuple[tuple[str, ...], jnp.ndarray]] = []
    for node in tn.nodes:
        t = tensors[node.name]
        if tuple(t.shape) != node.dims:
            raise ValueError(
                f"tensor {node.name}: shape {t.shape} != declared {node.dims}"
            )
        work.append((node.edges, t))

    for (i, j) in steps:
        (ea, ta) = work[i]
        (eb, tb) = work[j]
        shared = [e for e in ea if e in eb]
        ax_a = [ea.index(e) for e in shared]
        ax_b = [eb.index(e) for e in shared]
        if contract_fn is not None:
            tc = contract_fn(ta, tb, (ax_a, ax_b))
        else:
            tc = jnp.tensordot(ta, tb, axes=(ax_a, ax_b),
                               preferred_element_type=preferred_dtype)
        ec = tuple(e for e in ea if e not in shared) + tuple(
            e for e in eb if e not in shared
        )
        if constrain is not None:
            tc = constrain(ec, tc)
        work = [w for s, w in enumerate(work) if s not in (i, j)]
        work.append((ec, tc))

    if len(work) != 1:
        raise ValueError("path did not fully contract the network")
    edges, result = work[0]
    if out_edges is not None:
        perm = [edges.index(e) for e in out_edges]
        result = jnp.transpose(result, perm)
    return result


def core_tensors(
    tn: TensorNetwork, arrays: Sequence[jnp.ndarray], input_name: str = "X"
) -> dict[str, jnp.ndarray]:
    """Zip weight-core arrays (in node order, skipping the input node)."""
    names = [n.name for n in tn.nodes if n.name != input_name]
    if len(names) != len(arrays):
        raise ValueError(f"{len(names)} core nodes vs {len(arrays)} arrays")
    return dict(zip(names, arrays))
