"""Global latency-driven design-space exploration (paper Algorithm 1).

Stage 1 — design-space construction: per layer, the MAC-guided top-K path
search yields P_l; the partitioning space C_all and dataflow space D are
global.  Stage 2 — a cost table T[l, p, c, d] is populated by the latency
simulator.  Stage 3 — hierarchical search: for each global hardware
strategy h (which constrains C to C_h), the problem decomposes into
independent per-layer argmins; the best strategy wins.  This is exhaustive
over the (pruned) space, so the returned configuration is optimal within
it — matching the paper's "mathematically guaranteeing the optimal
solution with minimal overhead".

**Hardware co-search.**  ``global_search(hw_space=...)`` adds an outer
loop over architecture candidates (``repro.hw.ArchSpace``): the
architecture is shared by every layer (non-separable), so each feasible
candidate gets its own hierarchical argmin over a cost table built by
the hw-batched engine (``cost_table.build_cost_tables_hw`` — shared
registry rows, one vectorized evaluation per memory profile), and the
best (architecture, per-layer choices) pair wins.  The outer loop is
exhaustive over the candidate list, so the optimality guarantee extends
to the joint (arch, path, partitioning, dataflow) space — for the
``latency`` and ``train-latency`` objectives alike.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Mapping, Sequence

from .paths import CandidatePath, find_topk_paths
from .simulator import (
    ALL_DATAFLOWS,
    STRATEGY_SPACE,
    Dataflow,
    HardwareConfig,
    FPGA_VU9P,
    Partitioning,
    simulate,
)
from .tensor_network import TensorNetwork


@dataclasses.dataclass(frozen=True)
class LayerChoice:
    """Optimal (p, c, d) for one layer under the winning strategy.

    Under the ``train-latency`` objective, ``latency_s`` is the combined
    per-step cost and the decomposition + per-gradient backward path
    choices are populated; under inference objectives the backward fields
    stay empty.
    """

    layer: int
    path_index: int
    path: CandidatePath
    partitioning: Partitioning
    dataflow: Dataflow
    latency_s: float
    backward: tuple = ()              # tuple[cost_table.BackwardChoice, ...]
    fwd_latency_s: float = 0.0
    bwd_latency_s: float = 0.0
    update_latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class HwCandidateResult:
    """One architecture candidate's best configuration (hw co-search)."""

    hw: HardwareConfig
    strategy: str
    total_latency_s: float


@dataclasses.dataclass(frozen=True)
class DSEResult:
    strategy: str
    choices: tuple[LayerChoice, ...]
    total_latency_s: float
    cost_table: Mapping[tuple[int, int, Partitioning, Dataflow], float]
    objective: str = "latency"
    #: the architecture the choices were evaluated on (the winning
    #: candidate under ``hw_space=``, else the fixed target)
    hw: HardwareConfig | None = None
    #: per-candidate outcomes when ``hw_space=`` was searched (aligned
    #: with the candidate list for exhaustive searches; the *visited*
    #: candidates, in refinement order, for guided searches)
    hw_candidates: tuple[HwCandidateResult, ...] = ()
    #: search provenance: ``"exhaustive"`` (Algorithm 1's full sweep) or
    #: ``"guided"`` (the budgeted explorer of ``repro.search``)
    search: str = "exhaustive"
    #: cost-model evaluations performed — unique (arch, layer, path,
    #: partitioning, dataflow) cells read.  Exhaustive searches evaluate
    #: every cell of every candidate; guided searches stop at the budget.
    evals: int = 0
    #: the evaluation count at which the returned optimum was first found
    found_at_eval: int = 0

    @property
    def per_layer_latency(self) -> tuple[float, ...]:
        return tuple(c.latency_s for c in self.choices)


def build_cost_table(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig,
    partitionings: Sequence[Partitioning],
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
    engine: str = "auto",
) -> dict[tuple[int, int, Partitioning, Dataflow], float]:
    """T[l, p, c, d] <- Simulate(p, c, d) for all valid configs (Alg. 1, l.2).

    ``engine="vectorized"`` uses the batched NumPy engine
    (``repro.core.cost_table``), bit-identical to the scalar loop;
    ``"scalar"`` forces the per-cell oracle; ``"auto"`` picks the
    vectorized engine whenever the default ``simulate`` oracle is in use
    (a custom ``simulate_fn`` must go through the scalar loop).
    """
    if engine not in ("auto", "scalar", "vectorized"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "vectorized" and simulate_fn is not simulate:
        raise ValueError(
            "engine='vectorized' evaluates the built-in closed-form model; "
            "a custom simulate_fn requires engine='scalar'"
        )
    if engine == "vectorized" or (engine == "auto" and simulate_fn is simulate):
        from .cost_table import build_cost_table_vectorized

        return build_cost_table_vectorized(layer_paths, hw, partitionings, dataflows)
    table: dict[tuple[int, int, Partitioning, Dataflow], float] = {}
    for l, paths in enumerate(layer_paths):
        for p_idx, path in enumerate(paths):
            for c in partitionings:
                for d in dataflows:
                    table[(l, p_idx, c, d)] = simulate_fn(path, c, d, hw)
    return table


def replay_paths(
    layer_paths: Sequence[Sequence[CandidatePath]],
    networks: Sequence[TensorNetwork],
) -> list[tuple[CandidatePath, ...]]:
    """Re-cost each layer's candidate paths against new tensor networks.

    The serving throughput objective evaluates one contraction order at
    two activation shapes (prefill tokens vs per-step decode tokens).
    A candidate's *steps* are shape-independent; this replays them on
    ``networks`` (one per layer, same order as ``layer_paths``) so the
    same ``(layer, path_index)`` keys index both phase cost tables.
    """
    if len(layer_paths) != len(networks):
        raise ValueError(
            f"{len(layer_paths)} layers of candidate paths but "
            f"{len(networks)} replacement networks")
    out: list[tuple[CandidatePath, ...]] = []
    for paths, tn in zip(layer_paths, networks):
        replayed = []
        for p in paths:
            gemms = tuple(tn.gemm_sequence(p.steps))
            replayed.append(CandidatePath(
                steps=p.steps, macs=sum(g.macs for g in gemms), gemms=gemms))
        out.append(tuple(replayed))
    return out


def combine_phase_tables(
    prefill_table: Mapping[tuple[int, int, Partitioning, Dataflow], float],
    decode_table: Mapping[tuple[int, int, Partitioning, Dataflow], float],
    *,
    w_prefill: float = 1.0,
    w_decode: float = 1.0,
    calibration=None,
    prefill_paths: Sequence[Sequence[CandidatePath]] | None = None,
    decode_paths: Sequence[Sequence[CandidatePath]] | None = None,
) -> dict[tuple[int, int, Partitioning, Dataflow], float]:
    """Decode-weighted combined serving cost: ``w_p*T_pre + w_d*T_dec``.

    Both tables must index the identical (layer, path, partitioning,
    dataflow) key set — build the decode table over
    :func:`replay_paths`-ed candidates so path indices line up.  The
    serving weight is typically ``w_decode = gen_tokens / n_slots``: one
    admission's prefill amortized against its share of fixed-width
    decode steps.

    ``calibration`` applies the autotuner's measured correction
    (:func:`apply_calibration`) to *each phase table separately, at that
    phase's own GEMM shapes*, before combining: a shape-aware
    ``CostCorrection`` resolves the prefill cells against
    ``prefill_paths`` and the decode cells against ``decode_paths``
    (decode GEMMs are much skinnier, so one shared scale would mislead
    exactly where the phases disagree).  The calibrated combined table
    should then feed ``global_search(..., calibration=None)`` — the
    correction is already inside.
    """
    if prefill_table.keys() != decode_table.keys():
        raise ValueError(
            "phase tables index different (layer, path, partitioning, "
            "dataflow) keys; build the decode table over "
            "replay_paths(layer_paths, decode_networks)")
    if calibration is not None:
        prefill_table = apply_calibration(prefill_table, calibration,
                                          layer_paths=prefill_paths)
        decode_table = apply_calibration(decode_table, calibration,
                                         layer_paths=decode_paths)
    return {
        k: w_prefill * prefill_table[k] + w_decode * decode_table[k]
        for k in prefill_table
    }


def _hierarchical_argmin(
    layer_paths: Sequence[Sequence[CandidatePath]],
    table: Mapping[tuple[int, int, Partitioning, Dataflow], float],
    strategy_space: Mapping[str, Sequence[Partitioning]],
    dataflows: Sequence[Dataflow],
    train=None,
) -> tuple[str, tuple[LayerChoice, ...], float]:
    """Strategy loop + independent per-layer argmins over a built table."""
    best_cost = float("inf")
    best: tuple[str, tuple[LayerChoice, ...]] | None = None
    for h, c_h in strategy_space.items():
        choices: list[LayerChoice] = []
        cost_h = 0.0
        for l, paths in enumerate(layer_paths):
            lat, arg = min(
                ((table[(l, p, c, d)], (p, c, d))
                 for p in range(len(paths))
                 for c in c_h
                 for d in dataflows),
                key=lambda t: t[0],
            )
            p, c, d = arg
            if train is not None:
                w = train.weights
                choices.append(LayerChoice(
                    l, p, paths[p], c, d, lat,
                    backward=train.bwd_choices[(l, c, d)],
                    fwd_latency_s=w.fwd * train.fwd.seconds[(l, p, c, d)],
                    bwd_latency_s=w.bwd * train.bwd_seconds[(l, c, d)],
                    update_latency_s=w.update * train.update_seconds[l],
                ))
            else:
                choices.append(LayerChoice(l, p, paths[p], c, d, lat))
            cost_h += lat
        if cost_h < best_cost:
            best_cost = cost_h
            best = (h, tuple(choices))
    assert best is not None
    return best[0], best[1], best_cost


def _global_search_hw(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw_space: Sequence[HardwareConfig],
    strategy_space: Mapping[str, Sequence[Partitioning]],
    dataflows: Sequence[Dataflow],
    objective: str,
    layer_backwards: Sequence | None,
    train_weights,
    hw_tables,
    hw_train_tables,
    calibration: Mapping | None = None,
) -> DSEResult:
    """Outer architecture loop: per-candidate argmin, best candidate wins.

    Ties resolve to the earliest candidate — architecture spaces list the
    base target first, so equality means "the default was already
    optimal".
    """
    hw_space = tuple(hw_space)
    if not hw_space:
        raise ValueError("hw_space must contain at least one candidate")
    all_parts = sorted({c for cs in strategy_space.values() for c in cs})
    trains = None
    if objective == "train-latency":
        if hw_train_tables is not None:
            trains = tuple(hw_train_tables)
        else:
            if layer_backwards is None:
                raise ValueError(
                    "objective='train-latency' requires layer_backwards "
                    "(see repro.core.backward.memoised_layer_backwards) "
                    "or pre-built hw_train_tables")
            from .cost_table import build_train_cost_tables_hw

            trains = build_train_cost_tables_hw(
                layer_paths, layer_backwards, hw_space, all_parts,
                dataflows, weights=train_weights)
        if len(trains) != len(hw_space):
            raise ValueError(
                f"{len(trains)} train tables vs {len(hw_space)} candidates")
        tables = [t.train_seconds() for t in trains]
    elif hw_tables is not None:
        tables = list(hw_tables)
        if len(tables) != len(hw_space):
            raise ValueError(
                f"{len(tables)} hw_tables vs {len(hw_space)} candidates")
    else:
        from .cost_table import build_cost_tables_hw

        tables = [t.seconds for t in
                  build_cost_tables_hw(layer_paths, hw_space, all_parts,
                                       dataflows)]
    if calibration is not None:
        # measured rescale per candidate (ROADMAP gap c, closed): the
        # measured/analytic disagreement is a property of the cost model
        # vs the machine, so the same per-(shape-bucket, dataflow) scales
        # apply to every candidate's analytic table
        tables = [apply_calibration(t, calibration, dataflows,
                                    layer_paths=layer_paths)
                  for t in tables]

    candidates: list[HwCandidateResult] = []
    best_cost = float("inf")
    best: tuple[int, str, tuple[LayerChoice, ...]] | None = None
    for i, hw_i in enumerate(hw_space):
        strategy, choices, cost = _hierarchical_argmin(
            layer_paths, tables[i], strategy_space, dataflows,
            trains[i] if trains is not None else None)
        candidates.append(HwCandidateResult(hw_i, strategy, cost))
        if cost < best_cost:
            best_cost = cost
            best = (i, strategy, choices)
    assert best is not None
    i, strategy, choices = best
    n_evals = sum(len(t) for t in tables)
    return DSEResult(strategy, choices, best_cost, tables[i], objective,
                     hw=hw_space[i], hw_candidates=tuple(candidates),
                     search="exhaustive", evals=n_evals,
                     found_at_eval=n_evals)


def _normalize_calibration(
    calibration: Mapping, dataflows: Sequence[Dataflow]
) -> dict[Dataflow, float]:
    """Key a measured-latency calibration table by :class:`Dataflow`."""
    out: dict[Dataflow, float] = {}
    for k, v in calibration.items():
        d = k if isinstance(k, Dataflow) else Dataflow(str(k))
        s = float(v)
        if not s > 0:
            raise ValueError(
                f"calibration scale for {d.value} must be positive, got {v!r}")
        out[d] = s
    unknown = set(out) - set(dataflows)
    if unknown:
        raise ValueError(
            f"calibration names dataflows outside the search space: "
            f"{sorted(d.value for d in unknown)}")
    return out


def apply_calibration(
    table: Mapping[tuple[int, int, Partitioning, Dataflow], float],
    calibration,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    *,
    layer_paths: Sequence[Sequence[CandidatePath]] | None = None,
) -> dict[tuple[int, int, Partitioning, Dataflow], float]:
    """Rescale a cost table by measured/analytic factors.

    ``calibration`` is either

    - a mapping from dataflow (``Dataflow`` or its string value) to a
      positive scale — ``repro.tune.measured_calibration``'s geometric-
      mean measured/analytic ratio per dataflow (PR 5's flat model), or
    - a shape-aware correction model (``repro.tune.CostCorrection`` —
      anything exposing ``scale(M, K, N, dataflow)``), fit from the
      persistent tuning cache per (GEMM-shape bucket, dataflow).  This
      form needs ``layer_paths`` to resolve each table cell's dominant
      GEMM shape.

    A uniform table cannot move any argmin; *relative* disagreement
    between the analytic model and the machine can, which is exactly the
    signal wall-clock measurements carry.
    """
    if not isinstance(calibration, Mapping) and hasattr(calibration, "scale"):
        if layer_paths is None:
            raise ValueError(
                "a shape-aware correction model rescales per dominant GEMM "
                "shape; pass layer_paths so each (layer, path) cell can be "
                "resolved to its shape bucket")
        dom: dict[tuple[int, int], tuple[int, int, int]] = {}
        for l, paths in enumerate(layer_paths):
            for p, path in enumerate(paths):
                g = max(path.gemms, key=lambda g: g.macs)
                dom[(l, p)] = (int(g.M), int(g.K), int(g.N))
        scales: dict[tuple[tuple[int, int, int], Dataflow], float] = {}
        out = {}
        for k, v in table.items():
            shape = dom[k[:2]]
            s = scales.get((shape, k[3]))
            if s is None:
                s = float(calibration.scale(*shape, k[3]))
                if not s > 0:
                    raise ValueError(
                        f"correction scale for shape {shape} / {k[3].value} "
                        f"must be positive, got {s!r}")
                scales[(shape, k[3])] = s
            out[k] = v * s
        return out
    cal = _normalize_calibration(calibration, dataflows)
    return {k: v * cal.get(k[3], 1.0) for k, v in table.items()}


def global_search(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig = FPGA_VU9P,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
    engine: str = "auto",
    table: Mapping[tuple[int, int, Partitioning, Dataflow], float] | None = None,
    *,
    objective: str = "latency",
    layer_backwards: Sequence | None = None,
    train_weights=None,
    train_tables=None,
    hw_space: Sequence[HardwareConfig] | None = None,
    hw_tables: Sequence[Mapping] | None = None,
    hw_train_tables: Sequence | None = None,
    calibration: Mapping | None = None,
) -> DSEResult:
    """Algorithm 1: global strategy loop + independent per-layer argmins.

    ``table`` may supply a pre-built cost table (any per-config objective,
    e.g. the EDP table from ``cost_table.CostTables.edp``); by default the
    latency table is built with the selected ``engine``.

    ``calibration`` rescales the (built or supplied) cost table by
    measured/analytic factors (:func:`apply_calibration`) before the
    argmin — the measured-latency feedback loop of ``repro.tune``: when
    wall-clock measurements rank dataflows (or shape buckets, for a
    ``CostCorrection`` model) differently than the analytic model, the
    argmin genuinely moves.  Composes with fixed-target *and*
    architecture co-searches (each candidate's table is rescaled before
    its argmin); the training decomposition is still analytic-only (open
    item in ROADMAP.md).

    ``objective="train-latency"`` jointly optimizes the forward *and*
    backward passes: per cell, the cost is ``w_f * fwd + w_b * bwd +
    w_u * update`` where the backward term takes, for each gradient's
    contraction network, its best candidate path under the layer's
    (partitioning, dataflow).  ``layer_backwards`` (one
    ``backward.LayerBackward`` per layer — see
    ``backward.memoised_layer_backwards``) is required; the returned
    choices carry the per-gradient backward paths and the
    fwd/bwd/update latency decomposition.

    ``hw_space`` turns on the joint architecture co-search: the fixed
    ``hw`` target is ignored, every candidate is evaluated through the
    hw-batched cost-table engine (``hw_tables`` / ``hw_train_tables``
    may supply pre-built per-candidate tables, aligned with the space),
    and the result records the winning architecture (``result.hw``) plus
    every candidate's outcome (``result.hw_candidates``).
    """
    if objective not in ("latency", "train-latency", "throughput"):
        raise ValueError(
            f"unknown objective {objective!r}; have "
            "('latency', 'train-latency', 'throughput')"
            " — EDP goes through the ``table`` argument")
    if objective == "throughput":
        if hw_space is not None:
            raise ValueError(
                "objective='throughput' selects over a pre-combined phase "
                "table; the architecture co-search rebuilds tables per "
                "candidate and cannot consume one (open item, ROADMAP.md)")
        if table is None:
            raise ValueError(
                "objective='throughput' requires a pre-built combined "
                "phase table — combine_phase_tables(prefill, decode, "
                "w_decode=gen/slots) over replay_paths-aligned candidates "
                "(repro.dse --objective throughput builds it)")
    if calibration is not None and objective == "train-latency":
        raise ValueError(
            "calibration rescales the inference table; the training "
            "decomposition is analytic-only for now (ROADMAP.md)")
    if hw_space is not None:
        if table is not None or train_tables is not None:
            raise ValueError(
                "hw_space builds one table per candidate; pass per-candidate "
                "tables via hw_tables / hw_train_tables instead of "
                "table / train_tables")
        if simulate_fn is not simulate or engine == "scalar":
            raise ValueError(
                "hw_space is evaluated through the batched closed-form "
                "engine; custom simulate_fn / engine='scalar' are not "
                "supported")
        if train_weights is not None and hw_train_tables is not None:
            raise ValueError(
                "train_weights must be baked into hw_train_tables at build "
                "time; passing both is ambiguous")
        if objective == "train-latency" and hw_tables is not None:
            raise ValueError(
                "objective='train-latency' consumes hw_train_tables; "
                "hw_tables would be silently ignored")
        if objective != "train-latency" and hw_train_tables is not None:
            raise ValueError(
                "hw_train_tables requires objective='train-latency'; "
                "it would be silently ignored")
        return _global_search_hw(
            layer_paths, hw_space, strategy_space, dataflows, objective,
            layer_backwards, train_weights, hw_tables, hw_train_tables,
            calibration)
    if hw_tables is not None or hw_train_tables is not None:
        raise ValueError("hw_tables / hw_train_tables require hw_space")

    all_parts = sorted({c for cs in strategy_space.values() for c in cs})
    train = None
    if objective == "train-latency":
        if table is not None:
            raise ValueError(
                "objective='train-latency' builds its own combined table; "
                "a pre-built ``table`` cannot be decomposed "
                "(pass ``train_tables`` instead)")
        if train_tables is not None:
            if train_weights is not None:
                raise ValueError(
                    "train_weights must be baked into train_tables at build "
                    "time (build_train_cost_tables(weights=...)); passing "
                    "both is ambiguous")
            train = train_tables
        else:
            if layer_backwards is None:
                raise ValueError(
                    "objective='train-latency' requires layer_backwards "
                    "(see repro.core.backward.memoised_layer_backwards) "
                    "or a pre-built train_tables")
            from .cost_table import build_train_cost_tables

            train = build_train_cost_tables(
                layer_paths, layer_backwards, hw, all_parts, dataflows,
                weights=train_weights)
        table = train.train_seconds()
    elif table is None:
        table = build_cost_table(
            layer_paths, hw, all_parts, dataflows, simulate_fn, engine
        )
    if calibration is not None:
        table = apply_calibration(table, calibration, dataflows,
                                  layer_paths=layer_paths)

    strategy, choices, best_cost = _hierarchical_argmin(
        layer_paths, table, strategy_space, dataflows, train)
    return DSEResult(strategy, choices, best_cost, table, objective, hw=hw,
                     search="exhaustive", evals=len(table),
                     found_at_eval=len(table))


def brute_force_search(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig = FPGA_VU9P,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
) -> float:
    """Exhaustive cross-product search — test oracle for ``global_search``.

    Exponential in L; only usable for tiny models in tests.
    """
    best = float("inf")
    for h, c_h in strategy_space.items():
        per_layer_opts = []
        for paths in layer_paths:
            per_layer_opts.append([
                (p, c, d)
                for p in range(len(paths))
                for c in c_h
                for d in dataflows
            ])
        for combo in itertools.product(*per_layer_opts):
            cost = sum(
                simulate_fn(layer_paths[l][p], c, d, hw)
                for l, (p, c, d) in enumerate(combo)
            )
            best = min(best, cost)
    return best


def explore_model(
    networks: Sequence[TensorNetwork],
    hw: HardwareConfig = FPGA_VU9P,
    top_k: int = 4,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    engine: str = "auto",
    objective: str = "latency",
    hw_space: Sequence[HardwareConfig] | None = None,
) -> DSEResult:
    """End-to-end DSE for a model given per-layer tensor networks."""
    layer_paths = [find_topk_paths(tn, k=top_k) for tn in networks]
    layer_backwards = None
    if objective == "train-latency":
        from .backward import memoised_layer_backwards

        layer_backwards = memoised_layer_backwards(networks, k=top_k)
    return global_search(layer_paths, hw, strategy_space, dataflows,
                         engine=engine, objective=objective,
                         layer_backwards=layer_backwards, hw_space=hw_space)


def pareto_front(points: Sequence[tuple[float, float]]) -> list[int]:
    """Indices of the Pareto-optimal (cost1, cost2) points (both minimised)."""
    order = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front: list[int] = []
    best_y = float("inf")
    for i in order:
        if points[i][1] < best_y:
            front.append(i)
            best_y = points[i][1]
    return front
