"""Accuracy proxy for rank candidates: TT-SVD reconstruction error.

Scoring a rank candidate by actually fine-tuning the model is far too
expensive inside a DSE loop; the standard proxy (e.g. the paper's Table
1 compression study) is the relative Frobenius error of the TT-SVD of
the layer's weight matrix — a deterministic, training-free stand-in
that orders candidates the same way post-compression accuracy does for
moderate compression levels.

The repo has no pretrained checkpoints, so each family is scored
against a deterministic *synthetic reference weight* with a realistic
spectrum: an orthogonal low-rank core with power-law decaying singular
values plus a small isotropic noise floor, seeded from the family name
and shape.  The proxy is then exactly the quantity a checkpointed run
would compute — swap :func:`reference_weight` for a loader and nothing
downstream changes.

Model-level aggregation weights each family by its dense parameter
count times its instance count — optionally rescaled by a measured
activation RMS (``activation_calibration``), so families whose inputs
run hot count for more.
"""

from __future__ import annotations

import functools
import hashlib
import math
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.tt import reconstruction_error, tt_svd

from .space import FamilyFactorization, RankCandidate

#: rank of the reference weight's structured core
REFERENCE_COMPONENTS = 64

#: power-law decay exponent of the reference singular values
SPECTRUM_DECAY = 1.2

#: relative Frobenius mass of the isotropic noise floor (keeps every
#: truncation error strictly positive — no free-lunch candidates)
NOISE_FLOOR = 1e-3


def _seed(name: str, d_out: int, d_in: int) -> int:
    h = hashlib.sha1(f"{name}:{d_out}x{d_in}".encode()).digest()
    return int.from_bytes(h[:8], "big")


@functools.lru_cache(maxsize=16)
def reference_weight(name: str, d_out: int, d_in: int) -> np.ndarray:
    """Deterministic synthetic (d_out, d_in) reference weight.

    ``U diag(s) V^T + noise`` with orthonormal U/V, ``s_i ~ i^-1.2``
    over min(64, dims) components, and an isotropic noise floor at 1e-3
    of the structured Frobenius mass.  Same (name, shape) -> bit-equal
    array in every process, so proxies are reproducible across runs.
    """
    rng = np.random.default_rng(_seed(name, d_out, d_in))
    q = min(REFERENCE_COMPONENTS, d_out, d_in)
    u, _ = np.linalg.qr(
        rng.standard_normal((d_out, q)).astype(np.float32))
    v, _ = np.linalg.qr(
        rng.standard_normal((d_in, q)).astype(np.float32))
    s = np.arange(1, q + 1, dtype=np.float32) ** np.float32(-SPECTRUM_DECAY)
    w = (u * s) @ v.T
    g = rng.standard_normal((d_out, d_in)).astype(np.float32)
    g *= NOISE_FLOOR * np.linalg.norm(s) / np.linalg.norm(g)
    w += g
    w.setflags(write=False)
    return w


@functools.lru_cache(maxsize=4096)
def reconstruction_proxy(
    name: str,
    d_out: int,
    d_in: int,
    out_modes: tuple[int, ...],
    in_modes: tuple[int, ...],
    rank: int,
) -> float:
    """Relative Frobenius error of the TT-SVD of the family's reference
    weight under (out_modes, in_modes) at ``rank``.  The TT-SVD clips
    each cut to its full-rank bound, so the realized interior ranks
    equal :func:`repro.rank.space.clip_ranks` of the same grid point."""
    w = reference_weight(name, d_out, d_in)
    tt = tt_svd(w, out_modes, in_modes, max_rank=rank)
    return reconstruction_error(tt, w)


def family_proxy(f: FamilyFactorization) -> float:
    return reconstruction_proxy(f.name, f.d_out, f.d_in,
                                f.out_modes, f.in_modes, max(f.ranks))


def candidate_proxy(
    candidate: RankCandidate,
    weights: Optional[Mapping[str, float]] = None,
) -> float:
    """Model-level accuracy proxy: dense-parameter (x instance
    [x activation-RMS]) weighted mean of the per-family errors."""
    total_w = 0.0
    total = 0.0
    for f in candidate.families:
        w = float(f.dense_params) * f.instances
        if weights is not None:
            w *= float(weights.get(f.name, 1.0))
        total_w += w
        total += w * family_proxy(f)
    return total / total_w if total_w > 0 else 0.0


def activation_calibration(
    cfg,
    *,
    batch: int = 2,
    seq: int = 16,
    seed: int = 0,
) -> dict[str, float]:
    """Per-family input-RMS weights from one eager calibration forward.

    Runs a random-token prefill of ``cfg`` with layer scanning and remat
    disabled (both trace, which would hide activations from the eager
    capture hook) and returns ``{family name: mean input RMS}`` for use
    as :func:`candidate_proxy` weights.  Programmatic/test use only — the
    CLI's proxy stays unweighted so reports are model-free deterministic.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.models import api
    from repro.nn import capture_activation_rms

    eager_cfg = _dc.replace(cfg, scan_layers=False, remat="none")
    m = api(eager_cfg)
    rng = jax.random.PRNGKey(seed)
    params = m.init_params(rng)
    tokens = jax.random.randint(jax.random.fold_in(rng, 1),
                                (batch, seq), 0, eager_cfg.vocab, jnp.int32)
    with capture_activation_rms() as rms:
        m.prefill(params, {"tokens": tokens}, seq)
    return dict(rms)


def frontier_points(
    evals: Sequence[tuple[float, float]],
) -> list[int]:
    """Indices of the (latency, proxy) Pareto front (both minimised)."""
    from repro.core.dse import pareto_front

    return pareto_front(list(evals))
