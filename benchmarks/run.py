"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table3]

Each module prints a CSV block and writes results/benchmarks/<name>.json.
The roofline report additionally consumes results/dryrun/*.json when the
multi-pod dry-run has been executed.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    bench_dse,
    bench_dse_overhead,
    bench_fused_exec,
    bench_search,
    bench_shard_scaling,
    bench_plan_exec,
    bench_serve_wallclock,
    fig3_paths,
    fig5_dataflow,
    table1_compression,
    table2_dse_choices,
    table3_latency,
    table4_efficiency,
    table5_training_latency,
    table6_hw_cosearch,
    table_rank_frontier,
)

SUITES = {
    "table1": table1_compression.run,
    "table2": table2_dse_choices.run,
    "table3": table3_latency.run,
    "table4": table4_efficiency.run,
    "table5": table5_training_latency.run,
    "table6": table6_hw_cosearch.run,
    "table_rank": table_rank_frontier.run,
    "fig3": fig3_paths.run,
    "fig5": fig5_dataflow.run,
    "dse_overhead": bench_dse_overhead.run,
    "plan_exec": bench_plan_exec.run,
    "bench_dse": bench_dse.run,
    "bench_search": bench_search.run,
    "bench_shard": bench_shard_scaling.run,
    "bench_serve": bench_serve_wallclock.run,
    "bench_fused": bench_fused_exec.run,
}


def roofline_report():
    """Summarize the dry-run roofline table if artifacts exist."""
    import glob
    import json
    import os
    from repro.launch.roofline import RESULTS_DIR, analyze_cell, markdown_table
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "*_pod_tt.json")))
    rows = [r for p in paths
            for r in [analyze_cell(json.load(open(p)))] if r]
    if rows:
        print("# --- roofline (from dry-run artifacts) ---")
        print(markdown_table(rows))
        print()
    else:
        print("# roofline: no dry-run artifacts found (run repro.launch.dryrun)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(SUITES)
    failed = []
    for name in names:
        t0 = time.time()
        try:
            SUITES[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s\n", flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if not args.only:
        roofline_report()
    if failed:
        print(f"FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
