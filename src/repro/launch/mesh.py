"""Production mesh + sharding policy.

``make_production_mesh`` builds the assignment's meshes: ``(16, 16)``
("data", "model") single-pod and ``(2, 16, 16)`` ("pod", "data", "model")
multi-pod.  A FUNCTION, not a module constant — importing this module
never touches jax device state.

``make_rules`` is the per-(arch x shape) sharding policy:
  * batch  -> ("pod", "data") / ("data",)  (pure DP on the pod axis:
    cross-pod links carry only gradient reductions)
  * model  -> TP/EP axis
  * seq    -> sequence parallelism, enabled when attention heads cannot
    shard the model axis (kv_heads % tp != 0) or at >=200k context

``param_shardings`` is the FSDP-style parameter heuristic: largest
divisible dim -> "model", next -> "data" (weight-gathered FSDP under
GSPMD); small tensors (TT cores, norms) replicate.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding import ShardingRules


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    # older jax (< 0.5): meshes are Auto-mode only; no axis_types kwarg
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(devices: Optional[int] = None, multi_pod: bool = False) -> Mesh:
    """Small mesh over however many devices exist (CI / reduced dry-runs)."""
    n = devices or len(jax.devices())
    if multi_pod and n >= 8:
        pod = 2
        rest = n // pod
        model = _largest_pow2_le(int(math.isqrt(rest)))
        data = rest // model
        return _mesh((pod, data, model), ("pod", "data", "model"))
    model = _largest_pow2_le(int(math.isqrt(n)))
    data = n // model
    return _mesh((data, model), ("data", "model"))


def _largest_pow2_le(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _decode_cache_gib(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> float:
    """Per-device KV-cache GiB if sharded on batch only (heads replicated)."""
    b_local = max(shape.global_batch // max(dp, 1), 1)
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 1
    if cfg.family == "rwkv":
        return 0.0
    per_layer = 2 * b_local * shape.seq_len * cfg.n_kv_heads * cfg.hd * 2
    return n_attn * per_layer / 2**30


def sp_enabled(cfg: ModelConfig, shape: ShapeConfig, tp: int,
               dp: int = 16) -> bool:
    if cfg.family == "rwkv":
        return False  # attention-free: heads always shard
    if shape.step == "decode":
        # Perf iteration (see EXPERIMENTS.md §Perf): seq-sharding the KV
        # cache makes every decode step gather it (measured GB/step of
        # all-gather).  Batch+head sharding is collective-free — use it
        # whenever the cache fits; fall back to SP only when it doesn't.
        if cfg.n_kv_heads % tp == 0:
            return False
        return _decode_cache_gib(cfg, shape, dp) > 12.0
    if cfg.n_kv_heads % tp != 0:
        return True
    return shape.seq_len >= 200_000


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> ShardingRules:
    axis_sizes = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    tp = axis_sizes.get("model", 1)
    dp = math.prod(axis_sizes.get(a, 1) for a in batch_axes)
    return ShardingRules(
        axis_sizes=axis_sizes,
        batch_axes=batch_axes,
        model_axis="model" if "model" in axis_sizes else None,
        seq_axis="model" if sp_enabled(cfg, shape, tp, dp) else None,
        mesh=mesh,
    )


# ---------------------------------------------------------------------------
# parameter / cache / input sharding trees
# ---------------------------------------------------------------------------

_REPLICATE_BELOW = 65_536  # elements; TT cores & norms replicate


def _param_pspec(shape: tuple[int, ...], axis_sizes: dict) -> P:
    if math.prod(shape) < _REPLICATE_BELOW or len(shape) < 2:
        return P()
    spec: list = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    tp = axis_sizes.get("model", 1)
    if tp > 1:
        for i in order:
            if shape[i] % tp == 0 and shape[i] >= tp:
                spec[i] = "model"
                break
    fsdp = axis_sizes.get("data", 1)
    if fsdp > 1:
        for i in order:
            if spec[i] is None and shape[i] % fsdp == 0 and shape[i] >= fsdp:
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(params_shapes: Any, mesh: Mesh) -> Any:
    """FSDP/TP heuristic shardings for a param (or optimizer-state) tree."""
    axis_sizes = dict(mesh.shape)

    def one(leaf):
        return NamedSharding(mesh, _param_pspec(tuple(leaf.shape), axis_sizes))

    return jax.tree.map(one, params_shapes)


def _cache_pspec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
                 rules: ShardingRules) -> P:
    """Decode-cache shardings by leaf name.

    KV-style (L, B, S, H, D): batch -> DP; heads -> model when divisible,
    else sequence -> model (SP cache).  State-style: batch -> DP, the
    channel/head dim -> model when divisible.
    """
    ax = rules.axis_sizes
    tp = ax.get("model", 1)
    dp = math.prod(ax.get(a, 1) for a in rules.batch_axes)
    name = path.rsplit("/", 1)[-1].rsplit(".", 1)[-1]

    def batch_spec(b):
        return rules.batch_axes if (dp > 1 and b % dp == 0) else None

    if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
        L, b, s, h, d = shape
        bspec = batch_spec(b)
        if tp > 1 and h % tp == 0:
            return P(None, bspec, None, "model", None)
        # seq-shard only when the policy enabled SP (cache too big for
        # batch sharding) — otherwise replicate heads: collective-free
        if rules.seq_axis and tp > 1 and s % tp == 0:
            return P(None, bspec, "model", None, None)
        return P(None, bspec, None, None, None)
    if name == "conv" and len(shape) == 4:
        L, b, k, c = shape
        return P(None, batch_spec(b), None,
                 "model" if (tp > 1 and c % tp == 0) else None)
    if name in ("ssm", "wkv") and len(shape) == 5:
        L, b, h = shape[:3]
        return P(None, batch_spec(b),
                 "model" if (tp > 1 and h % tp == 0) else None, None, None)
    if name.startswith("shift") and len(shape) == 3:
        L, b, d = shape
        return P(None, batch_spec(b),
                 "model" if (tp > 1 and d % tp == 0) else None)
    # fallback: batch dim at index 1 if it matches, else replicate
    if len(shape) >= 2:
        return P(None, batch_spec(shape[1]), *([None] * (len(shape) - 2)))
    return P()


def cache_shardings(cfg: ModelConfig, caches_shapes: Any,
                    rules: ShardingRules) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(caches_shapes)[0]
    treedef = jax.tree.structure(caches_shapes)
    shardings = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        spec = _cache_pspec(key, tuple(leaf.shape), cfg, rules)
        shardings.append(NamedSharding(rules.mesh, spec))
    return jax.tree.unflatten(treedef, shardings)


def batch_shardings(batch_specs: Any, rules: ShardingRules) -> Any:
    """Input batches: leading dim -> DP axes (when divisible), rest replicated."""
    dp = math.prod(rules.axis_sizes.get(a, 1) for a in rules.batch_axes)

    def one(leaf):
        if leaf.ndim >= 1 and dp > 1 and leaf.shape[0] % dp == 0:
            return NamedSharding(rules.mesh,
                                 P(rules.batch_axes, *([None] * (leaf.ndim - 1))))
        return NamedSharding(rules.mesh, P())

    return jax.tree.map(one, batch_specs)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
