"""Per-family transformer blocks with a uniform (x, cache) -> (x, cache, aux)
interface so the layer-scan machinery in ``lm.py`` is family-agnostic.

Block params are plain dicts; stacking a block L times (vmapped init) gives
the scanned parameter tree.  ``cache`` is family-specific: KVCache for
attention blocks, SSMState for Mamba2, RWKVState for RWKV6; ``None`` in
training (no cache threading).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import (
    AttentionSpec,
    MLPSpec,
    MoESpec,
    RWKVSpec,
    SSMSpec,
    attention_apply,
    attention_init,
    mlp_apply,
    mlp_init,
    moe_apply,
    moe_init,
    rmsnorm,
    rmsnorm_init,
    rwkv_channel_mix,
    rwkv_init,
    rwkv_time_mix,
    ssm_apply,
    ssm_init,
)
from repro.nn.rwkv import RWKVState
from .config import ModelConfig


# ---------------------------------------------------------------------------
# spec builders
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, name: str = "attn", causal: bool = True) -> AttentionSpec:
    return AttentionSpec(
        name=name,
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        rope=cfg.rope,
        qkv_bias=cfg.qkv_bias,
        causal=causal,
        q_chunk=cfg.q_chunk,
        tt=cfg.tt,
    )


def mlp_spec(cfg: ModelConfig, name: str = "mlp") -> MLPSpec:
    return MLPSpec(name, cfg.d_model, cfg.d_ff, cfg.mlp_kind, cfg.tt)


def moe_spec(cfg: ModelConfig, name: str = "moe") -> MoESpec:
    return MoESpec(
        name=name,
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        n_experts=cfg.moe_experts,
        top_k=cfg.moe_top_k,
        n_shared=cfg.moe_shared,
        shared_d_ff=cfg.moe_shared_d_ff,
        capacity_factor=cfg.capacity_factor,
        kind=cfg.mlp_kind,
        tt=cfg.tt,
    )


def ssm_spec(cfg: ModelConfig, name: str = "ssm") -> SSMSpec:
    return SSMSpec(
        name=name,
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim,
        tt=cfg.tt,
    )


def rwkv_spec(cfg: ModelConfig, name: str = "rwkv") -> RWKVSpec:
    return RWKVSpec(
        name=name,
        d_model=cfg.d_model,
        head_dim=cfg.hd,
        d_ff=cfg.d_ff,
        tt=cfg.tt,
    )


# ---------------------------------------------------------------------------
# blocks — init
# ---------------------------------------------------------------------------

def block_init(rng: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """One decoder block of cfg.family (hybrid = one Mamba layer)."""
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    if cfg.family in ("dense", "vlm", "encdec"):
        return {
            "ln1": rmsnorm_init(d, dtype),
            "attn": attention_init(k1, attn_spec(cfg), dtype),
            "ln2": rmsnorm_init(d, dtype),
            "mlp": mlp_init(k2, mlp_spec(cfg), dtype),
        }
    if cfg.family == "moe":
        return {
            "ln1": rmsnorm_init(d, dtype),
            "attn": attention_init(k1, attn_spec(cfg), dtype),
            "ln2": rmsnorm_init(d, dtype),
            "moe": moe_init(k2, moe_spec(cfg), dtype),
        }
    if cfg.family == "hybrid":
        return {
            "ln": rmsnorm_init(d, dtype),
            "ssm": ssm_init(k1, ssm_spec(cfg), dtype),
        }
    if cfg.family == "rwkv":
        return {
            "ln1": rmsnorm_init(d, dtype),
            "tm": rwkv_init(k1, rwkv_spec(cfg), dtype),
            "ln2": rmsnorm_init(d, dtype),
        }
    raise ValueError(cfg.family)


def shared_attn_init(rng: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """Zamba2's shared attention block (one parameter set, applied G times)."""
    return {
        "ln": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(rng, attn_spec(cfg, name="shared_attn"), dtype),
    }


# ---------------------------------------------------------------------------
# blocks — apply
# ---------------------------------------------------------------------------

def block_apply(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    positions: Optional[jax.Array],
    cache,
    cache_pos,
):
    """Returns (x, new_cache, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm", "encdec", "moe"):
        h, new_cache = attention_apply(
            attn_spec(cfg), params["attn"], rmsnorm(params["ln1"], x),
            positions, cache, cache_pos,
        )
        x = x + h
        if cfg.family == "moe":
            h2, aux = moe_apply(moe_spec(cfg), params["moe"], rmsnorm(params["ln2"], x))
            return x + h2, new_cache, aux
        h2 = mlp_apply(mlp_spec(cfg), params["mlp"], rmsnorm(params["ln2"], x))
        return x + h2, new_cache, zero
    if cfg.family == "hybrid":
        h, new_state = ssm_apply(ssm_spec(cfg), params["ssm"], rmsnorm(params["ln"], x), cache)
        return x + h, new_state, zero
    if cfg.family == "rwkv":
        h, shift_tm, wkv = rwkv_time_mix(
            rwkv_spec(cfg), params["tm"], rmsnorm(params["ln1"], x), cache
        )
        x = x + h
        h2, shift_cm = rwkv_channel_mix(
            rwkv_spec(cfg), params["tm"], rmsnorm(params["ln2"], x), cache
        )
        x = x + h2
        new_cache = None
        if cache is not None:
            new_cache = RWKVState(shift_tm=shift_tm, shift_cm=shift_cm, wkv=wkv)
        return x, new_cache, zero
    raise ValueError(cfg.family)


def shared_attn_apply(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    positions: Optional[jax.Array],
    cache,
    cache_pos,
):
    h, new_cache = attention_apply(
        attn_spec(cfg, name="shared_attn"), params["attn"],
        rmsnorm(params["ln"], x), positions, cache, cache_pos,
    )
    return x + h, new_cache
