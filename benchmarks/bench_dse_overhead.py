"""DSE search overhead — the paper claims "minimal overhead" for the
hierarchical search vs brute force.  Times the three stages (top-K path
search, cost-table fill, global argmin) per model, reports the scalar
per-cell oracle vs the batched NumPy cost-table engine side by side
(``table_scalar_s`` / ``table_vectorized_s`` / ``table_speedup``), and
the brute-force alternative's combinatorial size.
"""

from __future__ import annotations

import time

from repro.core import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS as ALL_PARTS,
    FPGA_VU9P,
    STRATEGY_SPACE,
    build_cost_tables,
    find_topk_paths,
    global_search,
)
from repro.core.dse import build_cost_table
from repro.dse_cli import model_dse_layers
from repro.configs import get_config
from repro.models.vision import model_layers
from .common import emit


def _workloads():
    for model, dataset in [("resnet18", "cifar10"), ("vit_ti4", "cifar10")]:
        nets = [l.tt_network for l in model_layers(model, dataset, batch=1)]
        yield f"{model}/{dataset}", nets
    nets = [tn for _, tn in model_dse_layers(get_config("tt-lm-100m"), tokens=1024)]
    yield "tt-lm-100m", nets


def run() -> list[dict]:
    rows = []
    for name, nets in _workloads():
        t0 = time.perf_counter()
        layer_paths = [find_topk_paths(tn, k=4) for tn in nets]
        t_paths = time.perf_counter() - t0

        t0 = time.perf_counter()
        scalar = build_cost_table(layer_paths, FPGA_VU9P, ALL_PARTS,
                                  engine="scalar")
        t_scalar = time.perf_counter() - t0
        tables = build_cost_tables(layer_paths, FPGA_VU9P, ALL_PARTS)
        assert tables.seconds == scalar  # engines must agree bit-for-bit

        t0 = time.perf_counter()
        res = global_search(layer_paths, FPGA_VU9P, table=tables.seconds)
        t_argmin = time.perf_counter() - t0
        assert res.total_latency_s > 0

        brute = 0
        for h, cs in STRATEGY_SPACE.items():
            combo = 1
            for p in layer_paths:
                combo *= len(p) * len(cs) * len(ALL_DATAFLOWS)
            brute += combo
        rows.append({
            "model": name,
            "layers": len(nets),
            "path_search_s": t_paths,
            "table_scalar_s": t_scalar,
            "table_vectorized_s": tables.build_seconds,
            "table_speedup": t_scalar / tables.build_seconds,
            "argmin_s": t_argmin,
            "table_cells": tables.n_cells,
            "unique_gemm_evals": tables.n_unique_gemm_evals,
            "hierarchical_evals": sum(
                len(p) * len(ALL_PARTS) * len(ALL_DATAFLOWS)
                for p in layer_paths),
            "brute_force_combos": float(brute),
        })
    emit("bench_dse_overhead", rows)
    return rows


if __name__ == "__main__":
    run()
