"""Core contribution: tensor-network DSE for tensorized layers.

Public API: tensor-network builders, MAC-guided top-K path search, the
systolic latency simulator (FPGA + TPU parameterizations), Algorithm-1
global search, TT-SVD, and the jit-safe path executor.
"""

from .tensor_network import (
    GemmShape,
    Node,
    TensorNetwork,
    dense_linear_network,
    factorize,
    tt_conv_network,
    tt_linear_network,
)
from .paths import CandidatePath, find_topk_paths, greedy_path, reconstruction_path
from .simulator import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS,
    STRATEGY_SPACE,
    Dataflow,
    FPGA_VU9P,
    HardwareConfig,
    Partitioning,
    gemm_latency,
    layer_latency,
    simulate,
)
from .tpu_cost import TPU_V5E
from .cost_table import (
    BackwardChoice,
    CostTables,
    TrainCostTables,
    build_cost_table_vectorized,
    build_cost_tables,
    build_cost_tables_hw,
    build_train_cost_tables,
    build_train_cost_tables_hw,
)
from .backward import (
    BackwardProblem,
    LayerBackward,
    TrainCostWeights,
    backward_networks,
    grad_core_network,
    grad_input_network,
    layer_backward,
    memoised_layer_backwards,
    update_seconds,
)
from .dse import (
    DSEResult,
    HwCandidateResult,
    LayerChoice,
    apply_calibration,
    brute_force_search,
    build_cost_table,
    explore_model,
    global_search,
    pareto_front,
)
from .tt import TTMatrix, reconstruction_error, tt_rand, tt_svd
from .contraction import core_tensors, execute_path

__all__ = [
    "GemmShape", "Node", "TensorNetwork", "dense_linear_network", "factorize",
    "tt_conv_network", "tt_linear_network",
    "CandidatePath", "find_topk_paths", "greedy_path", "reconstruction_path",
    "ALL_DATAFLOWS", "ALL_PARTITIONINGS", "STRATEGY_SPACE", "Dataflow",
    "FPGA_VU9P", "HardwareConfig", "Partitioning", "gemm_latency",
    "layer_latency", "simulate", "TPU_V5E",
    "CostTables", "build_cost_table", "build_cost_table_vectorized",
    "build_cost_tables", "build_cost_tables_hw",
    "BackwardChoice", "TrainCostTables", "build_train_cost_tables",
    "build_train_cost_tables_hw",
    "BackwardProblem", "LayerBackward", "TrainCostWeights",
    "backward_networks", "grad_core_network", "grad_input_network",
    "layer_backward", "memoised_layer_backwards", "update_seconds",
    "DSEResult", "HwCandidateResult", "LayerChoice", "apply_calibration",
    "brute_force_search", "explore_model", "global_search", "pareto_front",
    "TTMatrix", "reconstruction_error", "tt_rand", "tt_svd",
    "core_tensors", "execute_path",
]
