"""Candidate encoding + variation operators for the guided joint search.

A :class:`Genome` is one point of the joint design space — the
architecture candidate index, the global partitioning strategy, and one
``(path index, partitioning, dataflow)`` gene per layer.  It is exactly
the coordinate system of the exhaustive search's cost table
``T[arch][l, p, c, d]`` restricted to a strategy, which is what makes a
genome *scoreable* by pure table reads: no new simulator machinery, the
guided driver and the exhaustive oracle consume the same numbers.

:class:`JointSpace` owns the variation operators:

- ``random_genome`` — uniform draw (population seeding);
- ``mutate`` — local moves: an architecture step to a *neighboring*
  candidate (L1-nearest in ``hw.arch_coordinates`` — the searched knobs
  are geometric, so adjacent grid points have similar cost surfaces), a
  strategy flip, and per-layer gene redraws;
- ``crossover`` — uniform per-layer gene mix of two parents under one
  parent's (arch, strategy).

Every operator *repairs* as it goes — a gene's partitioning is always
drawn from the genome's own strategy's ``C_h`` — so genomes are valid
table coordinates by construction and scoring never needs a feasibility
check.  All randomness flows through the caller's ``random.Random``;
the same seed replays the same proposal sequence bit-for-bit
(determinism is a tested property of the driver).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Mapping, Sequence

from repro.core.paths import CandidatePath
from repro.core.simulator import (
    ALL_DATAFLOWS,
    STRATEGY_SPACE,
    Dataflow,
    Partitioning,
)
from repro.hw import HardwareConfig, arch_coordinates

#: how many L1-nearest candidates count as an architecture's neighborhood
ARCH_NEIGHBORS = 4

#: one layer's gene: (path index, partitioning, dataflow)
LayerGene = tuple  # tuple[int, Partitioning, Dataflow]


@dataclasses.dataclass(frozen=True)
class Genome:
    """One joint-space point: architecture + strategy + per-layer genes."""

    arch: int
    strategy: str
    genes: tuple[LayerGene, ...]

    def keys(self):
        """The cost-table cells this genome's score sums over."""
        return [(l, p, c, d) for l, (p, c, d) in enumerate(self.genes)]


class JointSpace:
    """The searched joint space + its mutation/crossover operators."""

    def __init__(
        self,
        layer_paths: Sequence[Sequence[CandidatePath]],
        hw_space: Sequence[HardwareConfig],
        strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
        dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    ) -> None:
        if not hw_space:
            raise ValueError("hw_space must contain at least one candidate")
        if not all(paths for paths in layer_paths):
            raise ValueError("every layer needs at least one candidate path")
        self.layer_paths = tuple(tuple(p) for p in layer_paths)
        self.hw_space = tuple(hw_space)
        self.strategy_space = {h: tuple(cs)
                               for h, cs in strategy_space.items()}
        self.strategies = tuple(self.strategy_space)
        self.dataflows = tuple(dataflows)
        # L1-nearest candidates per architecture (ties to the lower index)
        coords = arch_coordinates(self.hw_space)
        self.arch_neighbors: tuple[tuple[int, ...], ...] = tuple(
            tuple(sorted(
                (j for j in range(len(coords)) if j != i),
                key=lambda j: (sum(abs(a - b)
                                   for a, b in zip(coords[i], coords[j])), j),
            )[:ARCH_NEIGHBORS])
            for i in range(len(coords))
        )

    # -- construction ------------------------------------------------------
    def _random_gene(self, l: int, c_h: Sequence[Partitioning],
                     rng: random.Random) -> LayerGene:
        return (rng.randrange(len(self.layer_paths[l])),
                c_h[rng.randrange(len(c_h))],
                self.dataflows[rng.randrange(len(self.dataflows))])

    def random_genome(self, rng: random.Random) -> Genome:
        strategy = self.strategies[rng.randrange(len(self.strategies))]
        c_h = self.strategy_space[strategy]
        genes = tuple(self._random_gene(l, c_h, rng)
                      for l in range(len(self.layer_paths)))
        return Genome(rng.randrange(len(self.hw_space)), strategy, genes)

    def encode_choices(self, arch: int, strategy: str, choices) -> Genome:
        """Re-encode a refined result's per-layer choices as a genome."""
        genes = tuple((c.path_index, c.partitioning, c.dataflow)
                      for c in choices)
        return Genome(arch, strategy, genes)

    # -- variation ---------------------------------------------------------
    def _repair(self, genes, strategy: str,
                rng: random.Random) -> tuple[LayerGene, ...]:
        c_h = self.strategy_space[strategy]
        out = []
        for l, (p, c, d) in enumerate(genes):
            if c not in c_h:
                c = c_h[rng.randrange(len(c_h))]
            out.append((p, c, d))
        return tuple(out)

    def mutate(self, g: Genome, rng: random.Random) -> Genome:
        arch, strategy, genes = g.arch, g.strategy, list(g.genes)
        r = rng.random()
        if r < 0.4 and len(self.hw_space) > 1:
            # local architecture step; occasionally a uniform jump so the
            # search cannot get trapped in one grid region
            nbrs = self.arch_neighbors[arch]
            if rng.random() < 0.75 and nbrs:
                arch = nbrs[rng.randrange(len(nbrs))]
            else:
                arch = rng.randrange(len(self.hw_space))
        elif r < 0.6 and len(self.strategies) > 1:
            others = [h for h in self.strategies if h != strategy]
            strategy = others[rng.randrange(len(others))]
        # always perturb one layer's gene: each component redrawn by coin
        l = rng.randrange(len(genes))
        p, c, d = genes[l]
        c_h = self.strategy_space[strategy]
        if rng.random() < 0.5:
            p = rng.randrange(len(self.layer_paths[l]))
        if rng.random() < 0.5:
            c = c_h[rng.randrange(len(c_h))]
        if rng.random() < 0.5:
            d = self.dataflows[rng.randrange(len(self.dataflows))]
        genes[l] = (p, c, d)
        return Genome(arch, strategy, self._repair(genes, strategy, rng))

    def crossover(self, a: Genome, b: Genome,
                  rng: random.Random) -> Genome:
        lead, other = (a, b) if rng.random() < 0.5 else (b, a)
        genes = tuple(
            ga if rng.random() < 0.5 else gb
            for ga, gb in zip(lead.genes, other.genes)
        )
        return Genome(lead.arch, lead.strategy,
                      self._repair(genes, lead.strategy, rng))
