"""Quickstart: tensorize one layer, search paths, run the DSE, execute.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FPGA_VU9P,
    TPU_V5E,
    explore_model,
    find_topk_paths,
    tt_linear_network,
)
from repro.nn import LinearSpec, TTConfig, linear_apply, linear_init

# 1. A 1024 -> 4096 projection, TT-factorized at rank 16 --------------------
tt = TTConfig(enabled=True, d=3, rank=16, min_dim=512)
spec = LinearSpec("demo", 1024, 4096, tag="mlp", tt=tt)
print(f"dense params: {1024 * 4096:,}   TT params: {spec.n_params():,} "
      f"({1024 * 4096 / spec.n_params():.1f}x compression)")

# 2. The layer as a tensor network; MAC-guided top-K path search ------------
tn = tt_linear_network(batch=256, in_modes=spec.in_modes,
                       out_modes=spec.out_modes, ranks=spec.tt_ranks)
paths = find_topk_paths(tn, k=4)
print("top-K path MACs:", [f"{p.macs:,}" for p in paths])
print(f"dense GEMM MACs: {256 * 1024 * 4096:,}")

# 3. Global latency-driven DSE (Algorithm 1) over (path, split, dataflow) ---
for hw in (FPGA_VU9P, TPU_V5E):
    res = explore_model([tn], hw, top_k=4)
    c = res.choices[0]
    print(f"{hw.name}: strategy={res.strategy} path={c.path_index} "
          f"partition={c.partitioning} dataflow={c.dataflow.value} "
          f"latency={c.latency_s * 1e6:.1f} us")

# 4. Execute the layer (the DSE-chosen path drives the contraction order) ---
params = linear_init(jax.random.PRNGKey(0), spec)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
y = jax.jit(lambda p, x: linear_apply(spec, p, x))(params, x)
print("forward:", x.shape, "->", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))
