"""RWKV-6 (Finch) block: data-dependent decay WKV, chunked for training.

Time-mix: token-shift interpolation with data-dependent mix (ddlerp via a
low-rank MLP), R/K/V/G projections, per-channel data-dependent decay
``w_t`` (LoRA-conditioned), bonus ``u`` for the current token, grouped
heads with per-head (key x value) state matrices.

The WKV recurrence
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
is evaluated chunk-parallel (same decomposition as the SSD kernel: an
intra-chunk lower-triangular attention term + an inter-chunk state scan),
so training is GEMM-dominated; decode advances S one token at a time.

Channel-mix: squared-ReLU MLP with token shift.  All projections route
through ``repro.nn.linear`` — tensorizable like every other arch.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard
from .linear import LinearSpec, TTConfig, linear_apply, linear_init


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    name: str
    d_model: int
    head_dim: int = 64
    d_ff: Optional[int] = None          # channel-mix width (default 3.5x)
    lora_r: int = 64                    # decay/mix LoRA rank
    chunk: int = 16                     # see _wkv_chunked numerics bound
    tt: Optional[TTConfig] = None

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim

    @property
    def ffn(self) -> int:
        return self.d_ff if self.d_ff else int(3.5 * self.d_model)

    def proj(self, tag: str, d_out: Optional[int] = None) -> LinearSpec:
        return LinearSpec(
            f"{self.name}.{tag}", self.d_model, d_out or self.d_model,
            False, "attn", self.tt,
        )


class RWKVState(NamedTuple):
    shift_tm: jax.Array   # (B, D) last token (time-mix shift)
    shift_cm: jax.Array   # (B, D) last token (channel-mix shift)
    wkv: jax.Array        # (B, H, N, N) per-head key->value state


def rwkv_init(rng: jax.Array, spec: RWKVSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, 10)
    d, r = spec.d_model, spec.lora_r
    h, n = spec.n_heads, spec.head_dim

    def lora(k, d_out):
        k1, k2 = jax.random.split(k)
        return {
            "a": (jax.random.normal(k1, (d, r)) * 0.01).astype(dtype),
            "b": (jax.random.normal(k2, (r, d_out)) * 0.01).astype(dtype),
        }

    return {
        "mix_base": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "mix_lora": lora(ks[1], 5 * d),
        "wr": linear_init(ks[2], spec.proj("wr"), dtype),
        "wk": linear_init(ks[3], spec.proj("wk"), dtype),
        "wv": linear_init(ks[4], spec.proj("wv"), dtype),
        "wg": linear_init(ks[5], spec.proj("wg"), dtype),
        "wo": linear_init(ks[6], spec.proj("wo"), dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),  # w ~ exp(-exp(-6)) ~ .9975
        "decay_lora": lora(ks[7], d),
        "bonus": (jax.random.normal(ks[8], (h, n)) * 0.05).astype(jnp.float32),
        "ln_x_scale": jnp.ones((d,), dtype),
        # channel mix
        "cm_mix": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25).astype(dtype),
        "cm_k": linear_init(jax.random.fold_in(rng, 1), spec.proj("cmk", spec.ffn), dtype),
        "cm_v": linear_init(jax.random.fold_in(rng, 2), spec.proj("cmv"), dtype),
        "cm_r": linear_init(jax.random.fold_in(rng, 3), LinearSpec(
            f"{spec.name}.cmr", spec.ffn, spec.d_model, False, "attn", spec.tt), dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """Previous token per position; ``last`` fills position 0 (decode cache)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1, :])
    else:
        last = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def _wkv_chunked(
    r: jax.Array,      # (B, S, H, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,   # (B, S, H, N) log-decay (< 0)
    bonus: jax.Array,  # (H, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B, H, N, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV.  Returns (y (B,S,H,N), final_state).

    Numerics: the intra-chunk factorisation
    ``exp(cum_{i-1} - cum_j) = exp(cum_{i-1} - cum_mid) * exp(cum_mid - cum_j)``
    is anchored at the chunk *midpoint*, so each factor's exponent is
    bounded by ``(chunk/2) * |logw|``; with ``logw`` clamped at -7.5 and
    chunk 16 the worst exponent is 60 < fp32's exp overflow (~88).  All
    inter-chunk factors are boundary-anchored and always <= 1.
    """
    b, s, h, n = r.shape
    l = min(chunk, s)
    if s % l:
        l = s
    c = s // l
    rc = r.reshape(b, c, l, h, n)
    kc = k.reshape(b, c, l, h, n)
    vc = v.reshape(b, c, l, h, n)
    wc = logw.reshape(b, c, l, h, n)
    cum = jnp.cumsum(wc, axis=2)                       # (b,c,l,h,n)
    mid = cum[:, :, l // 2 : l // 2 + 1]               # midpoint anchor

    # intra-chunk: y_i <- sum_{j<i} (r_i . exp(cum_{i-1}-cum_j) . k_j) v_j
    r_intra = rc * jnp.exp(cum - wc - mid).astype(r.dtype)
    k_intra = kc * jnp.exp(mid - cum).astype(r.dtype)
    att = jnp.einsum("bcihn,bcjhn->bchij", r_intra, k_intra)
    mask = jnp.tril(jnp.ones((l, l), bool), k=-1)       # strictly lower
    att = jnp.where(mask[None, None, None], att, 0.0)
    # diagonal bonus term: (r_i . u . k_i) v_i
    diag = jnp.einsum("bcihn,hn,bcihn->bcih", rc, bonus.astype(r.dtype), kc)
    y = jnp.einsum("bchij,bcjhn->bcihn", att.astype(r.dtype), vc)
    y = y + diag[..., None] * vc

    # inter-chunk: carry S; y_i += (r_i * exp(cum_{i-1})) @ S_prev
    to_end = jnp.exp(cum[:, :, -1:, :, :] - cum)        # (b,c,l,h,n)
    s_chunk = jnp.einsum(
        "bclhn,bclhm->bchnm", (kc * to_end.astype(k.dtype)), vc
    )                                                   # (b,c,h,n,m) key->value
    total = cum[:, :, -1]                               # (b,c,h,n)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(carry, inp):
        s_c, tot = inp
        out = carry
        carry = carry * jnp.exp(tot)[..., None] + s_c.astype(jnp.float32)
        return carry, out

    final, s_prev = jax.lax.scan(
        step,
        init_state,
        (s_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)            # (b,c,h,n,m)
    # inter-chunk read: r_i * exp(cum_{i-1}) (chunk-start anchored, <= 1)
    r_inter = rc * jnp.exp(cum - wc).astype(r.dtype)
    y = y + jnp.einsum("bcihn,bchnm->bcihm", r_inter, s_prev.astype(r.dtype))
    return y.reshape(b, s, h, n), final


def rwkv_time_mix(
    spec: RWKVSpec,
    params: dict,
    x: jax.Array,                        # (B, S, D)
    state: Optional[RWKVState] = None,
) -> tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """Returns (y, new_shift_tm, new_wkv_state)."""
    b, s, d = x.shape
    h, n = spec.n_heads, spec.head_dim
    prev = _token_shift(x, state.shift_tm if state is not None else None)
    delta = prev - x
    # ddlerp: base mix + LoRA(x + 0.5*delta) -> 5 per-channel mixes
    lo = jnp.tanh((x + 0.5 * delta) @ params["mix_lora"]["a"]) @ params["mix_lora"]["b"]
    mixes = params["mix_base"][None, None] + lo.reshape(b, s, 5, d)
    xr, xk, xv, xg, xw = [
        x + delta * mixes[:, :, i, :] for i in range(5)
    ]
    r = linear_apply(spec.proj("wr"), params["wr"], xr).reshape(b, s, h, n)
    k = linear_apply(spec.proj("wk"), params["wk"], xk).reshape(b, s, h, n)
    v = linear_apply(spec.proj("wv"), params["wv"], xv).reshape(b, s, h, n)
    g = jax.nn.silu(linear_apply(spec.proj("wg"), params["wg"], xg))
    r = shard(r, "batch", "seq", "model", None)
    k = shard(k, "batch", "seq", "model", None)
    v = shard(v, "batch", "seq", "model", None)

    dl = jnp.tanh(xw @ params["decay_lora"]["a"]) @ params["decay_lora"]["b"]
    logw = -jnp.exp(
        (params["decay_base"][None, None] + dl.astype(jnp.float32))
    ).reshape(b, s, h, n)                                # log w_t < 0
    # clamp: w >= e^-7.5 (full forget within ~2 steps anyway); keeps the
    # chunked factorisation inside fp32 range — see _wkv_chunked numerics
    logw = jnp.maximum(logw, -7.5)

    init = state.wkv if state is not None else None
    y, final = _wkv_chunked(r, k, v, logw, params["bonus"], spec.chunk, init)
    y = y.reshape(b, s, d)
    # per-head group norm (ln_x in the reference impl)
    yg = y.reshape(b, s, h, n).astype(jnp.float32)
    mu = jnp.mean(yg, axis=-1, keepdims=True)
    var = jnp.var(yg, axis=-1, keepdims=True)
    yg = (yg - mu) * jax.lax.rsqrt(var + 1e-5)
    y = (yg.reshape(b, s, d) * params["ln_x_scale"].astype(jnp.float32)).astype(x.dtype)
    out = linear_apply(spec.proj("wo"), params["wo"], y * g)
    new_shift = x[:, -1, :] if state is not None else None
    return shard(out, "batch", "seq", None), new_shift, (final if state is not None else None)


def rwkv_channel_mix(
    spec: RWKVSpec,
    params: dict,
    x: jax.Array,
    state: Optional[RWKVState] = None,
) -> tuple[jax.Array, Optional[jax.Array]]:
    prev = _token_shift(x, state.shift_cm if state is not None else None)
    delta = prev - x
    xk = x + delta * params["cm_mix"][0][None, None]
    xr = x + delta * params["cm_mix"][1][None, None]
    kk = linear_apply(spec.proj("cmk", spec.ffn), params["cm_k"], xk)
    kk = jnp.square(jax.nn.relu(kk))
    vv = linear_apply(
        LinearSpec(f"{spec.name}.cmr", spec.ffn, spec.d_model, False, "attn", spec.tt),
        params["cm_r"], kk,
    )
    rr = jax.nn.sigmoid(linear_apply(spec.proj("cmv"), params["cm_v"], xr))
    new_shift = x[:, -1, :] if state is not None else None
    return rr * vv, new_shift


def init_rwkv_state(spec: RWKVSpec, batch: int, dtype=jnp.float32) -> RWKVState:
    return RWKVState(
        shift_tm=jnp.zeros((batch, spec.d_model), dtype),
        shift_cm=jnp.zeros((batch, spec.d_model), dtype),
        wkv=jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.head_dim), jnp.float32),
    )
