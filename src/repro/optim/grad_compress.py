"""INT8 error-feedback gradient compression (cross-pod link saver).

The paper quantizes weights/activations/gradients to INT8 on the FPGA.
Our distributed analogue compresses the *gradient all-reduce payload* on
the slow cross-pod links: per-tensor symmetric INT8 quantization with an
error-feedback residual (the quantization error is carried to the next
step, so the compression is unbiased over time — Seide et al. 2014,
Karimireddy et al. 2019).

Inside a jitted train step, ``compress_decompress`` simulates the wire
format: values round-trip through int8 before entering the optimizer,
and the residual state is threaded alongside the optimizer state.  On a
real multi-pod deployment the int8 payload is what crosses the DCI; the
in-pod reduce-scatter stays bf16.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    residual: Any    # fp32 tree like grads


def compress_init(params: Any) -> CompressState:
    return CompressState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def int8_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(
    grads: Any, state: CompressState
) -> tuple[Any, CompressState]:
    """Error-feedback int8 round-trip of every gradient tensor."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = int8_quantize(g32)
        deq = int8_dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    pairs = jax.tree.map(one, grads, state.residual)
    new_grads = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, CompressState(residual=new_res)
