"""Fused path-segment kernel: a contiguous run of contraction-path steps
executed inside ONE ``pallas_call`` with VMEM-resident intermediates.

This bridges the two existing extremes: ``streaming_tt`` contracts the
*whole* path in VMEM (single streamed operand, whole-network working set
must fit), while ``tt_gemm`` launches one kernel per pairwise step and
round-trips every intermediate through HBM.  A fused segment executes the
chain runs found by ``repro.core.fusion.segment_path``: the batch-carrying
chain streams through the grid in token blocks, the batch-free operands
are pinned whole in VMEM (constant index_map), and each interior
intermediate lives in an fp32 VMEM scratch buffer — zero HBM bytes, one
kernel-launch overhead for the whole run.

Dataflow note: inside a segment every step is lowered as an OS-style
fp32 contraction with the *same* sequential k-block accumulation order
as the per-step kernels — the per-step WS/IS grid orders cannot be
preserved across a shared 1-d token grid, which is the "falling back to
OS inside a segment" rule the plan compiler and cost model assume.
Each chained step replays the per-step kernel's *exact* blocked GEMM:
the same clamped ``(block_m, block_k, block_n)`` tiles (clamped against
the full step dims, not the token-blocked kernel-local dims), the same
sequential k-block partial-sum grouping, and the dot operands are
materialized behind ``optimization_barrier`` so XLA cannot refuse the
per-step lowering by folding the surrounding transposes into the dot
(see ``_chain_step``).  fp32 fused execution is therefore bit-identical
to the per-step ``tt_gemm`` route (property-tested); with bf16 operands
it is *more* precise, because interior intermediates skip the per-step
cast back to bf16.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tt_gemm import _pad_to_block, pltpu_accumulator

#: default batch (streamed-token) edge label
BATCH_EDGE = "b"

Src = tuple[str, int]  # ("in", kernel-input position) | ("mid", op index)


def _compile_segment(entries, steps, batch_edge):
    """Symbolic replay of ``steps`` over the work-list ``entries``.

    ``entries`` is the current ``execute_path``-style work list as
    ``(edges, shape)`` pairs; ``steps`` are current-index ``(i, j)`` pairs
    forming a chain (each step after the first consumes the previous
    step's result).  Returns ``(input_positions, ops, mids)``:

      * ``input_positions`` — work-list indices of the original entries
        the segment reads, in first-use order (the kernel input order);
      * ``ops`` — per step ``(a_src, b_src, ax_a, ax_b, (m, k, n))``
        with sources in ``("in", pos)`` / ``("mid", t)`` space and the
        *full* flattened GEMM dims of the step (actual batch size — the
        dims the per-step route clamps its blocks against);
      * ``mids`` — per step ``(edges, dims)`` of its result (actual batch
        size; the caller re-blocks).

    Axis bookkeeping is copied verbatim from
    ``repro.core.contraction.execute_path`` so the fused result is
    element-for-element the sequential one.
    """
    sym: list[tuple[tuple[str, ...], tuple[int, ...], Src]] = []
    for pos, (edges, shape) in enumerate(entries):
        sym.append((tuple(edges), tuple(shape), ("in", pos)))

    input_positions: list[int] = []
    in_slot: dict[int, int] = {}
    ops: list[tuple] = []
    mids: list[tuple[tuple[str, ...], tuple[int, ...]]] = []

    def as_kernel_src(src: Src) -> Src:
        kind, idx = src
        if kind == "mid":
            return src
        if idx not in in_slot:
            in_slot[idx] = len(input_positions)
            input_positions.append(idx)
        return ("in", in_slot[idx])

    for t, (i, j) in enumerate(steps):
        (ea, da, sa), (eb, db, sb) = sym[i], sym[j]
        if t > 0 and ("mid", t - 1) not in (sa, sb):
            raise ValueError(
                f"segment step {t} does not consume the previous result "
                "(not a chain)")
        if sa[0] == "mid" and sb[0] == "mid":
            raise ValueError(f"segment step {t} joins two interior results")
        n_batch = (batch_edge in ea) + (batch_edge in eb)
        if n_batch != 1:
            raise ValueError(
                f"segment step {t}: need exactly one batch-carrying "
                f"operand, found {n_batch}")
        shared = [e for e in ea if e in eb]
        ax_a = tuple(ea.index(e) for e in shared)
        ax_b = tuple(eb.index(e) for e in shared)
        ec = tuple(e for e in ea if e not in shared) + tuple(
            e for e in eb if e not in shared)
        dc = tuple(d for e, d in zip(ea, da) if e not in shared) + tuple(
            d for e, d in zip(eb, db) if e not in shared)
        m_full = math.prod(d for e, d in zip(ea, da) if e not in shared)
        n_full = math.prod(d for e, d in zip(eb, db) if e not in shared)
        k_full = math.prod(da[ax] for ax in ax_a)
        ops.append((as_kernel_src(sa), as_kernel_src(sb), ax_a, ax_b,
                    (m_full, k_full, n_full)))
        mids.append((ec, dc))
        sym = [s for k, s in enumerate(sym) if k not in (i, j)]
        sym.append((ec, dc, ("mid", t)))
    return input_positions, ops, mids


def _clamp_block(block: int, dim: int) -> int:
    # local copy of ops.clamp_block (ops imports this module)
    p = 1
    while p < dim:
        p *= 2
    return max(8, min(block, p))


def _chain_step(a, b, ax_a, ax_b, full_dims, block_m, block_k, block_n):
    """One pairwise contraction, mirroring the per-step GEMM route exactly.

    Operands are transposed/flattened to (M, K) @ (K, N) with the same
    axis bookkeeping as ``ops.gemm_contract``, then tiled with the same
    clamped blocks the per-step route would use — ``full_dims`` are the
    step's full (un-token-blocked) flattened GEMM dims, because that is
    what ``gemm_contract`` clamps against.  Each output block accumulates
    its k-blocks *sequentially* from a zero fp32 accumulator (the
    per-step OS grouping; WS/IS agree after the fp32 output fix), and
    every dot sees an ``optimization_barrier``-materialized block of
    exactly the per-step kernel's shape, so XLA lowers the same GEMM in
    both routes and the fused result is bit-identical to the
    spill-per-step route.
    """
    m_full, k_full, n_full = full_dims
    a_free = [i for i in range(a.ndim) if i not in ax_a]
    b_free = [i for i in range(b.ndim) if i not in ax_b]
    a_dims = [a.shape[i] for i in a_free]
    b_dims = [b.shape[i] for i in b_free]
    m = math.prod(a_dims)
    n = math.prod(b_dims)
    k = math.prod(a.shape[i] for i in ax_a)
    a2 = jnp.transpose(a, a_free + list(ax_a)).reshape(m, k)
    b2 = jnp.transpose(b, list(ax_b) + b_free).reshape(k, n)
    bm = _clamp_block(block_m, m_full)
    bk = _clamp_block(block_k, k_full)
    bn = _clamp_block(block_n, n_full)
    a2 = _pad_to_block(_pad_to_block(a2, 0, bm), 1, bk)
    b2 = _pad_to_block(_pad_to_block(b2, 0, bk), 1, bn)
    n_m, n_k, n_n = a2.shape[0] // bm, a2.shape[1] // bk, b2.shape[1] // bn
    rows = []
    for mi in range(n_m):
        cols = []
        for ni in range(n_n):
            acc = jnp.zeros((bm, bn), jnp.float32)
            for kb in range(n_k):
                ab = jax.lax.optimization_barrier(
                    a2[mi * bm:(mi + 1) * bm, kb * bk:(kb + 1) * bk])
                bb = jax.lax.optimization_barrier(
                    b2[kb * bk:(kb + 1) * bk, ni * bn:(ni + 1) * bn])
                acc = acc + jnp.dot(ab, bb,
                                    preferred_element_type=jnp.float32)
            cols.append(acc)
        rows.append(jnp.concatenate(cols, axis=1) if len(cols) > 1
                    else cols[0])
    c = jnp.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]
    return c[:m, :n].reshape(tuple(a_dims) + tuple(b_dims))


def _kernel(*refs, ops, n_in, block_m, block_k, block_n):
    in_vals = [refs[k][...] for k in range(n_in)]
    o_ref = refs[n_in]
    scratch = refs[n_in + 1:]

    def val(src):
        kind, idx = src
        return in_vals[idx] if kind == "in" else scratch[idx][...]

    for t, (a_src, b_src, ax_a, ax_b, full_dims) in enumerate(ops):
        res = _chain_step(val(a_src), val(b_src), ax_a, ax_b, full_dims,
                          block_m, block_k, block_n)
        if t < len(ops) - 1:
            scratch[t][...] = res
        else:
            o_ref[...] = res.astype(o_ref.dtype)


def fused_segment_contract(
    work: Sequence[tuple[tuple[str, ...], jax.Array]],
    steps: Sequence[tuple[int, int]],
    *,
    block_tokens: int = 256,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    batch_edge: str = BATCH_EDGE,
    out_dtype=None,
    interpret: bool = False,
) -> tuple[tuple[str, ...], jax.Array]:
    """Execute the chain run ``steps`` over ``work`` in one ``pallas_call``.

    ``work`` is the live ``execute_path`` work list (``(edges, tensor)``
    pairs); ``steps`` are current-index pairs relative to it.  Returns
    ``(result_edges, result)`` — the same entry the sequential per-step
    route would append, so the caller's bookkeeping is unchanged.  The
    token dim is padded to the block multiple and sliced back (padding
    rows are zeros and the batch edge is never contracted inside a
    segment, so kept rows are exact).
    """
    if len(steps) < 2:
        raise ValueError("fused segments need at least two steps")
    entries = [(edges, tuple(t.shape)) for edges, t in work]
    input_positions, ops, mids = _compile_segment(entries, steps, batch_edge)
    arrays = [work[p][1] for p in input_positions]

    stream_slot = None
    for slot, p in enumerate(input_positions):
        if batch_edge in work[p][0]:
            if stream_slot is not None:
                raise ValueError("multiple batch-carrying segment inputs")
            stream_slot = slot
    if stream_slot is None:
        raise ValueError("segment has no batch-carrying input")
    stream_edges = work[input_positions[stream_slot]][0]
    bpos = stream_edges.index(batch_edge)
    tokens = arrays[stream_slot].shape[bpos]
    block_tokens = min(block_tokens, max(1, tokens))
    padded = arrays[stream_slot]
    padded = _pad_to_block(padded, bpos, block_tokens)
    pt = padded.shape[bpos]
    arrays = list(arrays)
    arrays[stream_slot] = padded
    grid = (pt // block_tokens,)

    def block_dims(edges, dims):
        return tuple(block_tokens if e == batch_edge else d
                     for e, d in zip(edges, dims))

    in_specs = []
    for slot, p in enumerate(input_positions):
        edges = work[p][0]
        shape = tuple(work[p][1].shape)
        if slot == stream_slot:
            bshape = block_dims(edges, shape)
            in_specs.append(pl.BlockSpec(
                bshape,
                functools.partial(
                    lambda g, bp, nd: tuple(g if ax == bp else 0
                                            for ax in range(nd)),
                    bp=bpos, nd=len(bshape))))
        else:
            in_specs.append(pl.BlockSpec(
                shape,
                functools.partial(lambda g, nd=len(shape): (0,) * nd)))

    out_edges, out_dims = mids[-1]
    opos = out_edges.index(batch_edge)
    out_block = block_dims(out_edges, out_dims)
    out_padded = tuple(pt if ax == opos else d
                       for ax, d in enumerate(out_dims))
    out_spec = pl.BlockSpec(
        out_block,
        functools.partial(
            lambda g, op, nd: tuple(g if ax == op else 0
                                    for ax in range(nd)),
            op=opos, nd=len(out_block)))
    out_dtype = out_dtype or arrays[stream_slot].dtype

    scratch_shapes = [
        pltpu_accumulator(block_dims(ec, dc)) for ec, dc in mids[:-1]
    ]
    kwargs = {}
    if not interpret:
        from jax.experimental.pallas import tpu as pltpu

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        )
    y = pl.pallas_call(
        functools.partial(_kernel, ops=ops, n_in=len(arrays),
                          block_m=block_m, block_k=block_k,
                          block_n=block_n),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_padded, out_dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **kwargs,
    )(*arrays)
    if pt != tokens:
        y = jax.lax.slice_in_dim(y, 0, tokens, axis=opos)
    return out_edges, y


def segment_vmem_bytes(
    work: Sequence[tuple[tuple[str, ...], jax.Array]],
    steps: Sequence[tuple[int, int]],
    *,
    block_tokens: int,
    batch_edge: str = BATCH_EDGE,
) -> int:
    """Working-set bytes the fused call keeps resident (for diagnostics)."""
    entries = [(edges, tuple(t.shape)) for edges, t in work]
    input_positions, _, mids = _compile_segment(entries, steps, batch_edge)

    def blocked(edges, dims, itemsize):
        return itemsize * math.prod(
            block_tokens if e == batch_edge else d
            for e, d in zip(edges, dims))

    total = sum(
        blocked(work[p][0], work[p][1].shape, work[p][1].dtype.itemsize)
        for p in input_positions)
    total += sum(blocked(ec, dc, 4) for ec, dc in mids)
    return total
