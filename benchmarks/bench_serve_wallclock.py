"""BENCH_serve — wall-clock serving throughput: dense vs planned vs tuned.

The missing perf trajectory: everything before this benchmark reported
*analytic* latency; this one times the real serving loop (batched
prefill + autoregressive decode, jitted, ``block_until_ready``) and
reports tokens/s per arch for three deployments:

- **dense**    — the un-tensorized baseline (``tt=False``);
- **planned**  — the DSE plan with the compiler's heuristic kernel
  tilings (``--emit-plan`` default);
- **tuned**    — the same search, but the plan carries the autotuner's
  *measured* tilings (``repro.tune``; ``--tune cache``).

The tuned sweep always includes the heuristic tiling, so tuned >= planned
holds by construction up to measurement noise; when the measured argmin
degenerates to the heuristic plan (bit-identical artifact), the planned
measurement is reused verbatim rather than re-timed.

On CPU hosts the Pallas backends run in interpret mode — absolute
numbers are Python-speed, but the dense/planned/tuned *ratios* rank real
deployments of this machine, which is the autotuner's whole premise.

Smoke workloads additionally run the **sustained-load scheduler**
section (``sched_*`` columns): a fixed 6-request synthetic trace through
the continuous-batching scheduler (``repro.serve``) under three
deployments — the phase-specialized *plan pair* (prefill plan searched
at the prefill token count, decode plan at the decode width) vs each
plan installed alone for both phases.  The pair runs each stream under
the phase-appropriate plan, so its sustained gen tok/s should match or
beat the best single plan; per-request p50/p95 latency rides along.

  PYTHONPATH=src python -m benchmarks.run --only bench_serve
"""

from __future__ import annotations

import dataclasses
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dse_cli import run_dse_plan
from repro.launch.mesh import make_rules, make_test_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import api
from repro.models.config import ShapeConfig
from repro.nn import install_plan
from repro.serve import Scheduler, ServeEngine, ServePolicy, summarize, synthetic_trace
from repro.sharding import use_rules

from .common import RESULTS_DIR, emit

#: bench-local tuning cache — persists across runs so re-benchmarking is
#: measurement-free, but never pollutes a deployment cache
CACHE_PATH = os.path.join(RESULTS_DIR, "tuning_cache_bench.json")

#: (row name, arch, smoke, serve shape).  ``tokens`` is the DSE's
#: streamed-token assumption and equals the prefill batch x prompt, so
#: the searched/tuned ``block_tokens`` is exercised at exactly the
#: token count it was measured for.
WORKLOADS = [
    ("tt-lm-smoke", "tt-lm-100m", True,
     dict(batch=2, prompt_len=64, gen=8, tokens=128)),
    ("tt-lm-100m", "tt-lm-100m", False,
     dict(batch=4, prompt_len=128, gen=8, tokens=512)),
]

REPEATS = 3

#: sustained-load scheduler section (smoke workloads only)
SCHED_REQUESTS = 6
SCHED_REPEATS = 3
SCHED_ARRIVAL_RATE = 1.0   # mean inter-arrival gap in decode steps


def _serve_once(cfg, batch_tokens, prompt_len, gen, plan):
    """One warm serve loop; returns (prefill_s, decode_s)."""
    batch = batch_tokens.shape[0]
    max_seq = prompt_len + gen
    shape = ShapeConfig("bench", max_seq, batch, "decode")
    mesh = make_test_mesh()
    rules = make_rules(cfg, shape, mesh)
    if plan is not None:
        m = api(cfg, plan=plan)
    else:
        install_plan(None)
        m = api(cfg)
    feed = {"tokens": batch_tokens}

    with use_rules(rules):
        params = m.init_params(jax.random.PRNGKey(0))
        prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
        decode = jax.jit(make_decode_step(cfg))

        # warmup: compile both steps outside the timed region
        logits, caches = prefill(params, feed)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        decode(params, tok, caches, jnp.asarray(prompt_len, jnp.int32))[
            0].block_until_ready()

        prefill_ts, decode_ts = [], []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            logits, caches = prefill(params, feed)
            logits.block_until_ready()
            prefill_ts.append(time.perf_counter() - t0)

            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            t0 = time.perf_counter()
            for i in range(gen):
                pos = jnp.asarray(prompt_len + i, jnp.int32)
                logits, caches = decode(params, tok, caches, pos)
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            jax.block_until_ready(logits)
            decode_ts.append(time.perf_counter() - t0)
    install_plan(None)
    return statistics.median(prefill_ts), statistics.median(decode_ts)


def _throughput(batch, prompt_len, gen, prefill_s, decode_s) -> dict:
    total_tokens = batch * (prompt_len + gen)
    return {
        "prefill_ms": prefill_s * 1e3,
        "decode_ms_per_tok": decode_s / gen * 1e3,
        "prefill_tok_s": batch * prompt_len / prefill_s,
        "decode_tok_s": batch * gen / decode_s,
        "tokens_s": total_tokens / (prefill_s + decode_s),
    }


def _behavior(plan):
    """Everything the executor consumes from a plan — two plans with
    equal behavior run identical kernels regardless of provenance."""
    return sorted(
        (lp.name, lp.backend, lp.dataflow, lp.path_steps, lp.tiling,
         tuple((op.wrt, op.backend, op.path_steps, op.tiling)
               for op in lp.backward))
        for lp in plan.layers)


def _sched_run(cfg, params, reqs, n_slots, max_seq, prefill_plan,
               decode_plan) -> dict:
    """Median-gen-tok/s summary of SCHED_REPEATS warm scheduler runs."""
    shape = ShapeConfig("bench", max_seq, n_slots, "decode")
    mesh = make_test_mesh()
    with use_rules(make_rules(cfg, shape, mesh)):
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_seq=max_seq,
                          prompt_bucket=8, prefill_plan=prefill_plan,
                          decode_plan=decode_plan)
        sched = Scheduler(eng, ServePolicy(schedule="continuous"), seed=0)
        sched.run(reqs)  # warm: trace prefill/decode/admit outside timing
        runs = [summarize(sched.run(reqs)) for _ in range(SCHED_REPEATS)]
    runs.sort(key=lambda r: r["gen_tok_s"])
    return runs[len(runs) // 2]


def _bench_sched(cfg, arch, smoke, batch, prompt_len, gen, tokens,
                 prefill_single) -> dict:
    """Sustained-load columns: plan pair vs each plan alone."""
    # the pair: the workload's own plan as the prefill leg, plus a
    # decode-width search for the decode leg (phase-stamped copies)
    _, decode_single = run_dse_plan(arch, tokens=batch, smoke=smoke)
    pair_p = dataclasses.replace(prefill_single, phase="prefill")
    pair_d = dataclasses.replace(decode_single, phase="decode")

    reqs = synthetic_trace(SCHED_REQUESTS, cfg.vocab, prompt_len=prompt_len,
                           gen=gen, arrival_rate=SCHED_ARRIVAL_RATE, seed=0)
    max_seq = prompt_len + gen
    params = api(cfg).init_params(jax.random.PRNGKey(0))
    pair = _sched_run(cfg, params, reqs, batch, max_seq, pair_p, pair_d)
    only_p = _sched_run(cfg, params, reqs, batch, max_seq,
                        prefill_single, prefill_single)
    only_d = _sched_run(cfg, params, reqs, batch, max_seq,
                        decode_single, decode_single)
    best_single = max(only_p["gen_tok_s"], only_d["gen_tok_s"])
    return {
        "sched_n_requests": SCHED_REQUESTS,
        "sched_slots": batch,
        "sched_steps": pair["steps"],
        "sched_occupancy": pair["mean_occupancy"],
        "sched_gen_tok_s_pair": pair["gen_tok_s"],
        "sched_gen_tok_s_prefill_plan": only_p["gen_tok_s"],
        "sched_gen_tok_s_decode_plan": only_d["gen_tok_s"],
        "sched_pair_vs_best_single": pair["gen_tok_s"] / best_single,
        "sched_ttft_p50_ms_pair": pair["ttft_p50_ms"],
        "sched_ttft_p95_ms_pair": pair["ttft_p95_ms"],
        "sched_latency_p50_ms_pair": pair["latency_p50_ms"],
        "sched_latency_p95_ms_pair": pair["latency_p95_ms"],
    }


def _bench_one(name, arch, smoke, shape) -> dict:
    batch, prompt_len, gen = shape["batch"], shape["prompt_len"], shape["gen"]
    tokens = shape["tokens"]
    rng = np.random.default_rng(0)

    cfg_tt = get_config(arch, tt=True, smoke=smoke)
    cfg_dense = get_config(arch, tt=False, smoke=smoke)
    prompts = jnp.asarray(
        rng.integers(0, cfg_tt.vocab, size=(batch, prompt_len)), jnp.int32)

    _, planned = run_dse_plan(arch, tokens=tokens, smoke=smoke)
    tune_report, tuned = run_dse_plan(arch, tokens=tokens, smoke=smoke,
                                      tune="cache", tune_cache=CACHE_PATH)

    dense = _throughput(batch, prompt_len, gen,
                        *_serve_once(cfg_dense, prompts, prompt_len, gen,
                                     None))
    heur = _throughput(batch, prompt_len, gen,
                       *_serve_once(cfg_tt, prompts, prompt_len, gen,
                                    planned))
    tilings_changed = sum(
        lp.tiling != planned.layer(lp.name).tiling for lp in tuned.layers)
    if _behavior(tuned) == _behavior(planned):
        # every executed decision (path, dataflow, backend, tiling,
        # backward ops) is identical: reuse the timing instead of
        # re-measuring noise — only provenance fields differ
        meas = dict(heur)
    else:
        meas = _throughput(batch, prompt_len, gen,
                           *_serve_once(cfg_tt, prompts, prompt_len, gen,
                                        tuned))

    sched = (_bench_sched(cfg_tt, arch, smoke, batch, prompt_len, gen,
                          tokens, planned)
             if smoke else {})

    return {
        "arch": name,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen": gen,
        "dse_tokens": tokens,
        **sched,
        "backends": "+".join(sorted({lp.backend for lp in tuned.layers})),
        "n_tilings_changed": tilings_changed,
        "n_tune_measured": tune_report["tune"]["n_measured"],
        "tokens_s_dense": dense["tokens_s"],
        "tokens_s_planned": heur["tokens_s"],
        "tokens_s_tuned": meas["tokens_s"],
        "prefill_tok_s_planned": heur["prefill_tok_s"],
        "prefill_tok_s_tuned": meas["prefill_tok_s"],
        "decode_tok_s_planned": heur["decode_tok_s"],
        "decode_tok_s_tuned": meas["decode_tok_s"],
        "tuned_vs_planned": meas["tokens_s"] / heur["tokens_s"],
        "prefill_ms_planned": heur["prefill_ms"],
        "prefill_ms_tuned": meas["prefill_ms"],
    }


def run() -> list[dict]:
    rows = [_bench_one(*w) for w in WORKLOADS]
    emit("BENCH_serve", rows)
    return rows


if __name__ == "__main__":
    run()
