"""Reproduce the paper's Fig. 3 story on a tensorized ViT-Ti/4 layer
(reconstruction vs MAC-optimal vs latency-optimal contraction orders),
then run the full model-level DSE via the ``repro.dse`` CLI machinery
and summarise its JSON report.

  PYTHONPATH=src python examples/dse_explore.py
"""

from collections import Counter

from repro.core import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS,
    find_topk_paths,
    layer_latency,
    reconstruction_path,
)
from repro.dse_cli import run_dse
from repro.hw import get_target
from repro.models.vision import vit_ti4_layers

FPGA_VU9P = get_target("fpga_vu9p")


def best(path):
    cfg = min(
        ((layer_latency(path, d, c, FPGA_VU9P).seconds, c, d.value)
         for c in ALL_PARTITIONINGS for d in ALL_DATAFLOWS),
        key=lambda t: t[0],
    )
    return cfg


def main():
    layer = vit_ti4_layers(batch=64)[2]     # fc1: 192 -> 768
    tn = layer.tt_network
    paths = find_topk_paths(tn, k=8)
    recon = reconstruction_path(tn)

    lat_r, c_r, d_r = best(recon)
    print(f"reconstruction order : {recon.macs:>12,} MACs  "
          f"{lat_r*1e6:8.1f} us  ({c_r}, {d_r})")
    lat_m, c_m, d_m = best(paths[0])
    print(f"MAC-optimal (Path-1) : {paths[0].macs:>12,} MACs  "
          f"{lat_m*1e6:8.1f} us  ({c_m}, {d_m})")
    lat_best, p_best = min(((best(p)[0], p) for p in paths), key=lambda t: t[0])
    k = paths.index(p_best) + 1
    _, c_b, d_b = best(p_best)
    print(f"latency-optimal (Path-{k}): {p_best.macs:>10,} MACs  "
          f"{lat_best*1e6:8.1f} us  ({c_b}, {d_b})")
    if p_best is not paths[0]:
        print(f"-> the latency-optimal path has {p_best.macs / paths[0].macs:.2f}x "
              f"the MACs but {100 * (1 - lat_best / lat_m):.0f}% lower latency "
              f"(the paper's Fig. 3 observation)")

    # model-level DSE: same report as `python -m repro.dse --arch tt-lm-100m`
    report = run_dse("tt-lm-100m", top_k=4)
    print(f"\n[tt-lm-100m] strategy={report['strategy']}  "
          f"total={report['total_latency_s'] * 1e3:.2f} ms  "
          f"({report['n_layers']} tensorized projections)")
    t = report["timings"]
    print(f"  path search {t['path_search_s'] * 1e3:.1f} ms, "
          f"cost table {t['table_build_s'] * 1e3:.1f} ms "
          f"({report['table']['n_unique_gemm_evals']} unique GEMM evals "
          f"for {report['table']['n_cells']} cells), "
          f"argmin {t['argmin_s'] * 1e3:.1f} ms")
    dfs = Counter(l["dataflow"] for l in report["layers"])
    non_mac = sum(1 for l in report["layers"] if not l["mac_optimal_path"])
    print(f"  dataflows {dict(dfs)}; {non_mac}/{report['n_layers']} layers "
          f"pick a non-MAC-optimal path")

    # joint (architecture, path, dataflow) co-search under the VU9P budget
    co = run_dse("vit_ti4/cifar10", top_k=4, hw_search="budget")
    hs = co["hw_search"]
    chosen, fixed = hs["chosen"], hs["fixed"]
    print(f"\n[vit_ti4/cifar10] hw co-search over {hs['n_candidates']} "
          f"feasible archs: {fixed['total_latency_s'] * 1e3:.3f} ms "
          f"(fixed {fixed['name']}) -> {chosen['total_latency_s'] * 1e3:.3f} "
          f"ms on {chosen['pe_rows']}x{chosen['pe_cols']} PEs "
          f"({hs['improvement_pct']:.1f}% faster)")


if __name__ == "__main__":
    main()
