"""Vectorized cost-table engine vs the scalar ``simulate()`` oracle.

The acceptance bar is *bit-identical* equality: the batched engine
shares the closed-form model and replays the exact accumulation order of
``layer_latency``, so every cell must compare equal with ``==`` (no
tolerance) across random GEMM sets, all partitionings and all dataflows,
on both hardware targets.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS,
    FPGA_VU9P,
    TPU_V5E,
    build_cost_table,
    build_cost_table_vectorized,
    build_cost_tables,
    find_topk_paths,
    global_search,
    simulate,
    tt_linear_network,
)

HW = {"fpga_vu9p": FPGA_VU9P, "tpu_v5e": TPU_V5E}


def _scalar_table(layer_paths, hw):
    return build_cost_table(layer_paths, hw, ALL_PARTITIONINGS, engine="scalar")


@pytest.mark.parametrize("hw_name", sorted(HW))
def test_vectorized_bit_identical_fixed_networks(hw_name):
    hw = HW[hw_name]
    sizes = [
        (4, (4, 4), (4, 4), (4, 4, 4)),
        (64, (2, 8), (8, 2), (4, 4, 4)),
        (1024, (12, 8, 8), (12, 8, 8), (16, 16, 16, 16, 16)),
    ]
    lp = [find_topk_paths(tt_linear_network(*s), k=4) for s in sizes]
    lp.append(lp[0])  # duplicate layer exercises the layer-dedup path
    scalar = _scalar_table(lp, hw)
    vec = build_cost_table_vectorized(lp, hw, ALL_PARTITIONINGS)
    assert set(scalar) == set(vec)
    for key in scalar:
        assert vec[key] == scalar[key], key  # bit-identical, no tolerance


@given(
    st.integers(1, 512),
    st.lists(st.integers(2, 6), min_size=1, max_size=3),
    st.lists(st.integers(2, 6), min_size=1, max_size=3),
    st.integers(1, 8),
)
@settings(max_examples=20, deadline=None)
def test_vectorized_bit_identical_random_networks(batch, in_modes, out_modes, rank):
    ranks = (rank,) * (len(in_modes) + len(out_modes) - 1)
    tn = tt_linear_network(batch, tuple(in_modes), tuple(out_modes), ranks)
    lp = [find_topk_paths(tn, k=3)]
    for hw in (FPGA_VU9P, TPU_V5E):
        scalar = _scalar_table(lp, hw)
        vec = build_cost_table_vectorized(lp, hw, ALL_PARTITIONINGS)
        assert vec == scalar  # dict equality => bit-identical floats


def test_vectorized_matches_simulate_per_cell():
    tn = tt_linear_network(64, (8, 8), (8, 8), (8, 8, 8))
    lp = [find_topk_paths(tn, k=4)]
    vec = build_cost_table_vectorized(lp, FPGA_VU9P, ALL_PARTITIONINGS)
    for (l, p, c, d), got in vec.items():
        assert got == simulate(lp[l][p], c, d, FPGA_VU9P)


def test_global_search_default_engine_unchanged():
    """Algorithm 1 through the vectorized default must equal the scalar run."""
    lp = [
        find_topk_paths(tt_linear_network(4, (4, 4), (4, 4), (4, 4, 4)), k=3),
        find_topk_paths(tt_linear_network(4, (2, 8), (8, 2), (4, 4, 4)), k=3),
    ]
    vec = global_search(lp, FPGA_VU9P)  # auto -> vectorized
    scalar = global_search(lp, FPGA_VU9P, engine="scalar")
    assert vec.total_latency_s == scalar.total_latency_s
    assert vec.strategy == scalar.strategy
    for a, b in zip(vec.choices, scalar.choices):
        assert (a.path_index, a.partitioning, a.dataflow) == (
            b.path_index, b.partitioning, b.dataflow)


def test_cost_tables_metadata_and_edp():
    tn = tt_linear_network(32, (4, 8), (8, 4), (8, 8, 8))
    lp = [find_topk_paths(tn, k=2)] * 3  # identical layers
    tables = build_cost_tables(lp, FPGA_VU9P)
    assert tables.n_unique_layers == 1
    assert tables.n_cells == len(tables.seconds)
    assert set(tables.traffic_words) == set(tables.seconds)
    edp = tables.edp(FPGA_VU9P)
    assert set(edp) == set(tables.seconds)
    for k, v in edp.items():
        assert v > 0
        # EDP = seconds * energy; energy strictly positive
        assert v / tables.seconds[k] == pytest.approx(
            tables.energy_joules(k, FPGA_VU9P))
