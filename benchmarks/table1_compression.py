"""Table 1 — TT compression ratios + reconstruction-error accuracy proxy.

The paper reports params reduction of 38.72x (ResNet-18/CIFAR-10), 35.82x
(ResNet-18/Tiny-ImageNet) and 12.17x (ViT-Ti/4) with <= 2.7% accuracy
drop after quantized TT training.  Parameter ratios are shape-exact here
(same formula as the paper); accuracy is proxied by TT-SVD relative
reconstruction error on synthetic compressible weights (low-rank +
noise) since no GPU training runs in this container.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import tt_svd, reconstruction_error
from repro.core.tt import quantize_int8, dequantize
from repro.models.vision import model_layers
from .common import emit

PAPER = {
    ("resnet18", "cifar10"): 38.72,
    ("resnet18", "tiny_imagenet"): 35.82,
    ("vit_ti4", "cifar10"): 12.17,
}

# ranks chosen per family to land in the paper's compression regime
RANKS = {"resnet18": 6, "vit_ti4": 14}


def _network_params(tn) -> tuple[int, int]:
    """(tt params, dense params) of one layer's weight network."""
    tt = sum(n.size for n in tn.nodes if n.kind == "core")
    out = tn.output_dims()
    inp = [n for n in tn.nodes if n.kind == "input"][0]
    batchish = {"b", "l"}
    dense_out = math.prod(d for e, d in out.items() if e not in batchish)
    dense_in = math.prod(d for e, d in zip(inp.edges, inp.dims)
                         if e not in batchish)
    return tt, dense_out * dense_in


def _recon_proxy(rank: int, rng) -> tuple[float, float]:
    """(fp32 error, int8 error) of TT-SVD on a compressible 256x256 weight."""
    u = rng.normal(size=(256, rank)) / math.sqrt(rank)
    v = rng.normal(size=(rank, 256))
    w = (u @ v + 0.02 * rng.normal(size=(256, 256))).astype(np.float32)
    tt = tt_svd(w, (16, 16), (16, 16), max_rank=2 * rank)
    err = reconstruction_error(tt, w)
    qcores = [dequantize(*quantize_int8(c)) for c in tt.cores]
    tt_q = type(tt)(qcores, tt.out_modes, tt.in_modes)
    return err, reconstruction_error(tt_q, w)


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for (model, dataset), paper_ratio in PAPER.items():
        rank = RANKS[model]
        layers = model_layers(model, dataset, batch=1, rank=rank)
        tt_p = dense_p = 0
        for l in layers:
            t, d = _network_params(l.tt_network)
            tt_p += t
            dense_p += d
        err, err_q = _recon_proxy(rank, rng)
        rows.append({
            "model": model,
            "dataset": dataset,
            "rank": rank,
            "params_ratio": dense_p / tt_p,
            "paper_ratio": paper_ratio,
            "recon_err_fp32": err,
            "recon_err_int8": err_q,
        })
    emit("table1_compression", rows)
    return rows


if __name__ == "__main__":
    run()
