"""chatglm3-6b [dense] — GLM 2d partial RoPE, extreme GQA (kv=2).

Assigned dims: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
[arXiv:2406.12793; hf].
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65_024,
    head_dim=128,
    rope="glm2d",
    qkv_bias=True,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="chatglm3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
