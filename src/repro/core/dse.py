"""Global latency-driven design-space exploration (paper Algorithm 1).

Stage 1 — design-space construction: per layer, the MAC-guided top-K path
search yields P_l; the partitioning space C_all and dataflow space D are
global.  Stage 2 — a cost table T[l, p, c, d] is populated by the latency
simulator.  Stage 3 — hierarchical search: for each global hardware
strategy h (which constrains C to C_h), the problem decomposes into
independent per-layer argmins; the best strategy wins.  This is exhaustive
over the (pruned) space, so the returned configuration is optimal within
it — matching the paper's "mathematically guaranteeing the optimal
solution with minimal overhead".
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Mapping, Sequence

from .paths import CandidatePath, find_topk_paths
from .simulator import (
    ALL_DATAFLOWS,
    STRATEGY_SPACE,
    Dataflow,
    HardwareConfig,
    FPGA_VU9P,
    Partitioning,
    simulate,
)
from .tensor_network import TensorNetwork


@dataclasses.dataclass(frozen=True)
class LayerChoice:
    """Optimal (p, c, d) for one layer under the winning strategy.

    Under the ``train-latency`` objective, ``latency_s`` is the combined
    per-step cost and the decomposition + per-gradient backward path
    choices are populated; under inference objectives the backward fields
    stay empty.
    """

    layer: int
    path_index: int
    path: CandidatePath
    partitioning: Partitioning
    dataflow: Dataflow
    latency_s: float
    backward: tuple = ()              # tuple[cost_table.BackwardChoice, ...]
    fwd_latency_s: float = 0.0
    bwd_latency_s: float = 0.0
    update_latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class DSEResult:
    strategy: str
    choices: tuple[LayerChoice, ...]
    total_latency_s: float
    cost_table: Mapping[tuple[int, int, Partitioning, Dataflow], float]
    objective: str = "latency"

    @property
    def per_layer_latency(self) -> tuple[float, ...]:
        return tuple(c.latency_s for c in self.choices)


def build_cost_table(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig,
    partitionings: Sequence[Partitioning],
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
    engine: str = "auto",
) -> dict[tuple[int, int, Partitioning, Dataflow], float]:
    """T[l, p, c, d] <- Simulate(p, c, d) for all valid configs (Alg. 1, l.2).

    ``engine="vectorized"`` uses the batched NumPy engine
    (``repro.core.cost_table``), bit-identical to the scalar loop;
    ``"scalar"`` forces the per-cell oracle; ``"auto"`` picks the
    vectorized engine whenever the default ``simulate`` oracle is in use
    (a custom ``simulate_fn`` must go through the scalar loop).
    """
    if engine not in ("auto", "scalar", "vectorized"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "vectorized" and simulate_fn is not simulate:
        raise ValueError(
            "engine='vectorized' evaluates the built-in closed-form model; "
            "a custom simulate_fn requires engine='scalar'"
        )
    if engine == "vectorized" or (engine == "auto" and simulate_fn is simulate):
        from .cost_table import build_cost_table_vectorized

        return build_cost_table_vectorized(layer_paths, hw, partitionings, dataflows)
    table: dict[tuple[int, int, Partitioning, Dataflow], float] = {}
    for l, paths in enumerate(layer_paths):
        for p_idx, path in enumerate(paths):
            for c in partitionings:
                for d in dataflows:
                    table[(l, p_idx, c, d)] = simulate_fn(path, c, d, hw)
    return table


def global_search(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig = FPGA_VU9P,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
    engine: str = "auto",
    table: Mapping[tuple[int, int, Partitioning, Dataflow], float] | None = None,
    *,
    objective: str = "latency",
    layer_backwards: Sequence | None = None,
    train_weights=None,
    train_tables=None,
) -> DSEResult:
    """Algorithm 1: global strategy loop + independent per-layer argmins.

    ``table`` may supply a pre-built cost table (any per-config objective,
    e.g. the EDP table from ``cost_table.CostTables.edp``); by default the
    latency table is built with the selected ``engine``.

    ``objective="train-latency"`` jointly optimizes the forward *and*
    backward passes: per cell, the cost is ``w_f * fwd + w_b * bwd +
    w_u * update`` where the backward term takes, for each gradient's
    contraction network, its best candidate path under the layer's
    (partitioning, dataflow).  ``layer_backwards`` (one
    ``backward.LayerBackward`` per layer — see
    ``backward.memoised_layer_backwards``) is required; the returned
    choices carry the per-gradient backward paths and the
    fwd/bwd/update latency decomposition.
    """
    if objective not in ("latency", "train-latency"):
        raise ValueError(
            f"unknown objective {objective!r}; have ('latency', 'train-latency')"
            " — EDP goes through the ``table`` argument")
    all_parts = sorted({c for cs in strategy_space.values() for c in cs})
    train = None
    if objective == "train-latency":
        if table is not None:
            raise ValueError(
                "objective='train-latency' builds its own combined table; "
                "a pre-built ``table`` cannot be decomposed "
                "(pass ``train_tables`` instead)")
        if train_tables is not None:
            if train_weights is not None:
                raise ValueError(
                    "train_weights must be baked into train_tables at build "
                    "time (build_train_cost_tables(weights=...)); passing "
                    "both is ambiguous")
            train = train_tables
        else:
            if layer_backwards is None:
                raise ValueError(
                    "objective='train-latency' requires layer_backwards "
                    "(see repro.core.backward.memoised_layer_backwards) "
                    "or a pre-built train_tables")
            from .cost_table import build_train_cost_tables

            train = build_train_cost_tables(
                layer_paths, layer_backwards, hw, all_parts, dataflows,
                weights=train_weights)
        table = train.train_seconds()
    elif table is None:
        table = build_cost_table(
            layer_paths, hw, all_parts, dataflows, simulate_fn, engine
        )

    best_cost = float("inf")
    best: tuple[str, tuple[LayerChoice, ...]] | None = None
    for h, c_h in strategy_space.items():
        choices: list[LayerChoice] = []
        cost_h = 0.0
        for l, paths in enumerate(layer_paths):
            lat, arg = min(
                ((table[(l, p, c, d)], (p, c, d))
                 for p in range(len(paths))
                 for c in c_h
                 for d in dataflows),
                key=lambda t: t[0],
            )
            p, c, d = arg
            if train is not None:
                w = train.weights
                choices.append(LayerChoice(
                    l, p, paths[p], c, d, lat,
                    backward=train.bwd_choices[(l, c, d)],
                    fwd_latency_s=w.fwd * train.fwd.seconds[(l, p, c, d)],
                    bwd_latency_s=w.bwd * train.bwd_seconds[(l, c, d)],
                    update_latency_s=w.update * train.update_seconds[l],
                ))
            else:
                choices.append(LayerChoice(l, p, paths[p], c, d, lat))
            cost_h += lat
        if cost_h < best_cost:
            best_cost = cost_h
            best = (h, tuple(choices))
    assert best is not None
    return DSEResult(best[0], best[1], best_cost, table, objective)


def brute_force_search(
    layer_paths: Sequence[Sequence[CandidatePath]],
    hw: HardwareConfig = FPGA_VU9P,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    simulate_fn: Callable[[CandidatePath, Partitioning, Dataflow, HardwareConfig], float] = simulate,
) -> float:
    """Exhaustive cross-product search — test oracle for ``global_search``.

    Exponential in L; only usable for tiny models in tests.
    """
    best = float("inf")
    for h, c_h in strategy_space.items():
        per_layer_opts = []
        for paths in layer_paths:
            per_layer_opts.append([
                (p, c, d)
                for p in range(len(paths))
                for c in c_h
                for d in dataflows
            ])
        for combo in itertools.product(*per_layer_opts):
            cost = sum(
                simulate_fn(layer_paths[l][p], c, d, hw)
                for l, (p, c, d) in enumerate(combo)
            )
            best = min(best, cost)
    return best


def explore_model(
    networks: Sequence[TensorNetwork],
    hw: HardwareConfig = FPGA_VU9P,
    top_k: int = 4,
    strategy_space: Mapping[str, Sequence[Partitioning]] = STRATEGY_SPACE,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    engine: str = "auto",
    objective: str = "latency",
) -> DSEResult:
    """End-to-end DSE for a model given per-layer tensor networks."""
    layer_paths = [find_topk_paths(tn, k=top_k) for tn in networks]
    layer_backwards = None
    if objective == "train-latency":
        from .backward import memoised_layer_backwards

        layer_backwards = memoised_layer_backwards(networks, k=top_k)
    return global_search(layer_paths, hw, strategy_space, dataflows,
                         engine=engine, objective=objective,
                         layer_backwards=layer_backwards)


def pareto_front(points: Sequence[tuple[float, float]]) -> list[int]:
    """Indices of the Pareto-optimal (cost1, cost2) points (both minimised)."""
    order = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front: list[int] = []
    best_y = float("inf")
    for i in order:
        if points[i][1] < best_y:
            front.append(i)
            best_y = points[i][1]
    return front
