"""Accuracy-aware rank search: the TT decomposition as a searched axis.

The DSE's first three axes — contraction path, partitioning, dataflow —
all map a *fixed* decomposition onto hardware.  This subsystem makes
the decomposition itself (modes per side x TT rank, per projection
family) the fourth axis:

- :mod:`repro.rank.space` enumerates candidate factorizations around
  the frozen ``TTConfig`` under a parameter budget;
- :mod:`repro.rank.proxy` scores each candidate's accuracy by TT-SVD
  reconstruction error against deterministic reference weights
  (optionally activation-RMS weighted);
- :mod:`repro.rank.search` evaluates every candidate through the
  existing cost-table/argmin stack and reports the (latency, accuracy)
  Pareto frontier plus a budget-constrained chosen candidate.

Driven by ``python -m repro.dse --rank-search budget
[--accuracy-budget EPS]``; the chosen factorizations ride in the v4
plan schema down to the executor (``repro.plan`` / ``launch/serve.py``).
"""

from .space import (
    DEFAULT_PARAM_BUDGET_RATIO,
    MODES_PER_SIDE,
    RANK_LADDER_FACTORS,
    FamilyFactorization,
    RankCandidate,
    RankSpace,
    clip_ranks,
    vision_rank_space,
)
from .proxy import (
    NOISE_FLOOR,
    REFERENCE_COMPONENTS,
    SPECTRUM_DECAY,
    activation_calibration,
    candidate_proxy,
    family_proxy,
    reconstruction_proxy,
    reference_weight,
)
from .search import (
    PROXY_EPS,
    RANK_SEARCH_MODES,
    CandidateEval,
    RankSearchResult,
    rank_search,
)

__all__ = [
    "DEFAULT_PARAM_BUDGET_RATIO", "MODES_PER_SIDE", "RANK_LADDER_FACTORS",
    "FamilyFactorization", "RankCandidate", "RankSpace", "clip_ranks",
    "vision_rank_space",
    "NOISE_FLOOR", "REFERENCE_COMPONENTS", "SPECTRUM_DECAY",
    "activation_calibration", "candidate_proxy", "family_proxy",
    "reconstruction_proxy", "reference_weight",
    "PROXY_EPS", "RANK_SEARCH_MODES", "CandidateEval", "RankSearchResult",
    "rank_search",
]
