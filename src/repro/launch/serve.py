"""Serving driver: batched prefill + decode loop with KV/state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch tt-lm-100m --smoke \
      --batch 4 --prompt-len 32 --gen 16

``--plan plan.json`` installs a DSE-compiled execution plan (emitted by
``python -m repro.dse --emit-plan``, see docs/plan_format.md): every TT
projection then contracts along its searched path through its searched
kernel backend/dataflow, and the driver reports which backends executed.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_rules, make_test_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import api
from repro.models.config import ShapeConfig
from repro.sharding import use_rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tt-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="install a DSE execution plan (repro.dse --emit-plan)")
    args = ap.parse_args()

    cfg = get_config(args.arch, tt=not args.dense, smoke=args.smoke)
    max_seq = args.prompt_len + args.gen
    shape = ShapeConfig("cli", max_seq, args.batch, "decode")
    mesh = make_test_mesh()
    rules = make_rules(cfg, shape, mesh)
    if args.plan:
        from repro.plan import (
            check_plan_for_config,
            load_plan,
            reset_execution_log,
        )

        plan = load_plan(args.plan)
        problems = check_plan_for_config(plan, args.arch, cfg)
        if problems:
            raise SystemExit(
                "error: plan/model mismatch: " + "; ".join(problems))
        reset_execution_log()
        m = api(cfg, plan=plan)
        print(f"installed plan: arch={plan.arch} hw={plan.hw} "
              f"strategy={plan.strategy} ({len(plan.layers)} layer plans)")
        print(f"plan tilings: {plan.tilings}"
              + (" (autotuned — repro.tune)"
                 if plan.tilings == "measured" else ""))
        if plan.hardware is not None:
            h = plan.hardware
            print(f"plan hardware: {h.name} ({h.pe_rows}x{h.pe_cols} PEs, "
                  f"sram {h.sram_input_bytes // 1024}+"
                  f"{h.sram_output_bytes // 1024} KiB, "
                  f"bw {h.dram_words_per_cycle:g} words/cycle)")
    else:
        m = api(cfg)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.family in ("vlm", "encdec"):
        n = cfg.n_frontend_tokens or 8
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(args.batch, n, cfg.d_model)), jnp.dtype(cfg.dtype))

    with use_rules(rules):
        params = m.init_params(jax.random.PRNGKey(0))
        prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        t0 = time.time()
        logits, caches = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        key = jax.random.PRNGKey(1)
        tokens = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.gen):
            tokens.append(np.asarray(tok))
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, caches = decode(params, tok, caches, pos)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    out = np.concatenate(tokens, axis=1)
    prefill_tok_s = args.batch * args.prompt_len / max(t_prefill, 1e-9)
    decode_tok_s = args.batch * args.gen / max(t_decode, 1e-9)
    total_tok = args.batch * (args.prompt_len + args.gen)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms "
          f"({prefill_tok_s:.1f} tok/s)")
    print(f"decode  {args.gen} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/args.gen*1e3:.2f} ms/tok, batch {args.batch}, "
          f"{decode_tok_s:.1f} tok/s)")
    print(f"overall {total_tok} tokens: "
          f"{total_tok / max(t_prefill + t_decode, 1e-9):.1f} tok/s")
    print("generated token ids (first row):", out[0][:16].tolist())
    if args.plan:
        import sys

        from repro.plan import execution_log

        log = execution_log()
        by_backend: dict[str, int] = {}
        for r in log:
            by_backend[r["backend"]] = by_backend.get(r["backend"], 0) + 1
        print(f"planned executions (trace-time): {len(log)} "
              f"by backend {dict(sorted(by_backend.items()))}")
        tilings = sorted({
            (r["tiling"]["block_m"], r["tiling"]["block_k"],
             r["tiling"]["block_n"], r["tiling"]["block_tokens"])
            for r in log})
        if tilings:
            print("kernel tilings (block_m,k,n,tokens): "
                  + " ".join(str(t) for t in tilings))
        if not log:
            print(
                f"WARNING: plan {args.plan} (arch={plan.arch!r}) matched no "
                f"executed projection of --arch {args.arch!r} — the run was "
                "entirely UNPLANNED (layer names did not line up; was the "
                "plan emitted for a different arch or tt/--dense setting?)",
                file=sys.stderr,
            )


if __name__ == "__main__":
    main()
