"""The hardware-target registry — single source of truth for ``--hw``.

Named, fixed accelerator descriptions live here: the paper's FPGA setup
(``fpga_vu9p``) and the TPU-v5e MXU reading (``tpu_v5e``).  Everything
that needs to resolve a target by name — ``python -m repro.dse --hw``,
plan migration (v2 → v3 embeds the named target), benchmarks — goes
through :func:`get_target`, so adding a target is a one-line
:func:`register_target` call.

TPU-v5e derivation: the MXU *is* a 128x128 systolic array, so the same
closed-form model applies with TPU constants:

  * peak 197 TFLOP/s bf16 per chip  ->  98.5e12 MAC/s
  * on a 128x128 array that is an effective 6.01 GHz MAC issue rate
    (the real chip reaches it with multiple MXU passes per clock; the
    effective-frequency abstraction preserves the peak roofline)
  * HBM 819 GB/s  ->  819e9 / 2 B (bf16) / 6.01e9 Hz ~= 68 words/cycle
  * VMEM ~128 MiB split ~3:1 between operand and output buffering,
    mirroring the paper's 3072/1024 KiB SRAM split.
"""

from __future__ import annotations

from .config import HardwareConfig

# the paper's simulator settings (5.1) are HardwareConfig's defaults
FPGA_VU9P = HardwareConfig()

_PEAK_FLOPS_BF16 = 197e12
_MXU = 128
_EFF_FREQ = (_PEAK_FLOPS_BF16 / 2.0) / (_MXU * _MXU)  # ~6.01e9
_HBM_BYTES_PER_S = 819e9
_BYTES_PER_WORD = 2  # bf16

TPU_V5E = HardwareConfig(
    name="tpu_v5e",
    pe_rows=_MXU,
    pe_cols=_MXU,
    freq_hz=_EFF_FREQ,
    sram_input_bytes=96 * 1024 * 1024,
    sram_output_bytes=32 * 1024 * 1024,
    dram_words_per_cycle=_HBM_BYTES_PER_S / _BYTES_PER_WORD / _EFF_FREQ,
    bytes_per_word=_BYTES_PER_WORD,
    gemm_overhead_cycles=256,  # kernel-dispatch / pipeline-warmup constant
)

#: interconnect constants used by the roofline analysis (per chip)
ICI_BYTES_PER_S_PER_LINK = 50e9
HBM_BYTES_PER_S = _HBM_BYTES_PER_S
PEAK_FLOPS_BF16 = _PEAK_FLOPS_BF16
VMEM_BYTES = 128 * 1024 * 1024
HBM_CAPACITY_BYTES = 16 * 1024**3


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: name -> HardwareConfig; the one mapping ``--hw`` resolves against
HW_TARGETS: dict[str, HardwareConfig] = {}


def register_target(hw: HardwareConfig) -> HardwareConfig:
    """Register a named target (idempotent for identical configs)."""
    existing = HW_TARGETS.get(hw.name)
    if existing is not None and existing != hw:
        raise ValueError(
            f"hardware target {hw.name!r} already registered with "
            "different parameters")
    HW_TARGETS[hw.name] = hw
    return hw


def get_target(name: str) -> HardwareConfig:
    """Resolve a target by name; unknown names list the valid choices."""
    try:
        return HW_TARGETS[name]
    except KeyError:
        raise KeyError(
            f"unknown hw {name!r}; have {sorted(HW_TARGETS)}") from None


def list_targets() -> tuple[str, ...]:
    return tuple(sorted(HW_TARGETS))


register_target(FPGA_VU9P)
register_target(TPU_V5E)
