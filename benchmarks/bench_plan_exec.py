"""Default-path vs planned execution, per workload.

For each TT projection workload this benchmark:

1. runs the DSE and compiles an ExecutionPlan (`repro.plan`);
2. reports the *simulated* latency of the naive default — MAC-optimal
   path, monolithic array, OS dataflow — against the plan's searched
   (path, partitioning, dataflow) choice;
3. times the *executed* forward pass (jitted, CPU) for the default
   executor vs the planned jnp executor (isolating the contraction-path
   change), plus the plan's Pallas backend in interpret mode.

Interpret-mode kernel timings measure Python-level kernel-body
evaluation, not TPU performance — they are correctness/plumbing numbers;
the analytic columns carry the hardware story (paper Tables 3/4).

  PYTHONPATH=src python -m benchmarks.bench_plan_exec
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FPGA_VU9P, find_topk_paths
from repro.core.dse import global_search
from repro.core.simulator import Dataflow, simulate
from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
from repro.plan import compile_plan

from .common import emit, timed

#: (name, d_in, d_out, d, rank, tokens)
WORKLOADS = [
    ("mlp_512x2048", 512, 2048, 3, 16, 256),
    ("attn_768x768", 768, 768, 3, 16, 256),
    ("mlp_1024x4096", 1024, 4096, 3, 16, 256),
]


def _bench_one(name: str, d_in: int, d_out: int, d: int, rank: int,
               tokens: int) -> dict:
    tt = TTConfig(enabled=True, d=d, rank=rank, min_dim=min(d_in, d_out))
    spec = LinearSpec(name, d_in, d_out, tag="mlp", tt=tt)
    tn = spec.network(tokens)
    paths = find_topk_paths(tn, k=4)
    res = global_search([paths], FPGA_VU9P)
    plan = compile_plan([(name, tn)], res, FPGA_VU9P, arch=name, tokens=tokens)
    lp = plan.layers[0]
    choice = res.choices[0]

    # analytic: naive default (MAC-optimal path, monolithic, OS) vs plan
    sim_default = simulate(paths[0], (1, 1), Dataflow.OS, FPGA_VU9P)
    sim_planned = choice.latency_s

    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (tokens, d_in))

    def run(p, xv):
        return linear_apply(spec, p, xv)

    install_plan(None)
    f_default = jax.jit(run)
    f_default(params, x).block_until_ready()  # compile outside the timing
    _, t_default = timed(lambda: f_default(params, x).block_until_ready())

    install_plan(plan.with_backend("jnp"))
    f_planned = jax.jit(run)
    f_planned(params, x).block_until_ready()
    _, t_planned_jnp = timed(lambda: f_planned(params, x).block_until_ready())

    install_plan(plan)  # the compiled backend (interpret mode on CPU)
    f_kernel = jax.jit(run)
    f_kernel(params, x).block_until_ready()
    _, t_kernel = timed(lambda: f_kernel(params, x).block_until_ready(),
                        repeat=1)
    err = float(jnp.max(jnp.abs(f_kernel(params, x) - f_default(params, x))))
    install_plan(None)

    return {
        "workload": name,
        "tokens": tokens,
        "plan_backend": lp.backend,
        "path_index": lp.path_index,
        "dataflow": lp.dataflow,
        "partitioning": "x".join(map(str, lp.partitioning)),
        "sim_default_us": sim_default * 1e6,
        "sim_planned_us": sim_planned * 1e6,
        "sim_speedup": sim_default / sim_planned if sim_planned else float("nan"),
        "wall_default_ms": t_default * 1e3,
        "wall_planned_jnp_ms": t_planned_jnp * 1e3,
        "wall_kernel_interpret_ms": t_kernel * 1e3,
        "kernel_max_abs_err": err,
    }


def run() -> list[dict]:
    rows = [_bench_one(*w) for w in WORKLOADS]
    emit("bench_plan_exec", rows)
    return rows


if __name__ == "__main__":
    run()
