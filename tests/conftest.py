"""Shared test config.  NOTE: no global XLA device-count flags here —
smoke tests and benches must see the real single CPU device; only the
dry-run subprocess tests use forced host platform device counts."""

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
