"""Deterministic, shard-aware, resumable synthetic token pipeline.

Every batch is a pure function of ``(seed, step, dp_rank)`` via counter
based Philox keys — no iterator state exists, so:

  * restart at step k reproduces batch k bit-exactly (checkpoint/restart
    correctness, verified by tests);
  * each data-parallel rank draws only its shard (no host reads the
    global batch);
  * elastic resharding (changing dp_world) re-partitions the same global
    stream: global sample index = step * global_batch + position, and a
    rank owns a contiguous slice of positions.

Token content follows a Zipf-like unigram draw with a deterministic
bigram skeleton so the LM loss actually decreases during the example
training runs (pure-uniform tokens have irreducible loss == log V).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew


class SyntheticTokens:
    """Stateless batch source; ``batch(step, rank, world)`` is pure."""

    def __init__(self, spec: DataSpec):
        self.spec = spec
        # fixed Zipf unigram distribution over the vocab
        ranks = np.arange(1, spec.vocab + 1, dtype=np.float64)
        probs = ranks ** (-spec.zipf_a)
        self._probs = probs / probs.sum()
        # deterministic bigram successor table: token t is often followed
        # by succ[t]; gives the model something learnable
        rng = np.random.default_rng(np.random.Philox(key=spec.seed))
        self._succ = rng.integers(0, spec.vocab, size=spec.vocab)

    def local_batch_size(self, world: int) -> int:
        gb = self.spec.global_batch
        if gb % world:
            raise ValueError(f"global_batch {gb} not divisible by dp world {world}")
        return gb // world

    def batch(self, step: int, rank: int = 0, world: int = 1) -> dict:
        """Returns {tokens (b, S) i32, labels (b, S) i32} for this rank."""
        spec = self.spec
        b = self.local_batch_size(world)
        start = step * spec.global_batch + rank * b
        rows = []
        for i in range(b):
            rng = np.random.default_rng(
                np.random.Philox(key=(spec.seed, start + i))
            )
            draws = rng.choice(spec.vocab, size=spec.seq_len + 1, p=self._probs)
            follow = rng.random(spec.seq_len + 1) < 0.5
            seq = draws.copy()
            # 50% of positions follow the bigram skeleton of the previous token
            for t in range(1, spec.seq_len + 1):
                if follow[t]:
                    seq[t] = self._succ[seq[t - 1]]
            rows.append(seq)
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def iterate(self, start_step: int, rank: int = 0, world: int = 1
                ) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, rank, world)
            step += 1


def make_pipeline(vocab: int, seq_len: int, global_batch: int,
                  seed: int = 0) -> SyntheticTokens:
    return SyntheticTokens(DataSpec(vocab, seq_len, global_batch, seed))
