"""Data pipeline."""

from .pipeline import DataSpec, SyntheticTokens, make_pipeline

__all__ = ["DataSpec", "SyntheticTokens", "make_pipeline"]
