"""Table 2 — distribution of layer-wise optimal configuration choices.

For each benchmark model the global DSE (Algorithm 1) selects per layer:
the hardware strategy (monolithic M vs split S), the contraction path
(Path-1 = MAC-optimal vs Path-k), and the dataflow.  The paper's
observation: 25-50% of layers pick a non-MAC-optimal path, and dataflow
choices vary per model/mode — the same distributions are reported here.

Training mode approximates the backward pass as the forward contraction
set at 3x the token count (dL/dX and dL/dW have forward-like shapes) —
an explicit, documented modelling choice.
"""

from __future__ import annotations

from repro.core import STRATEGY_SPACE, FPGA_VU9P, find_topk_paths, global_search
from repro.models.vision import model_layers
from .common import emit

MODELS = [
    ("resnet18", "tiny_imagenet"),
    ("resnet18", "cifar10"),
    ("vit_ti4", "cifar10"),
]


def _dse(model, dataset, batch):
    layers = model_layers(model, dataset, batch=batch)
    layer_paths = [find_topk_paths(l.tt_network, k=4) for l in layers]
    return global_search(layer_paths, FPGA_VU9P), layer_paths


def run() -> list[dict]:
    rows = []
    for model, dataset in MODELS:
        for mode, batch in (("inference", 1), ("training", 3)):
            res, _ = _dse(model, dataset, batch)
            n = len(res.choices)
            path1 = sum(1 for c in res.choices if c.path_index == 0)
            split = sum(1 for c in res.choices if c.partitioning != (1, 1))
            dfs = {d: 0 for d in ("IS", "OS", "WS")}
            for c in res.choices:
                dfs[c.dataflow.value] += 1
            rows.append({
                "model": model,
                "dataset": dataset,
                "mode": mode,
                "strategy": res.strategy,
                "split_pct": 100.0 * split / n,
                "path1_pct": 100.0 * path1 / n,
                "pathk_pct": 100.0 * (n - path1) / n,
                "IS_pct": 100.0 * dfs["IS"] / n,
                "OS_pct": 100.0 * dfs["OS"] / n,
                "WS_pct": 100.0 * dfs["WS"] / n,
                "total_latency_ms": res.total_latency_s * 1e3,
            })
    emit("table2_dse_choices", rows)
    return rows


if __name__ == "__main__":
    run()
