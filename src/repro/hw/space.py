"""Parameterized hardware-architecture space under a resource budget.

The paper's claim is that contraction path, dataflow mapping *and* the
hardware architecture are coupled and must be searched jointly.  This
module makes the architecture a first-class searched axis: an
:class:`ArchSpace` enumerates every *feasible* variant of a base target
under a fixed MAC/DSP budget —

- **PE array shape** ``R x C``: power-of-two dimensions whose product
  stays within the MAC budget (the DSP count of the paper's VU9P board,
  32 x 32 = 1024 by default) and does not waste more than half of it;
  extreme aspect ratios are rejected (wiring/fan-out infeasible).
- **SRAM split**: the board's total on-chip buffer is fixed; the
  input/output split point moves (the paper's 3072/1024 KiB is the 0.75
  point).
- **DRAM-bandwidth tier**: words/cycle at or below the board's pin
  bandwidth (a searched architecture cannot exceed the package).

Frequency, word width and per-GEMM overhead are inherited from the base
target — they are process/board constants, not architectural choices.
The base target itself is always candidate 0, so a joint
(architecture, path, dataflow) search over the space can never be worse
than the fixed-target search (the guarantee
``tests/test_hw.py`` asserts for every registered target).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional, Sequence

from .config import HardwareConfig
from .targets import FPGA_VU9P


def _pow2s(lo: int, hi: int) -> list[int]:
    out, p = [], 1
    while p <= hi:
        if p >= lo:
            out.append(p)
        p *= 2
    return out


@dataclasses.dataclass(frozen=True)
class ArchSpace:
    """Feasible architecture variants of ``base`` under a MAC/DSP budget.

    ``mac_budget`` defaults to the base target's own PE count — the
    search then *re-shapes* the same silicon rather than adding any.
    ``sram_total_bytes`` likewise defaults to the base target's total
    buffer; only the split point is searched.
    """

    base: HardwareConfig = FPGA_VU9P
    mac_budget: Optional[int] = None          # R*C <= budget (DSP count)
    min_pe_dim: int = 8
    max_pe_dim: int = 256
    max_aspect: int = 16                      # max(R,C)/min(R,C) cap
    min_budget_util: float = 0.5              # R*C >= util * budget
    sram_total_bytes: Optional[int] = None
    sram_input_fracs: tuple[float, ...] = (0.5, 0.625, 0.75, 0.875)
    min_sram_output_bytes: int = 64 * 1024
    bw_tiers: Optional[tuple[float, ...]] = None  # words/cycle, <= base

    def __post_init__(self) -> None:
        if self.mac_budget is None:
            object.__setattr__(self, "mac_budget", self.base.macs_per_cycle)
        if self.sram_total_bytes is None:
            object.__setattr__(self, "sram_total_bytes",
                               self.base.sram_total_bytes)
        if self.bw_tiers is None:
            bw = self.base.dram_words_per_cycle
            object.__setattr__(self, "bw_tiers", (bw / 4.0, bw / 2.0, bw))
        elif max(self.bw_tiers) > self.base.dram_words_per_cycle:
            raise ValueError(
                f"bw_tiers {self.bw_tiers} exceed the base target's pin "
                f"bandwidth ({self.base.dram_words_per_cycle:g} words/cycle)"
                " — every grid candidate would be infeasible")
        if self.mac_budget < self.min_pe_dim * self.min_pe_dim:
            raise ValueError(
                f"mac_budget {self.mac_budget} cannot fit a "
                f"{self.min_pe_dim}x{self.min_pe_dim} array")

    # -- feasibility ------------------------------------------------------
    def resource_problems(self, hw: HardwareConfig) -> list[str]:
        """*Hard* resource violations: the candidate does not fit the
        board.  (Distinct from the efficiency preferences below, which
        only prune the generated grid.)"""
        problems = []
        r, c = hw.pe_rows, hw.pe_cols
        if r * c > self.mac_budget:
            problems.append(f"{r}x{c} PEs exceed the MAC budget "
                            f"{self.mac_budget}")
        if hw.sram_input_bytes + hw.sram_output_bytes > self.sram_total_bytes:
            problems.append("SRAM split exceeds the total buffer budget")
        if hw.sram_output_bytes < self.min_sram_output_bytes:
            problems.append("output SRAM below the minimum buffer")
        if hw.dram_words_per_cycle > self.base.dram_words_per_cycle:
            problems.append("bandwidth tier exceeds the board's pins")
        return problems

    def feasibility(self, hw: HardwareConfig) -> list[str]:
        """Resource violations plus efficiency-preference problems
        (budget utilization, dim bounds, aspect ratio) — empty = ok."""
        problems = self.resource_problems(hw)
        r, c = hw.pe_rows, hw.pe_cols
        if r * c < self.min_budget_util * self.mac_budget:
            problems.append(f"{r}x{c} PEs waste more than "
                            f"{1 - self.min_budget_util:.0%} of the budget")
        if not (self.min_pe_dim <= r <= self.max_pe_dim
                and self.min_pe_dim <= c <= self.max_pe_dim):
            problems.append(f"array dim outside [{self.min_pe_dim}, "
                            f"{self.max_pe_dim}]")
        if max(r, c) > self.max_aspect * min(r, c):
            problems.append(f"aspect ratio {max(r, c) // min(r, c)} exceeds "
                            f"{self.max_aspect}")
        return problems

    def feasible(self, hw: HardwareConfig) -> bool:
        return not self.feasibility(hw)

    # -- enumeration ------------------------------------------------------
    def _grid(self) -> Iterator[HardwareConfig]:
        dims = _pow2s(self.min_pe_dim, self.max_pe_dim)
        for r in dims:
            for c in dims:
                for frac in self.sram_input_fracs:
                    sram_in = int(self.sram_total_bytes * frac)
                    sram_out = self.sram_total_bytes - sram_in
                    for bw in self.bw_tiers:
                        yield dataclasses.replace(
                            self.base,
                            name=(f"{self.base.name}@{r}x{c}"
                                  f"_s{frac:g}_bw{bw:g}"),
                            pe_rows=r,
                            pe_cols=c,
                            sram_input_bytes=sram_in,
                            sram_output_bytes=sram_out,
                            dram_words_per_cycle=bw,
                        )

    def candidates(self) -> tuple[HardwareConfig, ...]:
        """All feasible candidates; the base target is always first.

        The base is exempt from the efficiency *preferences* (it only
        has to fit the board's resources): it is the reference point, and
        dropping it — e.g. under an enlarged ``mac_budget`` where its PE
        count falls below ``min_budget_util`` — would break the
        "co-searched optimum <= fixed optimum" guarantee and every
        consumer of the report's ``fixed`` row.  Grid points that
        duplicate the base target's parameters under a different name are
        dropped, so ties in a joint search resolve to the base
        architecture.
        """
        def params(hw: HardwareConfig) -> tuple:
            return dataclasses.astuple(dataclasses.replace(hw, name=""))

        out: list[HardwareConfig] = []
        seen: set[tuple] = set()
        if not self.resource_problems(self.base):
            out.append(self.base)
            seen.add(params(self.base))
        for hw in self._grid():
            if params(hw) in seen or not self.feasible(hw):
                continue
            seen.add(params(hw))
            out.append(hw)
        if not out:
            raise ValueError(
                f"architecture space for {self.base.name!r} under budget "
                f"{self.mac_budget} has no feasible candidate")
        return tuple(out)

    def describe(self, hw: HardwareConfig) -> dict:
        """JSON-friendly summary of one candidate (CLI / benchmark rows)."""
        return {
            "name": hw.name,
            "pe_rows": hw.pe_rows,
            "pe_cols": hw.pe_cols,
            "sram_input_kib": hw.sram_input_bytes // 1024,
            "sram_output_kib": hw.sram_output_bytes // 1024,
            "dram_words_per_cycle": hw.dram_words_per_cycle,
        }


def arch_coordinates(
    hw_list: Sequence[HardwareConfig],
) -> tuple[tuple[float, ...], ...]:
    """Embed candidates in a metric space for neighborhood-based mutation.

    One coordinate vector per candidate: (log2 PE rows, log2 PE cols,
    input-SRAM fraction of the total buffer, log2 bandwidth tier).  The
    searched knobs are all geometric (pow2 dims, bw halvings), so log2
    makes "one grid step" a unit distance on each axis; the SRAM split is
    already a fraction.  Guided mutation uses L1 distance in this space
    to propose *adjacent* architectures instead of uniform jumps — the
    cost surface is smooth along each knob (halving bandwidth roughly
    doubles DRAM time), which is what makes local moves informative.
    """
    coords = []
    for hw in hw_list:
        total = hw.sram_input_bytes + hw.sram_output_bytes
        coords.append((
            math.log2(hw.pe_rows),
            math.log2(hw.pe_cols),
            hw.sram_input_bytes / total if total else 0.0,
            math.log2(hw.dram_words_per_cycle)
            if hw.dram_words_per_cycle > 0 else 0.0,
        ))
    return tuple(coords)
