"""NN substrate correctness: linear (dense/TT), embedding, attention,
MoE, SSD, WKV — each against an independent reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tt_svd
from repro.nn import (
    AttentionSpec,
    EmbeddingSpec,
    LinearSpec,
    MoESpec,
    TTConfig,
    attention_apply,
    attention_init,
    embedding_apply,
    embedding_init,
    head_apply,
    init_kv_cache,
    install_plan,
    linear_apply,
    linear_init,
    moe_apply,
    moe_init,
)
from repro.nn.rwkv import _wkv_chunked
from repro.nn.ssm import _ssd_chunked

TT = TTConfig(enabled=True, d=2, rank=64, min_dim=8,
              targets=("attn", "mlp", "head", "moe", "embed"))


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

def test_tt_linear_matches_dense_with_svd_cores(rng):
    """Load TT-SVD cores of a dense W into the layer: outputs must match
    the dense matmul (full-rank TT == exact)."""
    d_in, d_out = 16, 24
    spec = LinearSpec("l", d_in, d_out, False, "mlp", TT)
    assert spec.tensorized
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    # layer contracts x (in_modes) against cores: W tensor (out_modes, in_modes)
    tt = tt_svd(w.T, spec.out_modes, spec.in_modes, max_rank=64)
    params = {}
    for k, c in enumerate(tt.cores):
        arr = jnp.asarray(c, jnp.float32)
        if k == 0:
            arr = arr[0]            # squeeze boundary rank
        elif k == len(tt.cores) - 1:
            arr = arr[..., 0]
        params[f"core{k}"] = arr
    x = jnp.asarray(rng.normal(size=(5, d_in)), jnp.float32)
    y = linear_apply(spec, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w, rtol=1e-4,
                               atol=1e-4)


def test_tt_linear_all_paths_equivalent(rng):
    spec = LinearSpec("l2", 16, 16, False, "mlp", TT)
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    outs = [np.asarray(linear_apply(spec, params, x, path_index=i))
            for i in range(3)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_install_plan_changes_selected_path(rng):
    spec = LinearSpec("planned", 16, 16, False, "mlp", TT)
    install_plan({"planned": 1})
    from repro.nn.linear import planned_path_index
    assert planned_path_index("planned") == 1
    install_plan({})


def test_linear_bias_and_dense(rng):
    spec = LinearSpec("d", 8, 4, True, "mlp", None)
    p = linear_init(jax.random.PRNGKey(1), spec)
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    y = linear_apply(spec, p, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x) @ np.asarray(p["w"]) + np.asarray(p["b"]),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _dense_table(spec, p):
    vm = spec.vocab_modes
    full = p["core0"]
    for k in range(1, len(vm)):
        full = jnp.einsum("...r,rvds->...vds", full, p[f"core{k}"])
    full = full[0, ..., 0]
    perm = [2 * i for i in range(len(vm))] + [2 * i + 1 for i in range(len(vm))]
    return jnp.transpose(full, perm).reshape(spec.vocab, spec.d_model)


@pytest.mark.parametrize("vocab,d_model", [(120, 24), (96, 32), (253, 16)])
def test_tt_embedding_gather_and_head_exact(vocab, d_model, rng):
    tt = TTConfig(enabled=True, d=3, rank=8, min_dim=1, targets=("embed",))
    spec = EmbeddingSpec("e", vocab, d_model, tt)
    p = embedding_init(jax.random.PRNGKey(2), spec)
    table = _dense_table(spec, p)
    ids = jnp.asarray(rng.integers(0, vocab, size=(4, 7)), jnp.int32)
    emb = embedding_apply(spec, p, ids)
    np.testing.assert_allclose(np.asarray(emb), np.asarray(table)[np.asarray(ids)],
                               rtol=1e-5, atol=1e-5)
    x = jnp.asarray(rng.normal(size=(4, 7, d_model)), jnp.float32)
    logits = head_apply(spec, p, x)
    expect = jnp.einsum("bsd,vd->bsv", x, table)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _naive_causal_attention(q, k, v):
    b, s, h, d = q.shape
    scores = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    scores = np.where(mask[None, None], scores, -1e30)
    probs = np.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", probs, v)


def test_attention_matches_naive(rng):
    spec = AttentionSpec("a", d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
                         rope="none", q_chunk=4)
    p = attention_init(jax.random.PRNGKey(3), spec)
    x = jnp.asarray(rng.normal(size=(2, 12, 16)), jnp.float32)
    out, _ = attention_apply(spec, p, x)
    q = np.asarray(x @ p["wq"]["w"]).reshape(2, 12, 2, 8)
    k = np.asarray(x @ p["wk"]["w"]).reshape(2, 12, 2, 8)
    v = np.asarray(x @ p["wv"]["w"]).reshape(2, 12, 2, 8)
    expect = _naive_causal_attention(q, k, v).reshape(2, 12, 16) @ np.asarray(
        p["wo"]["w"])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-4)


def test_gqa_decode_matches_prefill_continuation(rng):
    spec = AttentionSpec("g", d_model=16, n_heads=4, n_kv_heads=2, head_dim=4,
                         rope="full", q_chunk=8)
    p = attention_init(jax.random.PRNGKey(4), spec)
    x = jnp.asarray(rng.normal(size=(1, 9, 16)), jnp.float32)
    full, _ = attention_apply(spec, p, x)
    cache = init_kv_cache(spec, 1, 16, jnp.float32)
    _, cache = attention_apply(spec, p, x[:, :8], cache=cache,
                               cache_pos=jnp.asarray(0, jnp.int32))
    dec, _ = attention_apply(spec, p, x[:, 8:9], cache=cache,
                             cache_pos=jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, 8]),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_chunk_invariance(rng):
    x = jnp.asarray(rng.normal(size=(1, 16, 16)), jnp.float32)
    outs = []
    for qc in (2, 4, 16):
        spec = AttentionSpec("c", 16, 2, 2, 8, rope="none", q_chunk=qc)
        p = attention_init(jax.random.PRNGKey(5), spec)
        out, _ = attention_apply(spec, p, x)
        outs.append(np.asarray(out))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_full_capacity_equals_dense_mixture(rng):
    spec = MoESpec("m", d_model=16, d_ff=32, n_experts=2, top_k=2, n_shared=0,
                   capacity_factor=4.0, router_group=8)
    p = moe_init(jax.random.PRNGKey(6), spec)
    x = jnp.asarray(rng.normal(size=(1, 8, 16)), jnp.float32)
    y, aux = moe_apply(spec, p, x)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    pr = jax.nn.softmax(logits, -1)

    def ffn(e, xx):
        up = xx @ p["eu"]["w"][e]
        gate = xx @ p["eg"]["w"][e]
        return (jax.nn.silu(gate) * up) @ p["ed"]["w"][e]

    expect = sum(pr[..., e:e + 1] * ffn(e, x) for e in range(2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-4,
                               atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    """With capacity 0-ish the output collapses toward zero (all dropped)."""
    spec = MoESpec("m2", d_model=8, d_ff=16, n_experts=4, top_k=1, n_shared=0,
                   capacity_factor=0.01, router_group=16)
    p = moe_init(jax.random.PRNGKey(7), spec)
    x = jnp.asarray(rng.normal(size=(1, 16, 8)), jnp.float32)
    y, _ = moe_apply(spec, p, x)
    spec_full = MoESpec("m2", d_model=8, d_ff=16, n_experts=4, top_k=1,
                        n_shared=0, capacity_factor=8.0, router_group=16)
    y_full, _ = moe_apply(spec_full, p, x)
    assert float(jnp.sum(jnp.abs(y))) < float(jnp.sum(jnp.abs(y_full)))


# ---------------------------------------------------------------------------
# SSD / WKV recurrences vs sequential references
# ---------------------------------------------------------------------------

def _ssd_ref(x, da, B, C, init=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    S = np.zeros((b, h, n, p)) if init is None else np.array(init)
    ys = []
    for t in range(s):
        S = S * np.exp(np.array(da[:, t]))[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", np.array(B[:, t]), np.array(x[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.array(C[:, t]), S))
    return np.stack(ys, 1), S


@pytest.mark.parametrize("chunk", [4, 16, 96])
def test_ssd_chunked_vs_sequential(chunk, rng):
    b, s, h, p, n = 2, 96, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    da = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))) * 0.3, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    init = jnp.asarray(rng.normal(size=(b, h, n, p)), jnp.float32)
    y, fin = _ssd_chunked(x, da, B, C, chunk=chunk, init_state=init)
    yr, Sr = _ssd_ref(x, da, B, C, init)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), Sr, rtol=2e-4, atol=2e-4)


def _wkv_ref(r, k, v, logw, u, init=None):
    b, s, h, n = r.shape
    S = np.zeros((b, h, n, n)) if init is None else np.array(init)
    ys = []
    for t in range(s):
        kv = np.einsum("bhn,bhm->bhnm", np.array(k[:, t]), np.array(v[:, t]))
        ys.append(np.einsum("bhn,bhnm->bhm", np.array(r[:, t]),
                            S + np.array(u)[None, :, :, None] * kv))
        S = np.exp(np.array(logw[:, t]))[..., None] * S + kv
    return np.stack(ys, 1), S


@pytest.mark.parametrize("decay_scale", [0.3, 7.0])
def test_wkv_chunked_vs_sequential(decay_scale, rng):
    b, s, h, n = 2, 64, 3, 4
    r = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    logw = jnp.maximum(jnp.asarray(
        -np.abs(rng.normal(size=(b, s, h, n))) * decay_scale, jnp.float32), -7.5)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    init = jnp.asarray(rng.normal(size=(b, h, n, n)), jnp.float32)
    y, fin = _wkv_chunked(r, k, v, logw, u, chunk=16, init_state=init)
    yr, Sr = _wkv_ref(r, k, v, logw, u, init)
    assert not np.any(np.isnan(np.asarray(y)))
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(fin), Sr, rtol=2e-4, atol=5e-4)
