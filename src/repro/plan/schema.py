"""ExecutionPlan schema: the deployable artifact of the DSE.

A plan is the bridge between *search* and *execute*: ``python -m repro.dse
--emit-plan`` compiles the winning ``DSEResult`` into one
:class:`ExecutionPlan`, and ``launch/serve.py --plan`` installs it so the
model's TT projections contract along the searched path, through the
searched kernel backend, with the searched dataflow and tiling.

The JSON wire format is versioned and documented in
``docs/plan_format.md``; serialization is *canonical* (sorted keys,
fixed indentation) so that serialize -> deserialize -> re-serialize is
byte-identical — the round-trip property ``tests/test_plan.py`` asserts.

Layer plans are keyed by the projection's ``LinearSpec.name``
(``attn.wq``, ``mlp.wd``, ``head``, ...).  The DSE explores one problem
per layer *instance* (``attn.wq[0]``, ``attn.wq[1]``, ...), but the model
executes repeated blocks under one ``lax.scan`` — all instances share one
trace — so the compiler collapses instances to a single entry per
projection family (identical networks get identical argmins, making the
collapse lossless).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Optional, Sequence

from repro.hw import HW_TARGETS, HardwareConfig

#: current wire-format version.  v2 added per-layer *backward* entries
#: (training-aware plans); v3 embeds the full hardware architecture the
#: plan was searched for (``hardware`` — the co-searched winner under
#: ``--hw-search``, else the named target); v4 embeds the searched TT
#: *factorization* per layer (``factorization`` — modes + ranks +
#: accuracy proxy from ``repro.rank``; ``null`` = the model's frozen
#: TTConfig decomposition).  Older files are migrated on load — see
#: :func:`migrate_plan_json`.
PLAN_FORMAT_VERSION = 4

#: versions :func:`ExecutionPlan.from_json` accepts (older ones migrate up)
SUPPORTED_VERSIONS = (1, 2, 3, 4)

#: executor backends a layer plan may name
BACKENDS = ("jnp", "tt_gemm", "streaming_tt")

#: how the plan's kernel tilings were derived: the compiler's analytic
#: heuristic (dominant GEMM + architecture caps), or the measured argmin
#: of the empirical autotuner (``repro.tune``) — provenance, not behavior
TILING_MODES = ("heuristic", "measured")

#: serving phase the plan was searched for: ``""`` (phase-agnostic —
#: every pre-pair plan), ``"prefill"`` (prompt-token batch sizes) or
#: ``"decode"`` (per-step token counts).  Optional wire field (absent =
#: ``""``), so existing v3 readers stay compatible.  The serve driver
#: refuses to install a plan under the wrong phase
#: (``check_plan_for_config(..., phase=...)``).
PHASES = ("", "prefill", "decode")

_DATAFLOWS = ("IS", "OS", "WS")


@dataclasses.dataclass(frozen=True)
class Tiling:
    """Kernel tiling decision <T_M, T_K, T_N> (+ token block).

    ``block_m/k/n`` drive the ``tt_gemm`` BlockSpecs; ``block_tokens`` is
    the streamed token-block size of the ``streaming_tt`` kernel.  The
    ``jnp`` backend ignores all four.
    """

    block_m: int = 128
    block_k: int = 128
    block_n: int = 128
    block_tokens: int = 256

    def __post_init__(self) -> None:
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"tiling.{f.name} must be a positive int, got {v!r}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping) -> "Tiling":
        return cls(**{f.name: int(d[f.name]) for f in dataclasses.fields(cls)})


@dataclasses.dataclass(frozen=True)
class BackwardOp:
    """One backward-pass contraction of a layer (schema v2).

    ``wrt`` names the gradient: ``"dx"`` for the activation gradient or a
    forward-network core node (``"G1"``...) for a weight gradient.  The
    ``path_steps`` replay the DSE-searched backward contraction order of
    that gradient's tensor network (``repro.core.backward``); ``backend``
    and ``tiling`` route it through a kernel, exactly like the forward.
    The backward pass shares the layer's dataflow (one hardware
    configuration per layer per step — the training cost model's
    assumption).
    """

    wrt: str
    path_index: int
    path_steps: tuple[tuple[int, int], ...]
    backend: str
    tiling: Tiling = Tiling()

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backward[{self.wrt}]: unknown backend "
                             f"{self.backend!r}")
        if self.backend == "streaming_tt" and self.wrt != "dx":
            raise ValueError(
                f"backward[{self.wrt}]: streaming_tt streams a single "
                "operand — only the dx gradient qualifies")
        for s in self.path_steps:
            if len(s) != 2:
                raise ValueError(f"backward[{self.wrt}]: malformed step {s!r}")

    def to_json(self) -> dict:
        return {
            "wrt": self.wrt,
            "path_index": self.path_index,
            "path_steps": [list(s) for s in self.path_steps],
            "backend": self.backend,
            "tiling": self.tiling.to_json(),
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "BackwardOp":
        return cls(
            wrt=str(d["wrt"]),
            path_index=int(d["path_index"]),
            path_steps=tuple((int(i), int(j)) for i, j in d["path_steps"]),
            backend=str(d["backend"]),
            tiling=Tiling.from_json(d["tiling"]),
        )


@dataclasses.dataclass(frozen=True)
class Factorization:
    """The searched TT decomposition of one projection (schema v4).

    Emitted by the rank search (``repro.rank``): the weight matrix is
    reshaped to ``out_modes x in_modes`` and decomposed with the
    ``ranks`` interior TT ranks.  Installing a plan that carries
    factorizations overrides the model's TTConfig-derived core shapes —
    parameter shapes change, so a factorized plan must be installed
    *before* ``init_params`` (``models.api(cfg, plan=...)``).
    ``accuracy_proxy`` is provenance: the candidate's weighted relative
    reconstruction error at search time.
    """

    out_modes: tuple[int, ...]
    in_modes: tuple[int, ...]
    ranks: tuple[int, ...]
    accuracy_proxy: float = 0.0

    def __post_init__(self) -> None:
        for field, want_pos in (("out_modes", True), ("in_modes", True),
                                ("ranks", True)):
            vals = getattr(self, field)
            if not vals or any(not isinstance(v, int) or v < 1 for v in vals):
                raise ValueError(
                    f"factorization.{field} must be positive ints, got {vals!r}")
        n_cuts = len(self.out_modes) + len(self.in_modes) - 1
        if len(self.ranks) != n_cuts:
            raise ValueError(
                f"factorization needs {n_cuts} interior ranks for "
                f"{len(self.out_modes)}+{len(self.in_modes)} modes, "
                f"got {len(self.ranks)}")

    @property
    def triple(self) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
        """(out_modes, in_modes, ranks) — the ``LinearSpec`` override form."""
        return (self.out_modes, self.in_modes, self.ranks)

    def to_json(self) -> dict:
        return {
            "out_modes": list(self.out_modes),
            "in_modes": list(self.in_modes),
            "ranks": list(self.ranks),
            "accuracy_proxy": self.accuracy_proxy,
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "Factorization":
        return cls(
            out_modes=tuple(int(m) for m in d["out_modes"]),
            in_modes=tuple(int(m) for m in d["in_modes"]),
            ranks=tuple(int(r) for r in d["ranks"]),
            accuracy_proxy=float(d.get("accuracy_proxy", 0.0)),
        )


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Deployment decision for one projection family.

    ``path_steps`` makes the plan self-contained: the pairwise contraction
    order is replayed verbatim at execution time (current-index semantics
    of ``TensorNetwork.contract_pair``), independent of path-search
    determinism.  ``path_index`` is provenance — the candidate's rank in
    the MAC-sorted top-K list (0 = MAC-optimal).
    """

    name: str
    path_index: int
    path_steps: tuple[tuple[int, int], ...]
    dataflow: str                      # "IS" | "OS" | "WS"
    partitioning: tuple[int, int]      # (1,1) | (1,2) | (2,1)
    backend: str                       # "jnp" | "tt_gemm" | "streaming_tt"
    tiling: Tiling = Tiling()
    #: v2: searched backward contractions (empty = inference-only plan;
    #: the executor then derives default backward paths at trace time)
    backward: tuple = ()               # tuple[BackwardOp, ...]
    #: v4: the searched TT decomposition (None = the model's frozen
    #: TTConfig factorization) — installed as a per-layer core-shape
    #: override, so it changes parameter shapes (see Factorization)
    factorization: Optional[Factorization] = None
    #: fusion segmentation of ``path_steps``: contiguous half-open
    #: ``(start, end)`` step ranges covering the whole path.  Ranges
    #: spanning >= 2 steps execute as ONE fused ``pallas_call`` with
    #: fp32 VMEM-resident intermediates (``kernels/fused_path.py``);
    #: singletons keep the per-step GEMM route.  Only meaningful for the
    #: ``tt_gemm`` backend.  Optional wire field (absent/``null`` =
    #: per-step execution throughout), so pre-fusion v4 readers stay
    #: compatible — no schema bump.
    segments: Optional[tuple[tuple[int, int], ...]] = None
    # provenance (not used by the executor)
    macs: int = 0
    latency_s: float = 0.0
    bwd_latency_s: float = 0.0
    instances: int = 1

    def __post_init__(self) -> None:
        if self.dataflow not in _DATAFLOWS:
            raise ValueError(f"{self.name}: unknown dataflow {self.dataflow!r}")
        if self.backend not in BACKENDS:
            raise ValueError(f"{self.name}: unknown backend {self.backend!r}")
        if len(self.partitioning) != 2:
            raise ValueError(f"{self.name}: partitioning must be (rows, cols)")
        for s in self.path_steps:
            if len(s) != 2:
                raise ValueError(f"{self.name}: malformed path step {s!r}")
        for op in self.backward:
            if not isinstance(op, BackwardOp):
                raise ValueError(
                    f"{self.name}: backward entries must be BackwardOp, "
                    f"got {type(op).__name__}")
        wrts = [op.wrt for op in self.backward]
        if len(set(wrts)) != len(wrts):
            raise ValueError(f"{self.name}: duplicate backward wrt entries")
        if self.factorization is not None:
            if not isinstance(self.factorization, Factorization):
                raise ValueError(
                    f"{self.name}: factorization must be a Factorization, "
                    f"got {type(self.factorization).__name__}")
            f = self.factorization
            # the layer network has one node per core plus the input, so a
            # full contraction takes exactly n_cores pairwise steps
            want = len(f.out_modes) + len(f.in_modes)
            if self.path_steps and len(self.path_steps) != want:
                raise ValueError(
                    f"{self.name}: {len(self.path_steps)} path steps but the "
                    f"factorization has {want} cores")
        if self.segments is not None:
            if self.backend != "tt_gemm":
                raise ValueError(
                    f"{self.name}: segments only apply to the tt_gemm "
                    f"backend, not {self.backend!r}")
            pos = 0
            for seg in self.segments:
                if len(seg) != 2 or seg[0] != pos or seg[1] <= seg[0]:
                    raise ValueError(
                        f"{self.name}: segments must contiguously cover "
                        f"[0, {len(self.path_steps)}), got "
                        f"{[list(s) for s in self.segments]}")
                pos = seg[1]
            if pos != len(self.path_steps):
                raise ValueError(
                    f"{self.name}: segments cover [0, {pos}) but the path "
                    f"has {len(self.path_steps)} steps")

    def with_backend(self, backend: str) -> "LayerPlan":
        """Force every contraction of the layer — forward AND backward —
        onto ``backend``.  The one carve-out: ``streaming_tt`` streams a
        single operand, so weight-gradient ops get ``tt_gemm`` (the
        closest kernel) instead.
        """
        def bwd_backend(op: "BackwardOp") -> str:
            if backend == "streaming_tt" and op.wrt != "dx":
                return "tt_gemm"
            return backend

        bwd = tuple(dataclasses.replace(op, backend=bwd_backend(op))
                    for op in self.backward)
        # segments describe tt_gemm fused runs; other backends drop them
        return dataclasses.replace(
            self, backend=backend, backward=bwd,
            segments=self.segments if backend == "tt_gemm" else None)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "path_index": self.path_index,
            "path_steps": [list(s) for s in self.path_steps],
            "dataflow": self.dataflow,
            "partitioning": list(self.partitioning),
            "backend": self.backend,
            "tiling": self.tiling.to_json(),
            "backward": [op.to_json() for op in self.backward],
            "factorization": (self.factorization.to_json()
                              if self.factorization is not None else None),
            "segments": ([list(s) for s in self.segments]
                         if self.segments is not None else None),
            "macs": self.macs,
            "latency_s": self.latency_s,
            "bwd_latency_s": self.bwd_latency_s,
            "instances": self.instances,
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "LayerPlan":
        return cls(
            name=str(d["name"]),
            path_index=int(d["path_index"]),
            path_steps=tuple((int(i), int(j)) for i, j in d["path_steps"]),
            dataflow=str(d["dataflow"]),
            partitioning=(int(d["partitioning"][0]), int(d["partitioning"][1])),
            backend=str(d["backend"]),
            tiling=Tiling.from_json(d["tiling"]),
            backward=tuple(BackwardOp.from_json(b)
                           for b in d.get("backward", [])),
            factorization=(Factorization.from_json(d["factorization"])
                           if d.get("factorization") is not None else None),
            segments=(tuple((int(s), int(e)) for s, e in d["segments"])
                      if d.get("segments") is not None else None),
            macs=int(d.get("macs", 0)),
            latency_s=float(d.get("latency_s", 0.0)),
            bwd_latency_s=float(d.get("bwd_latency_s", 0.0)),
            instances=int(d.get("instances", 1)),
        )


@dataclasses.dataclass(frozen=True)
class PlanSharding:
    """Sharding provenance: the mesh context the plan was searched for.

    Stamped by ``repro.dse --shards N`` (or an installed
    :class:`~repro.sharding.ShardingRules` mesh at search time): problem
    networks, cost tables, and tilings were all evaluated at
    ``tokens_per_shard`` — the per-device token block the shard_map
    executor (:mod:`repro.plan.sharded`) actually streams — instead of
    the global batch.  ``axes`` records the (mesh axis, size) pairs the
    token dim shards over; purely descriptive, execution re-derives the
    mapping from the rules installed at run time.  Optional wire field
    (absent = searched unsharded), so existing v4 readers stay
    compatible — no schema bump.
    """

    n_shards: int
    axes: tuple[tuple[str, int], ...] = ()
    tokens_per_shard: int = 0

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.tokens_per_shard < 0:
            raise ValueError(
                f"tokens_per_shard must be >= 0, got {self.tokens_per_shard}")

    def to_json(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "axes": [[a, int(s)] for a, s in self.axes],
            "tokens_per_shard": self.tokens_per_shard,
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "PlanSharding":
        return cls(
            n_shards=int(d["n_shards"]),
            axes=tuple((str(a), int(s)) for a, s in d.get("axes", ())),
            tokens_per_shard=int(d.get("tokens_per_shard", 0)),
        )


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The installable compilation of one DSE run."""

    layers: tuple[LayerPlan, ...]
    arch: str = ""
    hw: str = ""
    objective: str = "latency"
    strategy: str = ""
    tokens: int = 0
    total_latency_s: float = 0.0
    #: v3: the full architecture the plan was searched for — the
    #: co-searched winner under ``--hw-search``, else the named target.
    #: ``None`` only for migrated plans whose ``hw`` name is unregistered.
    hardware: Optional[HardwareConfig] = None
    #: tiling provenance: ``"measured"`` when the per-layer tilings are
    #: the autotuner's measured argmin (``repro.tune``), else the
    #: compiler's analytic heuristic.  Optional wire field (absent =
    #: ``"heuristic"``), so v3 readers stay compatible.
    tilings: str = "heuristic"
    #: serving-phase hint (see :data:`PHASES`) — ``--emit-plan-pair``
    #: stamps the two halves so drivers can refuse a swapped pair
    phase: str = ""
    #: sharding provenance (``None`` = searched unsharded); optional on
    #: the wire — absent in plans emitted before the shard axis existed
    sharding: Optional[PlanSharding] = None
    version: int = PLAN_FORMAT_VERSION

    def __post_init__(self) -> None:
        names = [lp.name for lp in self.layers]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate layer plans for {dup}")
        if self.tilings not in TILING_MODES:
            raise ValueError(
                f"unknown tilings provenance {self.tilings!r}; "
                f"have {TILING_MODES}")
        if self.phase not in PHASES:
            raise ValueError(
                f"unknown phase {self.phase!r}; have {PHASES}")
        if self.hardware is not None and not isinstance(self.hardware,
                                                        HardwareConfig):
            raise ValueError(
                f"hardware must be a repro.hw.HardwareConfig, "
                f"got {type(self.hardware).__name__}")

    def layer(self, name: str) -> Optional[LayerPlan]:
        for lp in self.layers:
            if lp.name == name:
                return lp
        return None

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(lp.name for lp in self.layers)

    def with_backend(self, backend: str) -> "ExecutionPlan":
        """A copy with every layer forced onto ``backend``."""
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        return dataclasses.replace(
            self, layers=tuple(lp.with_backend(backend) for lp in self.layers))

    # -- canonical JSON round-trip ----------------------------------------
    def to_json(self) -> dict:
        return {
            "format": "repro.execution_plan",
            "version": self.version,
            "arch": self.arch,
            "hw": self.hw,
            "hardware": (self.hardware.to_json()
                         if self.hardware is not None else None),
            "objective": self.objective,
            "phase": self.phase,
            "strategy": self.strategy,
            "tilings": self.tilings,
            "tokens": self.tokens,
            "total_latency_s": self.total_latency_s,
            "sharding": (self.sharding.to_json()
                         if self.sharding is not None else None),
            "layers": [lp.to_json() for lp in self.layers],
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "ExecutionPlan":
        fmt = d.get("format", "repro.execution_plan")
        if fmt != "repro.execution_plan":
            raise ValueError(f"not an execution plan (format={fmt!r})")
        version = int(d.get("version", -1))
        if version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"plan format version {version} unsupported "
                f"(this build reads versions {SUPPORTED_VERSIONS})")
        d = migrate_plan_json(d)
        hardware = d.get("hardware")
        return cls(
            layers=tuple(LayerPlan.from_json(l) for l in d["layers"]),
            arch=str(d.get("arch", "")),
            hw=str(d.get("hw", "")),
            objective=str(d.get("objective", "latency")),
            strategy=str(d.get("strategy", "")),
            tokens=int(d.get("tokens", 0)),
            total_latency_s=float(d.get("total_latency_s", 0.0)),
            hardware=(HardwareConfig.from_json(hardware)
                      if hardware is not None else None),
            tilings=str(d.get("tilings", "heuristic")),
            phase=str(d.get("phase", "")),
            sharding=(PlanSharding.from_json(d["sharding"])
                      if d.get("sharding") is not None else None),
            version=PLAN_FORMAT_VERSION,
        )

    def dumps(self) -> str:
        """Canonical serialization (stable across round-trips)."""
        return json.dumps(self.to_json(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def loads(cls, text: str) -> "ExecutionPlan":
        return cls.from_json(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())


def migrate_plan_json(d: Mapping) -> dict:
    """Upgrade a plan JSON dict to the current version (idempotent).

    v1 -> v2: layers gain an empty ``backward`` list (and zero
    ``bwd_latency_s`` provenance) — a v1 plan is an inference-only v2
    plan.  v2 -> v3: the plan gains a ``hardware`` object resolved from
    its ``hw`` target name through the ``repro.hw`` registry (``null``
    when the name is unregistered — the plan still installs; only the
    embedded-architecture provenance is missing).  v3 -> v4: every layer
    gains ``"factorization": null`` — a pre-rank-search plan runs the
    model's frozen TTConfig decomposition.  Each migration is
    deterministic, so ``loads(old).dumps()`` -> ``loads(...)`` ->
    ``dumps()`` is bit-stable (the round-trip property
    ``tests/test_plan.py`` asserts).
    """
    version = int(d.get("version", -1))
    if version == PLAN_FORMAT_VERSION:
        return dict(d)
    if version == 1:
        out = dict(d)
        out["version"] = 2
        out["layers"] = [
            {**layer, "backward": layer.get("backward", []),
             "bwd_latency_s": layer.get("bwd_latency_s", 0.0)}
            for layer in d["layers"]
        ]
        return migrate_plan_json(out)
    if version == 2:
        out = dict(d)
        out["version"] = 3
        if out.get("hardware") is None:
            target = HW_TARGETS.get(str(d.get("hw", "")))
            out["hardware"] = target.to_json() if target is not None else None
        return migrate_plan_json(out)
    if version == 3:
        out = dict(d)
        out["version"] = 4
        out["layers"] = [
            {**layer, "factorization": layer.get("factorization")}
            for layer in d["layers"]
        ]
        return out
    raise ValueError(f"cannot migrate plan version {version}")


def load_plan(path: str) -> ExecutionPlan:
    with open(path) as f:
        return ExecutionPlan.loads(f.read())
