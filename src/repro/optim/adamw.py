"""AdamW with decoupled weight decay; fp32 moments over any param dtype.

Moments inherit the *sharding* of their parameters (they are created by
``jax.tree.map`` inside the jitted step, so GSPMD propagates the param
shardings) — the optimizer is FSDP-transparent.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    m: Any               # fp32 tree like params
    v: Any               # fp32 tree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: Callable[[jax.Array], jax.Array] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Any, AdamWState]:
    """Returns (new_params, new_state)."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
