"""Guided exploration of the joint (arch, path, dataflow) design space.

The exhaustive search in ``repro.core.dse`` is the optimality oracle;
this package is the scaling story: a budgeted evolutionary driver
(:func:`guided_search`) that scores candidate encodings
(:class:`Genome`) by reads of the same vectorized cost tables and
refines promising architectures *exactly* — so with budget to visit
everything it returns the oracle's answer bit-for-bit, and with less it
degrades gracefully (never worse than the fixed target, monotone in the
budget).  ``python -m repro.dse --search guided`` is the CLI entry;
``tests/test_search_oracle.py`` holds the differential-oracle contract.
"""

from .encoding import ARCH_NEIGHBORS, Genome, JointSpace
from .guided import (
    DEFAULT_BUDGET_FRACTION,
    POPULATION,
    BudgetExhausted,
    guided_search,
)

__all__ = [
    "ARCH_NEIGHBORS",
    "BudgetExhausted",
    "DEFAULT_BUDGET_FRACTION",
    "Genome",
    "JointSpace",
    "POPULATION",
    "guided_search",
]
