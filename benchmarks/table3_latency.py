"""Table 3 — end-to-end latency: TT-optimized vs dense baseline.

The paper measures 3.28-4.00x (inference) and 3.42-3.85x (training)
speedups on the VU9P.  Here both sides run through the same simulator:
dense layers execute their single GEMM under the best dataflow; TT layers
execute the DSE-optimal (path, partitioning, dataflow).  Training is
modelled as 3x tokens (see table2 note).
"""

from __future__ import annotations

from repro.core import (
    ALL_DATAFLOWS,
    FPGA_VU9P,
    find_topk_paths,
    global_search,
    greedy_path,
    layer_latency,
)
from repro.models.vision import model_layers
from .common import emit

PAPER = {
    ("resnet18", "cifar10", "inference"): 4.00,
    ("resnet18", "tiny_imagenet", "inference"): 3.92,
    ("vit_ti4", "cifar10", "inference"): 3.28,
    ("resnet18", "cifar10", "training"): 3.85,
    ("resnet18", "tiny_imagenet", "training"): 3.82,
    ("vit_ti4", "cifar10", "training"): 3.42,
}


def _dense_latency(layers) -> float:
    total = 0.0
    for l in layers:
        path = greedy_path(l.dense_network)   # single GEMM
        total += min(
            layer_latency(path, d, (1, 1), FPGA_VU9P).seconds
            for d in ALL_DATAFLOWS
        )
    return total


def _tt_latency(layers) -> float:
    layer_paths = [find_topk_paths(l.tt_network, k=4) for l in layers]
    return global_search(layer_paths, FPGA_VU9P).total_latency_s


def run() -> list[dict]:
    rows = []
    for model, dataset in [("resnet18", "cifar10"),
                           ("resnet18", "tiny_imagenet"),
                           ("vit_ti4", "cifar10")]:
        for mode, batch in (("inference", 1), ("training", 3)):
            layers = model_layers(model, dataset, batch=batch)
            dense = _dense_latency(layers)
            tt = _tt_latency(layers)
            rows.append({
                "model": model,
                "dataset": dataset,
                "mode": mode,
                "dense_ms": dense * 1e3,
                "tt_opt_ms": tt * 1e3,
                "speedup": dense / tt,
                "paper_speedup": PAPER[(model, dataset, mode)],
            })
    emit("table3_latency", rows)
    return rows


if __name__ == "__main__":
    run()
