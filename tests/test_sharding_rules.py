"""Logical-axis sharding rules (no devices needed — pure spec logic)."""

from jax.sharding import PartitionSpec as P

from repro.sharding import ShardingRules


def rules(sp=False, multi=False):
    axes = {"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16}
    return ShardingRules(
        axis_sizes=axes,
        batch_axes=("pod", "data") if multi else ("data",),
        model_axis="model",
        seq_axis="model" if sp else None,
    )


def test_batch_and_model_resolution():
    r = rules()
    spec = r.partition_spec((256, 4096, 512), ("batch", None, "model"))
    assert spec == P("data", None, "model")


def test_indivisible_dim_replicates():
    r = rules()
    spec = r.partition_spec((10, 4096, 512), ("batch", None, "model"))
    assert spec == P(None, None, "model")
    spec = r.partition_spec((256, 4096, 10), ("batch", None, "model"))
    assert spec == P("data", None, None)


def test_seq_axis_off_means_replicated():
    r = rules(sp=False)
    spec = r.partition_spec((32, 4096, 512), ("batch", "seq", None))
    assert spec == P("data", None, None)


def test_sp_uses_model_once():
    """With SP on, seq takes the model axis; heads cannot reuse it."""
    r = rules(sp=True)
    spec = r.partition_spec((32, 4096, 32, 128), ("batch", "seq", "model", None))
    assert spec == P("data", "model", None, None)


def test_multipod_batch_axes():
    r = rules(multi=True)
    spec = r.partition_spec((256, 4096), ("batch", None))
    assert spec == P(("pod", "data"), None)


def test_tokens_axis_merges_dp_and_sp():
    r = rules(sp=True)
    spec = r.partition_spec((256 * 4096, 16), ("tokens", None))
    assert spec == P(("data", "model"), None)
    r2 = rules(sp=False)
    assert r2.partition_spec((1024, 16), ("tokens", None)) == P(("data",), None)


def test_no_rules_installed_noop():
    import jax.numpy as jnp
    from repro.sharding import shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x
