"""Fault-tolerant step-loop machinery.

* ``PreemptionGuard`` — converts SIGTERM/SIGINT into a checkpoint-then-
  exit at the next step boundary (the TPU preemption contract).
* ``StragglerMonitor`` — per-step wall-time EMA + robust deviation; flags
  steps slower than ``threshold``x the running median.  On a real pod the
  per-host heartbeats feed this; the single-host build monitors the jitted
  step itself (the mechanism, not the telemetry transport, is what the
  framework provides).
* ``FaultTolerantLoop`` — wraps a step function with bounded retry +
  restore-from-checkpoint: a step that raises is retried after restoring
  the last good state; repeated failure at the same step aborts (poison
  batch guard).  Combined with the stateless data pipeline, recovery is
  bit-exact.
"""

from __future__ import annotations

import signal
import time
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager


class PreemptionGuard:
    def __init__(self):
        self._preempted = False
        self._orig: dict[int, Any] = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._orig[sig] = signal.signal(sig, self._handler)
        return self

    def __exit__(self, *exc):
        for sig, h in self._orig.items():
            signal.signal(sig, h)
        return False

    def _handler(self, signum, frame):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the running median."""

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        history = self.times[-self.window:]
        self.times.append(seconds)
        if len(history) < 4:
            return False
        median = sorted(history)[len(history) // 2]
        if seconds > self.threshold * median:
            self.flagged.append(step)
            return True
        return False


class FaultTolerantLoop:
    """Runs ``step_fn(state, step) -> state`` with checkpointed recovery."""

    def __init__(
        self,
        step_fn: Callable[[Any, int], Any],
        manager: CheckpointManager,
        checkpoint_every: int = 50,
        max_retries_per_step: int = 2,
        straggler: Optional[StragglerMonitor] = None,
        on_restore: Optional[Callable[[Any], Any]] = None,
    ):
        self.step_fn = step_fn
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.max_retries = max_retries_per_step
        self.straggler = straggler or StragglerMonitor()
        self.on_restore = on_restore
        self.recoveries = 0

    def run(self, state: Any, start_step: int, num_steps: int) -> tuple[Any, int]:
        """Returns (final_state, last_completed_step + 1)."""
        step = start_step
        retries = 0
        with PreemptionGuard() as guard:
            while step < start_step + num_steps:
                t0 = time.monotonic()
                try:
                    state = self.step_fn(state, step)
                except Exception:
                    retries += 1
                    self.recoveries += 1
                    if retries > self.max_retries:
                        raise
                    latest = self.manager.latest_step()
                    if latest is not None:
                        _, state = self.manager.restore(state, latest)
                        if self.on_restore is not None:
                            state = self.on_restore(state)
                        step = latest
                    continue
                retries = 0
                self.straggler.record(step, time.monotonic() - t0)
                step += 1
                if step % self.checkpoint_every == 0 or guard.preempted:
                    self.manager.save_async(step, state)
                if guard.preempted:
                    self.manager.wait()
                    break
        self.manager.wait()
        return state, step
