"""Fused-segment execution vs the per-step spill route, wall-clock.

For representative ``tt-lm-100m`` serving shapes (a prefill-sized and a
decode-sized token batch), this benchmark times the per-step ``tt_gemm``
route (one ``pallas_call`` per contraction step, every intermediate
round-tripping HBM) against the fusion-segmented route (chain runs
executed inside one ``pallas_call`` with fp32 VMEM-resident
intermediates), and checks the two routes agree bit-for-bit.

Interpret-mode wall-clock on CPU measures Python-level kernel-body
evaluation plus per-call dispatch — the launch-overhead component the
fused path amortizes is real on every backend; the analytic fused cost
model (``core/cost_table.fused_cost_tables``) carries the HBM-traffic
story.

  PYTHONPATH=src python -m benchmarks.bench_fused_exec
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import fusion
from repro.core.paths import find_topk_paths
from repro.kernels import ops
from repro.tune.measure import (
    measure_fused,
    measure_per_step,
    synthesize_network_tensors,
)

from .common import emit

#: (phase, tokens) serving shapes; prefill streams a whole prompt
#: bucket, decode a small slot batch
SHAPES = [("prefill", 256), ("decode", 8)]

VMEM_BUDGET = 8 * 2**20


def _layer_pick(named):
    """One attention + one MLP projection (first of each family)."""
    picked, seen = [], set()
    for name, tn in named:
        fam = name.split(".")[0].split("[")[0]
        if fam not in seen:
            seen.add(fam)
            picked.append((name, tn))
    return picked[:2]


def _routes_bit_identical(tn, steps, segments, block_tokens) -> bool:
    """Execute both routes once on the same tensors, compare bits."""
    tensors = synthesize_network_tensors(tn)
    contract = ops.gemm_contract(interpret=True)

    def seq_step(w, i, j, fn):
        (ea, ta), (eb, tb) = w[i], w[j]
        shared = [x for x in ea if x in eb]
        val = fn(ta, tb, ea, eb, shared)
        ec = tuple(x for x in ea if x not in shared) + tuple(
            x for x in eb if x not in shared)
        w = [q for t, q in enumerate(w) if t not in (i, j)]
        w.append((ec, val))
        return w

    def per_step_fn(ta, tb, ea, eb, shared):
        return contract(ta, tb, (tuple(ea.index(x) for x in shared),
                                 tuple(eb.index(x) for x in shared)))

    plain = [(n.edges, tensors[n.name]) for n in tn.nodes]
    for i, j in steps:
        plain = seq_step(plain, i, j, per_step_fn)
    ec_p, val_p = plain[-1]

    seg = [(n.edges, tensors[n.name]) for n in tn.nodes]
    for s, e in segments:
        if e - s >= 2:
            ec, val = ops.fused_segment(seg, steps[s:e],
                                        block_tokens=block_tokens,
                                        interpret=True)
            for i, j in steps[s:e]:
                seg = [w for t, w in enumerate(seg) if t not in (i, j)]
                seg.append(None)
            seg[-1] = (ec, val)
        else:
            seg = seq_step(seg, *steps[s], per_step_fn)
    ec_s, val_s = seg[-1]

    a, b = np.asarray(val_s), np.asarray(val_p)
    if ec_s != ec_p:
        b = np.transpose(b, [ec_p.index(x) for x in ec_s])
    return bool(np.array_equal(a.view(np.uint32), b.view(np.uint32)))


def _bench_one(phase: str, tokens: int, name: str, tn) -> dict:
    steps = tuple(tuple(s) for s in find_topk_paths(tn, k=4)[0].steps)
    bt = ops.clamp_block(256, tokens)
    segs = fusion.segment_path(tn, steps, block_tokens=bt,
                               budget_bytes=VMEM_BUDGET)
    per_step_s = measure_per_step(tn, steps, interpret=True)
    fused_s = measure_fused(tn, steps, segs, bt, interpret=True)
    return {
        "phase": phase,
        "layer": name,
        "tokens": tokens,
        "n_steps": len(steps),
        "n_segments": len(segs),
        "n_fused_runs": sum(1 for s, e in segs if e - s >= 2),
        "per_step_ms": per_step_s * 1e3,
        "fused_ms": fused_s * 1e3,
        "speedup": per_step_s / fused_s if fused_s else float("nan"),
        "bit_identical": _routes_bit_identical(tn, steps, segs, bt),
    }


def run() -> list[dict]:
    from repro.dse_cli import model_dse_layers

    cfg = get_config("tt-lm-100m", tt=True, smoke=False)
    rows = []
    for phase, tokens in SHAPES:
        named = model_dse_layers(cfg, tokens=tokens)
        for name, tn in _layer_pick(named):
            rows.append(_bench_one(phase, tokens, name, tn))
    emit("BENCH_fused", rows)
    ok = all(r["bit_identical"] for r in rows)
    best = max(r["speedup"] for r in rows)
    print(f"# fused vs per-step: best speedup {best:.2f}x, "
          f"bit-identical: {ok}")
    return rows


if __name__ == "__main__":
    run()
