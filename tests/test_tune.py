"""Measured-latency autotuner: cache, sweeps, measured plans, calibration.

Covers the acceptance criteria of the autotuner PR:

1. the tuning cache round-trips bit-stably and is keyed by device kind
   (and interpret flag) — one machine's numbers never leak onto another;
2. ``compile_plan(tilings="measured")`` emits plans that validate
   against schema v3 and, over a warm cache, replay with **zero**
   measurements to a bit-identical artifact;
3. ``global_search(calibration=...)`` genuinely changes the argmin when
   measurements invert the analytic per-dataflow ranking;
4. ``kernels.tt_gemm`` auto-pads non-block-multiple dims (autotuned
   tilings never need caller-side padding logic).

Most tests inject stub measurement functions into the Autotuner (fast,
deterministic); one small real-measurement test exercises the actual
harness end to end.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FPGA_VU9P, Dataflow, find_topk_paths, global_search
from repro.core.dse import apply_calibration
from repro.nn import LinearSpec, TTConfig
from repro.plan import ExecutionPlan, compile_plan
from repro.tune import (
    KERNEL_MODULES,
    Autotuner,
    TuningCache,
    gemm_variants,
    gemm_work_items,
    heuristic_blocks,
    kernel_fingerprint,
    measured_calibration,
    streaming_variants,
    variant_key,
)


# fake measurements: strictly decreasing in total block volume, so the
# argmin is always the largest feasible variant — deterministic, fast,
# and distinguishable from the (128-capped) heuristic on large shapes
def _fake_gemm(M, K, N, dataflow, blocks, **kw):
    bm, bk, bn = blocks
    return 1.0 / (bm * bk * bn)


def _fake_streaming(tn_block, steps, tokens, block_tokens, **kw):
    return 1.0 / block_tokens


def _fail_gemm(*a, **kw):
    raise AssertionError("measurement requested on a warm cache")


def _fail_streaming(*a, **kw):
    raise AssertionError("measurement requested on a warm cache")


def _stub_tuner(cache=None, mode="cache", device_kind="cpu", **kw):
    return Autotuner(cache, mode, device_kind=device_kind, interpret=True,
                     measure_gemm_fn=_fake_gemm,
                     measure_streaming_fn=_fake_streaming, **kw)


def _unit_problem(tokens=32, d_out=256):
    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, d_out, tag="mlp", tt=tt)
    tn = spec.network(tokens)
    paths = find_topk_paths(tn, k=4)
    res = global_search([paths], FPGA_VU9P)
    return spec, tn, paths, res


# ---------------------------------------------------------------------------
# cache round-trip + device keying
# ---------------------------------------------------------------------------

def test_cache_roundtrip_bit_stable(tmp_path):
    tuner = _stub_tuner()
    tuner.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    _, tn, paths, _ = _unit_problem()
    tuner.tune_streaming(tn, paths[0].steps, 32, include=[32])
    assert len(tuner.cache) == 2

    text = tuner.cache.dumps()
    loaded = TuningCache.loads(text)
    assert loaded.dumps() == text  # canonical: load -> dump is byte-stable

    path = tmp_path / "cache.json"
    tuner.cache.save(str(path))
    assert TuningCache.load(str(path)).dumps() == text
    # load -> save -> load is also stable on disk
    TuningCache.load(str(path)).save(str(path))
    assert path.read_text() == text


def test_cache_is_device_keyed():
    cache = TuningCache()
    cpu = _stub_tuner(cache, device_kind="cpu")
    best = cpu.tune_gemm(64, 64, 64, "OS")
    assert cpu.n_measured > 0

    # same shapes, different device kind: every lookup must miss
    tpu = _stub_tuner(cache, device_kind="TPU_v5e")
    assert tpu.tune_gemm(64, 64, 64, "OS") == best  # same fake model
    assert tpu.n_cache_hits == 0
    assert tpu.n_measured == cpu.n_measured
    keys = set(cache.entries)
    assert any(":cpu:" in k for k in keys)
    assert any(":TPU_v5e:" in k for k in keys)


def test_kernel_fingerprint_tracks_kernel_sources(tmp_path):
    """The fingerprint hashes the Pallas kernel sources: stable across
    calls, sensitive to any byte of any kernel file."""
    assert kernel_fingerprint() == kernel_fingerprint()
    assert len(kernel_fingerprint()) == 12
    assert len(KERNEL_MODULES) == 4

    a = tmp_path / "a.py"
    b = tmp_path / "b.py"
    a.write_text("def kernel(): return 1\n")
    b.write_text("def other(): return 2\n")
    fp = kernel_fingerprint([str(a), str(b)])
    assert kernel_fingerprint([str(a), str(b)]) == fp
    # path order must not matter (sorted before hashing)
    assert kernel_fingerprint([str(b), str(a)]) == fp
    # a one-byte kernel edit yields a different fingerprint
    a.write_text("def kernel(): return 9\n")
    assert kernel_fingerprint([str(a), str(b)]) != fp


def test_cache_is_kernel_fingerprint_keyed():
    """ROADMAP gap (d): mutating a kernel invalidates cached timings —
    entries keyed under the old fingerprint simply stop matching, so the
    tuner re-measures instead of replaying stale numbers."""
    cache = TuningCache()
    v1 = _stub_tuner(cache, kernel_fp="aaaa00000000")
    best = v1.tune_gemm(64, 64, 64, "OS")
    assert v1.n_measured > 0

    # same kernels -> warm replay, zero measurements (even with a
    # measurement fn that would fail the test if called)
    warm = Autotuner(cache, "cache", device_kind="cpu", interpret=True,
                     measure_gemm_fn=_fail_gemm,
                     measure_streaming_fn=_fail_streaming,
                     kernel_fp="aaaa00000000")
    assert warm.tune_gemm(64, 64, 64, "OS") == best
    assert warm.n_measured == 0 and warm.n_cache_hits > 0

    # mutated kernels -> every lookup misses, fresh measurements
    v2 = _stub_tuner(cache, kernel_fp="bbbb11111111")
    assert v2.tune_gemm(64, 64, 64, "OS") == best  # same fake model
    assert v2.n_cache_hits == 0 and v2.n_measured > 0
    keys = set(cache.entries)
    assert any(":kaaaa00000000" in k for k in keys)
    assert any(":kbbbb11111111" in k for k in keys)


def test_cache_rejects_foreign_json():
    with pytest.raises(ValueError, match="not a tuning cache"):
        TuningCache.loads('{"format": "something.else", "version": 1}')
    with pytest.raises(ValueError, match="version"):
        TuningCache.loads('{"format": "repro.tuning_cache", "version": 99}')


def test_entry_fingerprint_parsing():
    from repro.tune import entry_fingerprint

    assert entry_fingerprint("gemm:32:32:32:OS:cpu:i1:kdeadbeef") == "deadbeef"
    assert entry_fingerprint("gemm:32:32:32:OS:cpu:i1") is None  # pre-PR7 key
    assert entry_fingerprint("gemm:32:kXYZ") is None             # not hex


def test_merge_caches_union_and_last_writer_wins():
    from repro.tune import entry_fingerprint, merge_caches

    a = _stub_tuner(TuningCache())
    a.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    b = _stub_tuner(TuningCache())
    b.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    b.tune_gemm(64, 64, 64, "IS", include=[heuristic_blocks(64, 64, 64)])

    fp = entry_fingerprint(next(iter(a.cache.entries)))
    merged, dropped, dropped_shards = merge_caches(
        [a.cache, b.cache], fingerprint=fp)
    assert dropped == 0 and dropped_shards == 0
    assert len(merged) == 2  # union: shared key merges, new key added
    # last writer wins: the colliding entry's measurements come from b
    key = next(k for k in merged.entries if k in a.cache.entries)
    assert merged.entries[key].measured_s == b.cache.entries[key].measured_s
    # merging is idempotent
    again, _, _ = merge_caches([merged], fingerprint=fp)
    assert again.dumps() == merged.dumps()


def test_merge_caches_drops_foreign_fingerprints():
    from repro.tune import merge_caches

    a = _stub_tuner(TuningCache())
    a.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    merged, dropped, _ = merge_caches([a.cache], fingerprint="0" * 12)
    assert len(merged) == 0 and dropped == 1


def test_entry_shards_parsing():
    from repro.tune import entry_shards

    assert entry_shards("gemm:32x32x32:OS:cpu:interp:s4:kdeadbeef") == 4
    assert entry_shards("gemm:32x32x32:OS:cpu:interp:s1:kdeadbeef") == 1
    # pre-shard key: no segment
    assert entry_shards("gemm:32x32x32:OS:cpu:interp:kdeadbeef") is None


def test_tuner_keys_carry_shard_count():
    t1 = _stub_tuner(TuningCache())
    t4 = Autotuner(t1.cache, "cache", device_kind="cpu", interpret=True,
                   measure_gemm_fn=_fake_gemm,
                   measure_streaming_fn=_fake_streaming,
                   kernel_fp=t1.kernel_fp, shards=4)
    k1 = t1.gemm_key(64, 64, 64, "OS")
    k4 = t4.gemm_key(64, 64, 64, "OS")
    assert k1 != k4 and ":s1:" in k1 and ":s4:" in k4
    # a 4-shard measurement never answers a single-device lookup
    t4.tune_gemm(64, 64, 64, "OS")
    assert t1.cached_gemm_blocks(64, 64, 64, "OS") is None


def test_merge_caches_drops_shard_mismatches():
    from repro.tune import entry_fingerprint, merge_caches

    a = _stub_tuner(TuningCache())  # shards=1 keys
    a.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    b_cache = TuningCache()
    b = Autotuner(b_cache, "cache", device_kind="cpu", interpret=True,
                  measure_gemm_fn=_fake_gemm,
                  measure_streaming_fn=_fake_streaming,
                  kernel_fp=a.kernel_fp, shards=4)
    b.tune_gemm(64, 64, 64, "OS", include=[heuristic_blocks(64, 64, 64)])

    fp = entry_fingerprint(next(iter(a.cache.entries)))
    # no filter: every mesh width survives (keys never collide)
    merged, dropped, dropped_shards = merge_caches(
        [a.cache, b_cache], fingerprint=fp)
    assert len(merged) == 2 and dropped == 0 and dropped_shards == 0
    # shard filter: the 1-shard entry is a shard-shape mismatch at s4
    merged4, dropped, dropped_shards = merge_caches(
        [a.cache, b_cache], fingerprint=fp, shards=4)
    assert len(merged4) == 1 and dropped == 0 and dropped_shards == 1
    assert all(":s4:" in k for k in merged4.entries)


def test_merge_cli_roundtrip(tmp_path, capsys):
    from repro.tune import entry_fingerprint
    from repro.tune.cli import run_merge

    a = _stub_tuner(TuningCache())
    a.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    b = _stub_tuner(TuningCache())
    b.tune_gemm(64, 64, 64, "IS", include=[heuristic_blocks(64, 64, 64)])
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    out = str(tmp_path / "merged.json")
    a.cache.save(pa)
    b.cache.save(pb)

    fp = entry_fingerprint(next(iter(a.cache.entries)))
    assert run_merge([pa, pb, "-o", out, "--fingerprint", fp]) == 0
    merged = TuningCache.load(out)
    assert len(merged) == 2
    assert "2 entries kept, 0 dropped" in capsys.readouterr().err
    # unreadable input is a clean exit-2, not a traceback
    assert run_merge([str(tmp_path / "missing.json"), "-o", out]) == 2


def test_cached_tiling_lookups_never_measure():
    from repro.plan.compiler import rebatch

    t = _stub_tuner(TuningCache())
    t.tune_gemm(96, 160, 512, "OS", include=[heuristic_blocks(96, 160, 512)])
    _, tn, paths, _ = _unit_problem()
    t.tune_streaming(tn, paths[0].steps, 32, include=[32])

    warm = Autotuner(t.cache, "cache", device_kind="cpu", interpret=True,
                     measure_gemm_fn=_fail_gemm,
                     measure_streaming_fn=_fail_streaming)
    assert warm.cached_gemm_blocks(96, 160, 512, "OS") is not None
    assert warm.cached_gemm_blocks(97, 160, 512, "OS") is None  # miss: None
    assert warm.cached_streaming_tokens(tn, paths[0].steps, 32) == 32
    assert warm.cached_streaming_tokens(rebatch(tn, 64), paths[0].steps,
                                        64) is None


def test_entry_argmin_is_deterministic_on_ties():
    tuner = _stub_tuner()
    key = tuner.gemm_key(64, 64, 64, "OS")
    entry = tuner.cache.ensure(key, kind="gemm", backend="tt_gemm",
                               device_kind="cpu", interpret=True, problem={})
    entry.measured_s[variant_key((64, 64, 64))] = 1.0
    entry.measured_s[variant_key((32, 64, 64))] = 1.0
    entry.measured_s[variant_key((64, 32, 64))] = 2.0
    assert entry.best_blocks == (32, 64, 64)  # tie -> smallest variant


# ---------------------------------------------------------------------------
# variant generators
# ---------------------------------------------------------------------------

def test_gemm_variants_feasible_and_include_heuristic():
    vs = gemm_variants(96, 160, 512, include=[heuristic_blocks(96, 160, 512)])
    assert heuristic_blocks(96, 160, 512) in vs
    for bm, bk, bn in vs:
        # pow2, >= 8, never beyond the next pow2 of the dim
        for b, dim in ((bm, 96), (bk, 160), (bn, 512)):
            assert b >= 8 and (b & (b - 1)) == 0
            assert b <= max(8, 1 << (dim - 1).bit_length())
    assert vs == sorted(set(vs))


def test_streaming_variants_respect_vmem_budget():
    _, tn, paths, _ = _unit_problem(tokens=512)
    steps = paths[0].steps
    all_bt = streaming_variants(tn, steps, 512, include=[256])
    assert 256 in all_bt
    tight = streaming_variants(tn, steps, 512, include=[256],
                               budget_bytes=1)  # nothing fits
    assert tight == []
    from repro.plan import streaming_fits
    for bt in all_bt:
        assert streaming_fits(tn, steps, bt)


def test_gemm_work_items_dedup_and_order():
    _, _, paths, _ = _unit_problem()
    items = gemm_work_items([paths, paths, paths])  # repeated layers dedup
    assert len(items) == len(set(items))
    assert items == gemm_work_items([paths])
    capped = gemm_work_items([paths], max_shapes=1)
    assert len(capped) == 1 and capped[0] == items[0]


# ---------------------------------------------------------------------------
# measured plans: schema v3, zero-measurement replay, bit-identity
# ---------------------------------------------------------------------------

def test_measured_plan_validates_and_replays_from_cache(tmp_path):
    spec, tn, paths, res = _unit_problem(tokens=32)
    cache = TuningCache()
    tuner = _stub_tuner(cache)
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                        tokens=32, tilings="measured", tuner=tuner)
    assert plan.tilings == "measured"
    assert tuner.n_measured > 0

    # schema round-trip: canonical, bit-stable, version preserved
    d = plan.to_json()
    assert d["version"] == 4 and d["tilings"] == "measured"
    text = plan.dumps()
    assert ExecutionPlan.loads(text).dumps() == text

    # replay over the warm cache: zero measurements, bit-identical plan
    replay = Autotuner(cache, "cache", device_kind="cpu", interpret=True,
                       measure_gemm_fn=_fail_gemm,
                       measure_streaming_fn=_fail_streaming)
    plan2 = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                         tokens=32, tilings="measured", tuner=replay)
    assert replay.n_measured == 0 and replay.n_cache_hits > 0
    assert plan2.dumps() == text

    # a kernel-source mutation (different fingerprint) makes the same
    # cache stale: nothing replays, everything re-measures (gap (d))
    stale = _stub_tuner(cache, kernel_fp="deadbeef0000")
    plan3 = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                         tokens=32, tilings="measured", tuner=stale)
    assert stale.n_cache_hits == 0 and stale.n_measured > 0
    assert plan3.dumps() == text  # same fake measurements -> same plan


def test_measured_tilings_differ_from_heuristic_on_large_shapes():
    # tokens 512 > the heuristic's 256 block_tokens cap; the fake
    # measurements prefer the largest feasible block, so the measured
    # tiling must move
    spec, tn, paths, res = _unit_problem(tokens=512)
    plan_h = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                          tokens=512)
    assert plan_h.tilings == "heuristic"
    tuner = _stub_tuner()
    plan_m = compile_plan([("demo", tn)], res, FPGA_VU9P, arch="unit",
                          tokens=512, tilings="measured", tuner=tuner)
    (lp_h,), (lp_m,) = plan_h.layers, plan_m.layers
    assert lp_h.backend == lp_m.backend  # backend choice stays heuristic
    if lp_m.backend == "streaming_tt":
        assert lp_m.tiling.block_tokens > lp_h.tiling.block_tokens
    else:
        assert lp_m.tiling != lp_h.tiling


def test_compile_plan_rejects_bad_tiling_modes():
    _, tn, _, res = _unit_problem()
    with pytest.raises(ValueError, match="tilings"):
        compile_plan([("demo", tn)], res, FPGA_VU9P, tilings="magic")
    with pytest.raises(ValueError, match="requires a tuner"):
        compile_plan([("demo", tn)], res, FPGA_VU9P, tilings="measured")


def test_schema_rejects_unknown_tilings_provenance():
    _, tn, _, res = _unit_problem()
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P)
    with pytest.raises(ValueError, match="tilings"):
        dataclasses.replace(plan, tilings="vibes")
    # absent wire field defaults to heuristic (pre-autotuner v3 files)
    d = plan.to_json()
    del d["tilings"]
    assert ExecutionPlan.from_json(d).tilings == "heuristic"


# ---------------------------------------------------------------------------
# calibration: measured feedback can flip the DSE argmin
# ---------------------------------------------------------------------------

def test_calibration_changes_argmin_when_measurements_invert_ranking():
    _, tn, paths, base = _unit_problem()
    (choice,) = base.choices
    won = choice.dataflow
    others = [d for d in Dataflow if d is not won]

    # synthetic measurement: the analytically-chosen dataflow is 1000x
    # slower on this machine than the model believes
    calibration = {won.value: 1000.0}
    res = global_search([paths], FPGA_VU9P, calibration=calibration)
    (new,) = res.choices
    assert new.dataflow in others
    assert new.dataflow != won

    # a uniform calibration cannot move any argmin
    uniform = {d.value: 7.5 for d in Dataflow}
    res_u = global_search([paths], FPGA_VU9P, calibration=uniform)
    assert res_u.choices[0].dataflow == won
    assert res_u.total_latency_s == pytest.approx(7.5 * base.total_latency_s)


def test_apply_calibration_validation():
    table = {(0, 0, (1, 1), Dataflow.OS): 1.0}
    assert apply_calibration(table, {"OS": 2.0})[
        (0, 0, (1, 1), Dataflow.OS)] == 2.0
    with pytest.raises(ValueError, match="positive"):
        apply_calibration(table, {"OS": 0.0})
    with pytest.raises(ValueError):
        apply_calibration(table, {"XX": 1.0})
    _, _, paths, _ = _unit_problem()
    # calibration composes with the architecture co-search (ROADMAP gap
    # (c), closed): uniform scale -> same winner, scaled cost — the
    # argmin-flipping case lives in tests/test_search_oracle.py
    plain = global_search([paths], FPGA_VU9P, hw_space=(FPGA_VU9P,))
    res_hw = global_search([paths], FPGA_VU9P, calibration={d.value: 2.0
                                                            for d in Dataflow},
                           hw_space=(FPGA_VU9P,))
    assert res_hw.total_latency_s == pytest.approx(2 * plain.total_latency_s)
    from repro.core import memoised_layer_backwards
    _, tn, _, _ = _unit_problem()
    with pytest.raises(ValueError, match="train"):
        global_search([paths], FPGA_VU9P, calibration={"OS": 2.0},
                      objective="train-latency",
                      layer_backwards=memoised_layer_backwards([tn], k=2))


def test_measured_calibration_scales_follow_measurements():
    # fake measurement is dataflow-independent, analytic costs differ per
    # dataflow -> scales must differ and be positive
    tuner = _stub_tuner()
    scales = measured_calibration([(128, 128, 256)], tuner, FPGA_VU9P)
    assert set(scales) == {"IS", "OS", "WS"}
    assert all(s > 0 for s in scales.values())
    assert len(set(scales.values())) > 1


# ---------------------------------------------------------------------------
# dse_cli --tune plumbing (stubbed measurements)
# ---------------------------------------------------------------------------

def test_run_dse_tune_cache_reports_and_replays(tmp_path, monkeypatch):
    import repro.tune.measure as tmeasure
    from repro.dse_cli import run_dse_plan

    monkeypatch.setattr(tmeasure, "measure_gemm", _fake_gemm)
    monkeypatch.setattr(tmeasure, "measure_streaming", _fake_streaming)
    cache = str(tmp_path / "cache.json")

    report, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                                tune="cache", tune_cache=cache)
    t = report["tune"]
    assert t["mode"] == "cache" and t["cache"] == cache
    assert set(t["calibration"]) == {"IS", "OS", "WS"}
    assert t["n_measured"] > 0
    assert plan.tilings == "measured"
    # per-layer latency provenance stays in analytic seconds: the
    # calibration scale is divided back out, so instances sum to the
    # plan's (analytic) total exactly as in untuned plans
    assert sum(lp.latency_s * lp.instances
               for lp in plan.layers) == pytest.approx(plan.total_latency_s)

    # second run: fully cache-served, bit-identical plan
    monkeypatch.setattr(tmeasure, "measure_gemm", _fail_gemm)
    monkeypatch.setattr(tmeasure, "measure_streaming", _fail_streaming)
    report2, plan2 = run_dse_plan("tt-lm-100m", smoke=True, top_k=2,
                                  tokens=32, tune="cache", tune_cache=cache)
    assert report2["tune"]["n_measured"] == 0
    assert plan2.dumps() == plan.dumps()


def test_run_dse_tune_rejects_unsupported_combos(tmp_path):
    from repro.dse_cli import run_dse

    # --mode train composes since the tiling lift (ROADMAP gap b); the
    # ambiguous --mode both combination is what's rejected now
    with pytest.raises(ValueError, match="ambiguous"):
        run_dse("tt-lm-100m", smoke=True, mode="both", tune="cache")
    with pytest.raises(ValueError, match="analytic-only"):
        run_dse("tt-lm-100m", smoke=True, objective="edp", tune="cache")


def test_run_dse_tune_train_mode_measured_tilings(tmp_path, monkeypatch):
    """ROADMAP gap (b) closed: train plans may carry measured tilings.
    The train *search* stays analytic (no calibration), but the emitted
    plan replays measured forward tilings and cache-served backward
    tilings."""
    import repro.tune.measure as tmeasure
    from repro.dse_cli import run_dse_plan

    monkeypatch.setattr(tmeasure, "measure_gemm", _fake_gemm)
    monkeypatch.setattr(tmeasure, "measure_streaming", _fake_streaming)
    cache = str(tmp_path / "cache.json")
    report, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2,
                                tokens=32, mode="train", tune="cache",
                                tune_cache=cache)
    t = report["tune"]
    assert t["mode"] == "cache"
    assert t["calibration"] is None          # train search is analytic
    assert t["n_calibration_shapes"] == 0
    assert "analytic" in t["note"]
    assert t["n_measured"] > 0
    assert plan.tilings == "measured"
    assert any(lp.backward for lp in plan.layers)  # it is a train plan


def test_run_dse_tune_composes_with_hw_search(tmp_path, monkeypatch):
    """ROADMAP gap (c) closed: --tune now composes with --hw-search —
    the calibrated tables rescale every candidate before its argmin."""
    import repro.tune.measure as tmeasure
    from repro.dse_cli import run_dse

    monkeypatch.setattr(tmeasure, "measure_gemm", _fake_gemm)
    monkeypatch.setattr(tmeasure, "measure_streaming", _fake_streaming)
    cache = str(tmp_path / "cache.json")
    report = run_dse("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                     hw_search="budget", tune="cache", tune_cache=cache)
    assert report["hw_search"]["n_candidates"] >= 64
    assert set(report["tune"]["calibration"]) == {"IS", "OS", "WS"}
    assert report["tune"]["correction"]["model"] == "shape-bucket-geomean"


def test_combine_phase_tables_calibrates_each_phase_at_own_shapes():
    """ROADMAP serving follow-on (a): the throughput objective's combined
    table applies the measured correction per phase *at that phase's own
    GEMM shapes* — decode GEMMs are skinnier, so the shape-aware model
    must resolve each phase's cells against its own candidate paths."""
    from types import SimpleNamespace

    from repro.core.dse import combine_phase_tables

    df = Dataflow.OS
    key = (0, 0, (1, 1), df)
    pre = {key: 10.0}
    dec = {key: 1.0}

    def path_with_gemm(M, K, N):
        g = SimpleNamespace(M=M, K=K, N=N, macs=M * K * N)
        return SimpleNamespace(gemms=(g,))

    prefill_paths = [[path_with_gemm(1024, 64, 64)]]
    decode_paths = [[path_with_gemm(8, 64, 64)]]

    class ShapeScale:
        def scale(self, M, K, N, dataflow):
            return 2.0 if M >= 1024 else 5.0

    out = combine_phase_tables(
        pre, dec, w_prefill=1.0, w_decode=3.0,
        calibration=ShapeScale(),
        prefill_paths=prefill_paths, decode_paths=decode_paths)
    # prefill cell scaled by 2 (big GEMM), decode cell by 5 (skinny)
    assert out[key] == pytest.approx(1.0 * 2.0 * 10.0 + 3.0 * 5.0 * 1.0)

    # flat per-dataflow calibration scales both phases uniformly
    flat = combine_phase_tables(pre, dec, w_decode=3.0,
                                calibration={df.value: 2.0})
    assert flat[key] == pytest.approx(2.0 * (10.0 + 3.0 * 1.0))
    # and no calibration leaves the weighted sum untouched
    plain = combine_phase_tables(pre, dec, w_decode=3.0)
    assert plain[key] == pytest.approx(13.0)


def test_run_dse_tune_throughput_calibrated(tmp_path, monkeypatch):
    """--tune now composes with --objective throughput: the measured
    correction rescales both phase tables before the decode-weighted
    combine (previously rejected as latency-only)."""
    import repro.tune.measure as tmeasure
    from repro.dse_cli import run_dse

    monkeypatch.setattr(tmeasure, "measure_gemm", _fake_gemm)
    monkeypatch.setattr(tmeasure, "measure_streaming", _fake_streaming)
    cache = str(tmp_path / "cache.json")

    report = run_dse("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                     objective="throughput", tune="cache", tune_cache=cache)
    assert report["objective"] == "throughput"
    assert set(report["tune"]["calibration"]) == {"IS", "OS", "WS"}
    assert report["tune"]["n_measured"] > 0
    assert report["serving"]["calibrated"] is True
    # the combined objective is in calibrated units; the analytic phase
    # split stays analytic seconds
    assert report["serving"]["total_prefill_s"] > 0
    assert report["serving"]["total_decode_step_s"] > 0

    untuned = run_dse("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                      objective="throughput")
    assert untuned["serving"]["calibrated"] is False
    w = untuned["serving"]["decode_weight"]
    assert untuned["total_objective"] == pytest.approx(
        untuned["serving"]["total_prefill_s"]
        + w * untuned["serving"]["total_decode_step_s"])


def test_run_tune_cli_pipeline_with_stub_tuner(tmp_path):
    from repro.tune.cli import run_tune

    cache_path = str(tmp_path / "cache.json")
    tuner = _stub_tuner(TuningCache(), cache_path=cache_path)
    report = run_tune("tt-lm-100m", smoke=True, top_k=2, tokens=32,
                      max_shapes=2, tuner=tuner)
    assert report["n_shapes"] == 2
    assert report["n_families"] == 2
    assert report["n_measured"] == tuner.n_measured > 0
    assert set(report["calibration"]) == {"IS", "OS", "WS"}
    for fam in report["families"]:
        if "speedup_vs_heuristic" in fam and fam["speedup_vs_heuristic"]:
            assert fam["speedup_vs_heuristic"] >= 1.0
    # the cache was persisted and reloads bit-stably
    assert TuningCache.load(cache_path).dumps() == tuner.cache.dumps()


# ---------------------------------------------------------------------------
# kernels: auto-padding + one real measurement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", ["IS", "OS", "WS"])
def test_tt_gemm_auto_pads_non_multiple_dims(dataflow, rng):
    from repro.kernels.tt_gemm import tt_gemm

    a = jnp.asarray(rng.standard_normal((48, 96)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((96, 160)).astype(np.float32))
    out = tt_gemm(a, b, dataflow=dataflow, block_m=32, block_k=64,
                  block_n=128, interpret=True)
    assert out.shape == (48, 160)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


def test_real_measurement_harness_smoke():
    # one genuine interpret-mode measurement through each harness path
    from repro.tune import measure_gemm, measure_streaming
    from repro.plan.compiler import rebatch

    s = measure_gemm(32, 32, 32, "OS", (32, 32, 32), interpret=True,
                     warmup=1, repeats=2)
    assert s > 0
    _, tn, paths, _ = _unit_problem(tokens=32)
    s2 = measure_streaming(rebatch(tn, 16), paths[0].steps, 32, 16,
                           interpret=True, warmup=1, repeats=2)
    assert s2 > 0
