"""Serving driver: request scheduler + phase-specialized execution plans.

  PYTHONPATH=src python -m repro.launch.serve --arch tt-lm-100m --smoke \
      --schedule continuous --batch 4 --n-requests 8 --prompt-len 32 --gen 16

A thin CLI over :mod:`repro.serve`: requests (synthetic sustained load,
or a ``--trace`` JSON) flow through the continuous-batching scheduler —
batch-1 prefills admitted into free decode lanes of a fixed-width decode
batch.  ``--schedule oneshot`` runs the same engine at concurrency 1
(the bit-exact per-request reference).

``--plan plan.json`` installs one DSE-compiled execution plan (emitted
by ``python -m repro.dse --emit-plan``, see docs/plan_format.md) for
both phases; ``--plan-prefill``/``--plan-decode`` install a
phase-specialized pair (``--emit-plan-pair``) so each stream contracts
under its own searched paths/backends/tilings.  ``--strict-plan`` makes
an entirely unplanned run (a plan was given but no projection executed
under it) a non-zero exit.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.configs import get_config
from repro.launch.mesh import make_rules, make_test_mesh
from repro.models import api
from repro.models.config import ShapeConfig
from repro.serve import (
    Scheduler,
    ServeEngine,
    ServePolicy,
    load_trace,
    summarize,
    synthetic_trace,
)
from repro.sharding import use_rules

EXIT_UNPLANNED = 3   # --strict-plan: plan given, zero planned executions
EXIT_DEMOTED = 4     # --strict-plan: planned Pallas layers fell back to jnp


def _load_and_describe(path: str, label: str):
    from repro.plan import load_plan

    plan = load_plan(path)
    print(f"installed {label}: arch={plan.arch} hw={plan.hw} "
          f"strategy={plan.strategy} ({len(plan.layers)} layer plans)"
          + (f" [phase {plan.phase}]" if plan.phase else ""))
    print(f"plan tilings: {plan.tilings}"
          + (" (autotuned — repro.tune)" if plan.tilings == "measured" else ""))
    if plan.hardware is not None:
        h = plan.hardware
        print(f"plan hardware: {h.name} ({h.pe_rows}x{h.pe_cols} PEs, "
              f"sram {h.sram_input_bytes // 1024}+"
              f"{h.sram_output_bytes // 1024} KiB, "
              f"bw {h.dram_words_per_cycle:g} words/cycle)")
    return plan


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tt-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--schedule", default="oneshot",
                    choices=("oneshot", "continuous"),
                    help="oneshot: each request decodes alone (default); "
                         "continuous: admit into free decode lanes each step")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slot width (fixed decode batch; default 4)")
    ap.add_argument("--n-requests", type=int, default=None,
                    help="synthetic-trace request count (default: --batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean inter-arrival gap in decode steps "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="request-trace JSON (repro.serve.load_trace) "
                         "instead of the synthetic trace")
    ap.add_argument("--prompt-bucket", type=int, default=8,
                    help="round prompt lengths up to a multiple (bounds "
                         "prefill trace count; default 8)")
    ap.add_argument("--max-admissions", type=int, default=None,
                    help="admission-policy cap per step (default: fill "
                         "every free lane)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="install one DSE execution plan for both phases "
                         "(repro.dse --emit-plan)")
    ap.add_argument("--plan-prefill", default=None, metavar="PATH",
                    help="prefill-phase plan of a pair "
                         "(repro.dse --emit-plan-pair)")
    ap.add_argument("--plan-decode", default=None, metavar="PATH",
                    help="decode-phase plan of a pair")
    ap.add_argument("--strict-plan", action="store_true",
                    help="exit non-zero if a plan was given but the run "
                         "executed no planned projection (entirely "
                         "UNPLANNED run), or if any layer planned for a "
                         "Pallas backend silently fell back to the jnp "
                         "executor (DEMOTED run)")
    args = ap.parse_args(argv)

    if args.plan and (args.plan_prefill or args.plan_decode):
        ap.error("--plan is mutually exclusive with "
                 "--plan-prefill/--plan-decode")

    cfg = get_config(args.arch, tt=not args.dense, smoke=args.smoke)
    if cfg.family == "encdec":
        print("error: the serve scheduler is causal-LM only; encdec runs "
              "its own scalar-position decoder", file=sys.stderr)
        return 2

    any_plan = bool(args.plan or args.plan_prefill or args.plan_decode)
    if args.plan:
        prefill_plan = decode_plan = _load_and_describe(args.plan, "plan")
    else:
        prefill_plan = (_load_and_describe(args.plan_prefill, "prefill plan")
                        if args.plan_prefill else None)
        decode_plan = (_load_and_describe(args.plan_decode, "decode plan")
                       if args.plan_decode else None)

    if args.trace:
        requests = load_trace(args.trace, cfg.vocab, seed=args.seed)
    else:
        n = args.n_requests if args.n_requests is not None else args.batch
        requests = synthetic_trace(
            n, cfg.vocab, prompt_len=args.prompt_len, gen=args.gen,
            arrival_rate=args.arrival_rate, seed=args.seed)

    bucket = args.prompt_bucket
    max_seq = max(
        max(-(-len(r.prompt) // bucket) * bucket,
            len(r.prompt) + r.max_new_tokens - 1)
        for r in requests) if requests else bucket

    shape = ShapeConfig("cli", max_seq, args.batch, "decode")
    mesh = make_test_mesh()
    rules = make_rules(cfg, shape, mesh)

    from repro.plan import (
        execution_log,
        execution_log_dropped,
        reset_execution_log,
    )

    reset_execution_log()
    t0 = time.perf_counter()
    with use_rules(rules):
        # install a plan before init: v4 plans embed searched
        # factorizations, which set the TT parameter shapes themselves
        init_plan = prefill_plan if prefill_plan is not None else decode_plan
        m = api(cfg, plan=init_plan) if init_plan is not None else api(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        try:
            engine = ServeEngine(
                cfg, params, n_slots=args.batch, max_seq=max_seq,
                prompt_bucket=bucket, prefill_plan=prefill_plan,
                decode_plan=decode_plan, arch=args.arch)
        except ValueError as e:
            print(f"error: plan/model mismatch: {e}", file=sys.stderr)
            return 2
        sched = Scheduler(
            engine,
            ServePolicy(schedule=args.schedule,
                        max_admissions_per_step=args.max_admissions),
            temperature=args.temperature, seed=args.seed)
        result = sched.run(requests)
    total_s = time.perf_counter() - t0

    s = summarize(result)
    print(f"schedule {args.schedule}: {s['n_requests']} requests over "
          f"{s['steps']} steps, {result.n_slots} decode slots, "
          f"occupancy {s['mean_occupancy']:.2f}")
    print(f"throughput: {s['gen_tok_s']:.1f} gen tok/s, "
          f"{s['total_tok_s']:.1f} total tok/s "
          f"({s['generated_tokens']} generated / {s['total_tokens']} total "
          f"tokens, serve {s['wall_s']*1e3:.1f} ms, "
          f"end-to-end {total_s*1e3:.1f} ms)")
    print(f"latency: ttft p50/p95 {s['ttft_p50_ms']:.1f}/"
          f"{s['ttft_p95_ms']:.1f} ms, request p50/p95 "
          f"{s['latency_p50_ms']:.1f}/{s['latency_p95_ms']:.1f} ms")
    if result.completions:
        c0 = result.completions[0]
        print(f"generated token ids (rid {c0.rid}): "
              f"{list(c0.tokens)[:16]}")

    if any_plan:
        log = execution_log()
        by_stream: dict[str, dict[str, int]] = {}
        for r in log:
            st = by_stream.setdefault(r["stream"] or "?", {})
            st[r["backend"]] = st.get(r["backend"], 0) + 1
        n_pre = sum(by_stream.get("prefill", {}).values())
        n_dec = sum(by_stream.get("decode", {}).values())
        print(f"planned executions (trace-time): {len(log)} — "
              f"prefill stream: {n_pre}, decode stream: {n_dec}")
        for stream in ("prefill", "decode"):
            if stream in by_stream:
                print(f"  {stream} backends "
                      f"{dict(sorted(by_stream[stream].items()))}")
        tilings = sorted({
            (r["tiling"]["block_m"], r["tiling"]["block_k"],
             r["tiling"]["block_n"], r["tiling"]["block_tokens"])
            for r in log})
        if tilings:
            print("kernel tilings (block_m,k,n,tokens): "
                  + " ".join(str(t) for t in tilings))
        seg_recs = [r for r in log if r.get("segment")]
        if seg_recs:
            fused = [r for r in seg_recs
                     if r["segment"][1] - r["segment"][0] >= 2]
            n_steps = sum(r["segment"][1] - r["segment"][0] for r in fused)
            print(f"fused segments (trace-time): {len(seg_recs)} segment "
                  f"records, {len(fused)} fused chain runs ({n_steps} path "
                  "steps with VMEM-resident intermediates)")
        dropped = execution_log_dropped()
        if dropped:
            print(f"NOTE: the execution log ring dropped {dropped} oldest "
                  f"records (cap {len(log)}); trace-time counts above are "
                  "lower bounds")
        meshes = sorted({r.get("mesh", "") for r in log} - {""})
        if meshes:
            shapes = sorted({tuple(r["shard_shape"]) for r in log
                             if r.get("shard_shape")})
            print(f"sharded execution: mesh {' '.join(meshes)}, "
                  f"per-shard (tokens, d_in) "
                  + " ".join(str(s) for s in shapes))
        # a layer planned for a Pallas backend that recorded backend
        # "jnp" was demoted by the dispatcher (e.g. a mesh the problem
        # could not shard over) — surface it; --strict-plan makes it fatal
        backends_by_stream = {
            s: ({lp.name: lp.backend for lp in p.layers}
                if p is not None else {})
            for s, p in (("prefill", prefill_plan), ("decode", decode_plan))
        }
        demoted = [
            r for r in log
            if r["backend"] == "jnp"
            and backends_by_stream.get(
                r["stream"], {}).get(r["name"], "jnp") != "jnp"
        ]
        if demoted:
            names = sorted({r["name"] for r in demoted})
            print(f"WARNING: {len(demoted)} planned-Pallas executions were "
                  f"DEMOTED to the jnp executor ({len(names)} layers: "
                  f"{names[:4]}{'...' if len(names) > 4 else ''})",
                  file=sys.stderr)
            if args.strict_plan:
                return EXIT_DEMOTED
        if not log:
            print(
                "WARNING: a plan was given but the run executed no planned "
                "projection — the run was entirely UNPLANNED (layer names "
                "did not line up; was the plan emitted for a different arch "
                "or tt/--dense setting?)",
                file=sys.stderr,
            )
            if args.strict_plan:
                return EXIT_UNPLANNED
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
