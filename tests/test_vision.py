"""The paper's own benchmark models (ResNet-18-TT, ViT-Ti/4) as
contraction workloads + a mini end-to-end DSE over them."""

import pytest

from repro.core import FPGA_VU9P, explore_model, find_topk_paths
from repro.models.vision import model_layers, resnet18_layers, vit_ti4_layers


def test_resnet18_layer_inventory():
    layers = resnet18_layers("cifar10")
    # stem + 4 stages x 2 blocks x 2 convs + fc = 18
    assert len(layers) == 18
    for l in layers:
        assert l.dense_macs > 0
        out = l.tt_network.output_dims()
        assert out  # has free edges


def test_resnet18_tiny_imagenet_larger():
    c = sum(l.dense_macs for l in resnet18_layers("cifar10"))
    t = sum(l.dense_macs for l in resnet18_layers("tiny_imagenet"))
    assert t > c  # 64x64 input -> more patches


def test_vit_layer_inventory():
    layers = vit_ti4_layers()
    assert len(layers) == 12 * 4 + 1


def test_tt_paths_cheaper_than_dense_reconstruction():
    """TT contraction along the searched path must beat reconstructing W
    for compressible conv layers (the compression premise, Table 3)."""
    wins = 0
    for l in resnet18_layers("cifar10")[4:10]:
        best = find_topk_paths(l.tt_network, k=1)[0]
        if best.macs < l.dense_macs:
            wins += 1
    assert wins >= 4


def test_mini_dse_over_vit_layers():
    nets = [l.tt_network for l in vit_ti4_layers(batch=1)[:4]]
    res = explore_model(nets, FPGA_VU9P, top_k=2)
    assert res.total_latency_s > 0
    assert len(res.choices) == 4


def test_model_layers_dispatch():
    assert model_layers("resnet18", "cifar10")
    assert model_layers("vit_ti4", "cifar10")
    with pytest.raises(ValueError):
        model_layers("alexnet", "cifar10")
