"""Logical-axis sharding rules (DP / TP / EP / SP over the production mesh).

Models annotate activations with *logical* axes ("batch", "seq", "model",
None); the launcher installs :class:`ShardingRules` mapping those to mesh
axes.  With no rules installed (unit tests, single host), annotations are
no-ops, so model code is mesh-agnostic.

Resolution rules:
  * "batch"  -> the data-parallel axes (('pod','data') multi-pod, ('data',))
  * "model"  -> the tensor/expert-parallel axis
  * "seq"    -> sequence-parallel axis (== model axis when SP is enabled)
  * a dim is only sharded if its size divides the mesh-axes product —
    otherwise it silently replicates (e.g. 2 KV heads on a 16-way TP axis).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    axis_sizes: dict          # mesh axis name -> size
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    seq_axis: Optional[str] = None        # set to model axis for SP
    mesh: Optional[jax.sharding.Mesh] = None
    #: opt-in row-parallel TT execution: split the leading input mode (and
    #: its core) over the model axis and psum the partial outputs inside
    #: the shard_map body (see repro.plan.sharded).  Changes float
    #: summation order — outputs are equivalent, not bit-identical.
    tt_model_reduce: bool = False

    def resolve(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        if logical == "batch":
            return self.batch_axes
        if logical == "model":
            return (self.model_axis,) if self.model_axis else ()
        if logical == "seq":
            return (self.seq_axis,) if self.seq_axis else ()
        if logical == "tokens":
            # a flattened (batch x seq) dim: DP axes, plus the SP axis when
            # sequence parallelism is on (b-major merge matches the layout)
            seq = (self.seq_axis,) if self.seq_axis else ()
            return self.batch_axes + tuple(a for a in seq if a not in self.batch_axes)
        raise ValueError(f"unknown logical axis {logical!r}")

    def token_shard_axes(self, tokens: int) -> tuple[str, ...]:
        """Mesh axes a flattened ``tokens`` dim can shard over, or ``()``.

        The resolved "tokens" axes, kept only when every axis has size > 1
        and ``tokens`` divides their product (shard_map needs exact
        per-shard blocks; the GSPMD constraint path merely replicates on
        mismatch).
        """
        axes = tuple(a for a in self.resolve("tokens")
                     if self.axis_sizes.get(a, 1) > 1)
        prod = math.prod(self.axis_sizes[a] for a in axes)
        if not axes or prod <= 1 or tokens % prod != 0:
            return ()
        return axes

    def n_token_shards(self, tokens: int) -> int:
        return math.prod(
            self.axis_sizes[a] for a in self.token_shard_axes(tokens)) or 1

    def partition_spec(self, shape: Sequence[int], logical_axes: Sequence) -> P:
        used: set[str] = set()
        spec = []
        for dim, logical in zip(shape, logical_axes):
            axes = self.resolve(logical)
            axes = tuple(a for a in axes if a not in used)
            prod = math.prod(self.axis_sizes.get(a, 1) for a in axes)
            if axes and prod > 1 and dim % prod == 0:
                # "tokens" is semantically a *merged* (batch x seq) dim, so
                # its spec entry stays a tuple even with one mesh axis
                spec.append(axes if (len(axes) > 1 or logical == "tokens")
                            else axes[0])
                used.update(axes)
            else:
                spec.append(None)
        return P(*spec)


_tls = threading.local()


def set_rules(rules: Optional[ShardingRules]) -> None:
    _tls.rules = rules


def get_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """Annotate ``x`` with a sharding constraint from logical axis names.

    One logical name per dim: "batch" | "seq" | "model" | None.  No-op when
    no rules are installed.
    """
    rules = get_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    spec = rules.partition_spec(x.shape, logical_axes)
    if rules.mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(rules: ShardingRules, shape: Sequence[int], logical_axes) -> NamedSharding:
    assert rules.mesh is not None
    return NamedSharding(rules.mesh, rules.partition_spec(shape, logical_axes))
