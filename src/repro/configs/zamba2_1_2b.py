"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

Assigned dims: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000
ssm_state=64  [arXiv:2411.15242; hf].  Shared attention applied every 6
Mamba layers (one parameter set reused — Zamba2's signature trick).
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="zamba2-smoke",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    head_dim=16,
    ssm_state=8,
    ssm_head_dim=16,
    attn_every=2,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
