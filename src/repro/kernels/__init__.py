"""Pallas TPU kernels for the DSE's compute hot-spots.

``tt_gemm``      — dataflow-configurable tiled GEMM (IS/OS/WS as grid order).
``streaming_tt`` — fused TT contraction, cores VMEM-pinned, tokens streamed.
``ops``          — jit'd wrappers (interpret=True on CPU, Mosaic on TPU).
``ref``          — pure-jnp oracles.
"""

from . import ops, ref
from .tt_gemm import tt_gemm
from .streaming_tt import streaming_tt_linear, build_block_network

__all__ = ["ops", "ref", "tt_gemm", "streaming_tt_linear", "build_block_network"]
