"""Rotary position embeddings: standard, partial (GLM-style 2d), or none.

``glm2d`` follows ChatGLM's scheme: rotary applied to the first half of
each head dimension only (two interleaved rotary groups), the remainder
passes through — captured here as a partial-rotary factor of 0.5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, base: float = 10_000.0) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension (even count)."""
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,             # (..., seq, heads, head_dim)
    positions: jax.Array,     # (..., seq)
    *,
    base: float = 10_000.0,
    rotary_fraction: float = 1.0,
) -> jax.Array:
    """Rotate the first ``rotary_fraction`` of head_dim; pass the rest."""
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * rotary_fraction)
    rot_dim -= rot_dim % 2
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    inv_freq = rope_frequencies(rot_dim, base)          # (rot_dim/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, rd/2)
    angles = angles[..., None, :]                       # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


def rope_for(kind: str):
    """kind in {"full", "glm2d", "none"} -> (fraction, base) or None."""
    if kind == "none":
        return None
    if kind == "glm2d":
        return (0.5, 10_000.0)
    if kind == "full":
        return (1.0, 10_000.0)
    raise ValueError(f"unknown rope kind {kind!r}")
