"""``python -m repro.tune`` — warm the measured-latency tuning cache.

Runs the full measure -> calibrate -> re-search loop for one arch:

    PYTHONPATH=src python -m repro.tune --arch tt-lm-100m --smoke \
        --cache results/tuning_cache.json

1. enumerate the model's DSE problems and candidate paths (the same
   pipeline as ``python -m repro.dse``);
2. measure the unique dominant GEMM shapes under every dataflow at the
   heuristic tiling — the per-dataflow calibration signal;
3. re-run the global argmin with the measured calibration applied, so
   the families tuned next are the ones a calibrated ``--tune cache``
   search will actually deploy;
4. sweep kernel-tiling variants per deployed family (GEMM blocks for
   ``tt_gemm`` layers, ``block_tokens`` for streaming layers) and
   persist every measurement to the cache.

A subsequent ``python -m repro.dse --tune cache --emit-plan`` replays
the warmed cache without re-measuring; ``--max-shapes`` bounds the work
for smoke/CI runs (unmeasured problems are then measured on first miss
by the consuming search).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

from repro.dse_cli import dse_problems, model_layer_paths
from repro.hw import get_target

from .autotune import (
    TUNE_MODES,
    Autotuner,
    gemm_work_items,
    measured_calibration,
)
from .cache import (
    DEFAULT_CACHE_PATH,
    TuningCache,
    kernel_fingerprint,
    merge_caches,
    variant_key,
)


def run_tune(
    arch: str,
    hw: str = "fpga_vu9p",
    top_k: int = 4,
    tokens: Optional[int] = None,
    smoke: bool = False,
    cache_path: str = DEFAULT_CACHE_PATH,
    mode: str = "cache",
    max_shapes: Optional[int] = None,
    warmup: Optional[int] = None,
    repeats: Optional[int] = None,
    tuner: Optional[Autotuner] = None,
    shards: int = 1,
) -> dict:
    """Measure, calibrate, re-search, sweep; returns the JSON report.

    ``tuner`` may inject a pre-built :class:`Autotuner` (tests stub the
    measurement functions through it); by default one is built over the
    persistent cache at ``cache_path``.
    """
    from repro.core.dse import global_search
    from repro.plan.compiler import (
        base_name,
        batch_dim,
        choose_backend,
        choose_tiling,
    )

    if mode not in TUNE_MODES:
        raise ValueError(f"unknown tune mode {mode!r}; have {TUNE_MODES}")
    hw_cfg = get_target(hw)
    named, tokens = dse_problems(arch, tokens, smoke)
    if shards > 1:
        # warm the cache for a sharded search: measure the per-shard
        # problems a `repro.dse --shards N` run will look up
        from repro.core.cost_table import shard_streamed_tokens

        tokens = shard_streamed_tokens(tokens, shards)
        named, tokens = dse_problems(arch, tokens, smoke)
    layer_paths = model_layer_paths(named, top_k)
    if tuner is None:
        kw = {}
        if warmup is not None:
            kw["warmup"] = warmup
        if repeats is not None:
            kw["repeats"] = repeats
        tuner = Autotuner(TuningCache.load_or_empty(cache_path), mode,
                          cache_path=cache_path, shards=shards, **kw)

    t0 = time.perf_counter()
    shapes = gemm_work_items(layer_paths, max_shapes=max_shapes)
    calibration = measured_calibration(shapes, tuner, hw_cfg)
    res = global_search(layer_paths, hw_cfg, calibration=calibration)

    families = []
    seen: set[str] = set()
    for (inst_name, tn), choice in zip(named, res.choices):
        name = base_name(inst_name)
        if name in seen:
            continue
        seen.add(name)
        if max_shapes is not None and len(families) >= max_shapes:
            break
        t = tokens or batch_dim(tn)
        tiling = choose_tiling(choice, t, None)
        backend = choose_backend(tn, choice, tiling, None)
        row = {
            "name": name,
            "backend": backend,
            "dataflow": choice.dataflow.value,
            "heuristic": tiling.to_json(),
        }
        if backend == "tt_gemm":
            g = max(choice.path.gemms, key=lambda g: g.macs)
            best = tuner.tune_gemm(
                g.M, g.K, g.N, choice.dataflow.value,
                include=[(tiling.block_m, tiling.block_k, tiling.block_n)])
            entry = tuner.cache.get(
                tuner.gemm_key(g.M, g.K, g.N, choice.dataflow.value))
            row["measured"] = {"block_m": best[0], "block_k": best[1],
                               "block_n": best[2]}
            row["speedup_vs_heuristic"] = _speedup(
                entry, (tiling.block_m, tiling.block_k, tiling.block_n))
        elif backend == "streaming_tt":
            bt = tuner.tune_streaming(tn, choice.path.steps, t,
                                      include=[tiling.block_tokens])
            if bt is not None:
                entry = tuner.cache.get(
                    tuner.streaming_key(tn, choice.path.steps, t))
                row["measured"] = {"block_tokens": bt}
                row["speedup_vs_heuristic"] = _speedup(
                    entry, (tiling.block_tokens,))
        families.append(row)

    if tuner.cache_path is not None:
        tuner.save()
    return {
        "arch": arch,
        "hw": hw,
        "mode": mode,
        "cache": tuner.cache_path,
        "device_kind": tuner.device_kind,
        "interpret": tuner.interpret,
        "tokens": tokens,
        "shards": tuner.shards,
        "top_k": top_k,
        "n_shapes": len(shapes),
        "n_families": len(families),
        "n_measured": tuner.n_measured,
        "n_cache_hits": tuner.n_cache_hits,
        "n_cache_entries": len(tuner.cache),
        "tune_seconds": time.perf_counter() - t0,
        "calibration": calibration,
        "families": families,
    }


def _speedup(entry, heuristic_variant: tuple[int, ...]) -> Optional[float]:
    """best-vs-heuristic measured ratio for one cache entry (>= 1.0)."""
    if entry is None:
        return None
    h = entry.measured_s.get(variant_key(heuristic_variant))
    b = entry.best_seconds
    if h is None or b is None or b <= 0:
        return None
    return h / b


def _build_merge_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.tune merge",
        description="Union tuning caches from several hosts into one "
                    "(last-writer-wins on identical keys; entries measured "
                    "through edited kernel sources are dropped).",
    )
    p.add_argument("caches", nargs="+", metavar="CACHE.json",
                   help="cache files to merge, oldest first (later files "
                        "win on colliding measurements)")
    p.add_argument("-o", "--out", required=True, metavar="PATH",
                   help="merged cache destination")
    p.add_argument("--fingerprint", default=None, metavar="HASH",
                   help="accept entries with this kernel-source hash "
                        "(default: the current working tree's)")
    p.add_argument("--shards", type=int, default=None, metavar="N",
                   help="accept only entries measured for an N-way mesh "
                        "(per-shard problem shapes differ per mesh width; "
                        "default: keep every width)")
    return p


def run_merge(argv: Sequence[str]) -> int:
    args = _build_merge_parser().parse_args(argv)
    try:
        caches = [TuningCache.load(p) for p in args.caches]
    except (OSError, ValueError) as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    fp = args.fingerprint or kernel_fingerprint()
    merged, dropped, dropped_shards = merge_caches(
        caches, fingerprint=fp, shards=args.shards)
    merged.save(args.out)
    total_in = sum(len(c) for c in caches)
    shard_note = (f", {dropped_shards} dropped (shard-shape mismatch vs "
                  f"s{args.shards})" if args.shards is not None else "")
    print(f"merged {len(args.caches)} caches ({total_in} entries) -> "
          f"{args.out}: {len(merged)} entries kept, {dropped} dropped "
          f"(fingerprint mismatch vs k{fp}){shard_note}", file=sys.stderr)
    return 0


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Empirical kernel autotuner: measure Pallas variant "
                    "latencies, warm the persistent tuning cache, and "
                    "report the measured calibration table.",
    )
    p.add_argument("--arch", required=True,
                   help="named config (see repro.dse --list-archs)")
    p.add_argument("--hw", default="fpga_vu9p",
                   help="cost-model target the calibration compares against")
    p.add_argument("--top-k", type=int, default=4, metavar="K")
    p.add_argument("--tokens", type=int, default=None)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--cache", default=DEFAULT_CACHE_PATH, metavar="PATH",
                   help=f"tuning-cache file (default {DEFAULT_CACHE_PATH})")
    p.add_argument("--mode", default="cache", choices=TUNE_MODES,
                   help="cache: measure only cache misses (default); "
                        "measure: re-measure and overwrite")
    p.add_argument("--shards", type=int, default=1, metavar="N",
                   help="measure per-shard problems for an N-way mesh "
                        "(matches repro.dse --shards N lookups; default 1)")
    p.add_argument("--max-shapes", type=int, default=None, metavar="N",
                   help="bound the calibration shapes and tuned families "
                        "(smoke/CI runs)")
    p.add_argument("--repeats", type=int, default=None, metavar="R",
                   help="timed repetitions per variant (median kept)")
    p.add_argument("--warmup", type=int, default=None, metavar="W",
                   help="untimed warmup calls per variant (absorbs jit "
                        "compilation; raise on noisy hosts)")
    p.add_argument("--out", default="-", metavar="PATH",
                   help="report destination ('-' = stdout, default)")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "merge":
        return run_merge(list(argv[1:]))
    args = _build_parser().parse_args(argv)
    try:
        report = run_tune(
            arch=args.arch,
            hw=args.hw,
            top_k=args.top_k,
            tokens=args.tokens,
            smoke=args.smoke,
            cache_path=args.cache,
            mode=args.mode,
            max_shapes=args.max_shapes,
            warmup=args.warmup,
            repeats=args.repeats,
            shards=args.shards,
        )
    except (KeyError, ValueError) as e:
        msg = e.args[0] if e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return 2
    text = json.dumps(report, indent=2)
    if args.out == "-":
        print(text)
    else:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    print(f"tuned {report['n_families']} families / "
          f"{report['n_shapes']} shapes: {report['n_measured']} measured, "
          f"{report['n_cache_hits']} cache hits -> {args.cache}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
