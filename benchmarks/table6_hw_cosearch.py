"""Table 6 — fixed ``fpga_vu9p`` vs co-searched architecture.

For the paper's vision workloads plus the tt-lm config, and for both the
inference and training objectives, run the joint (architecture, path,
dataflow) co-search over the feasible VU9P-budget space
(``repro.hw.ArchSpace``) and report the latency delta plus the chosen
(R x C, SRAM split, bandwidth) per arch.  The co-searched optimum can
never be worse than the fixed target (the base architecture is in the
space); the interesting question is *how much* re-shaping the same
silicon buys per workload — the FETTA/HEAT observation.

  PYTHONPATH=src python -m benchmarks.run --only table6
"""

from __future__ import annotations

from repro.dse_cli import VISION_ARCHS, run_dse

from .common import emit

ARCHS = list(VISION_ARCHS) + ["tt-lm-100m"]


def run() -> list[dict]:
    rows = []
    for arch in ARCHS:
        for mode in ("infer", "train"):
            report = run_dse(arch, top_k=4, mode=mode, hw_search="budget")
            hs = report["hw_search"]
            chosen, fixed = hs["chosen"], hs["fixed"]
            rows.append({
                "arch": arch,
                "mode": mode,
                "objective": report["objective"],
                "n_candidates": hs["n_candidates"],
                "fixed_latency_ms": fixed["total_latency_s"] * 1e3,
                "cosearch_latency_ms": chosen["total_latency_s"] * 1e3,
                "improvement_pct": hs["improvement_pct"],
                "chosen_pe": f"{chosen['pe_rows']}x{chosen['pe_cols']}",
                "chosen_sram_kib": (f"{chosen['sram_input_kib']}+"
                                    f"{chosen['sram_output_kib']}"),
                "chosen_bw_words": chosen["dram_words_per_cycle"],
                "chosen_strategy": chosen["strategy"],
            })
    emit("table6_hw_cosearch", rows)
    return rows


if __name__ == "__main__":
    run()
