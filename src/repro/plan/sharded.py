"""shard_map-routed planned execution on multi-device meshes.

PR 2's executor restricted Pallas plan backends to single-device meshes:
the kernels flatten ``(B, S)`` to ``tokens`` and carry no sharding
annotations, so under GSPMD a >1-device mesh would force a relayout and
the dispatcher demoted every planned contraction to the jnp executor.
This module lifts that gate by making the sharding *explicit* instead:

* the flattened token dim is split over the installed
  :class:`~repro.sharding.ShardingRules` token axes (DP, plus the SP
  axis when sequence parallelism is on) via ``jax.shard_map``;
* each shard runs the *same* ``streaming_tt`` / ``tt_gemm`` kernel at
  its per-shard ``(tokens/n_shards, d_in)`` shape — which is also the
  shape the DSE/tuner searched when a shard context was active
  (``repro.dse --shards``);
* TT cores are tiny by construction and replicate (``in_specs=P()``);
  their gradient cotangents are psummed across shards by the shard_map
  transpose, so training stays correct (``check_rep=False`` because the
  Pallas ``custom_vjp`` body defeats replication checking);
* an optional model-axis output reduction
  (``ShardingRules.tt_model_reduce``) splits the leading input mode and
  its TT core over the model axis and reduces partial outputs with an
  explicit ``jax.lax.psum`` *inside* the body — classic row-parallel TP
  with no forced relayout.  This changes float summation order, so
  outputs are numerically equivalent (~1e-6 rtol for f32), not
  bit-identical; pure token-DP sharding *is* bit-identical to the
  single-device planned path because TT contractions are row-independent
  and per-shard K-blocking is unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

from .executor import planned_tt_linear, shard_execution
from .schema import LayerPlan

try:  # jax >= 0.5 promotes shard_map to the top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def _smap(f, mesh, in_specs, out_specs):
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # newer jax renamed the replication-check knob
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


@dataclasses.dataclass(frozen=True)
class ShardDecision:
    """How one planned projection maps onto the installed mesh."""

    axes: tuple[str, ...]     # mesh axes sharding the flattened token dim
    n_shards: int             # product of those axis sizes (1 = replicated)
    model_reduce: bool = False  # split leading input mode over model axis
    tp: int = 1               # model-axis size when model_reduce

    def describe(self, axis_sizes: dict, model_axis: Optional[str]) -> str:
        parts = ",".join(f"{a}={axis_sizes[a]}" for a in self.axes)
        if self.model_reduce:
            red = f"reduce({model_axis}={self.tp})"
            return f"{parts}+{red}" if parts else red
        return parts


def shard_decision(rules, tokens: int,
                   in_modes: Sequence[int]) -> Optional[ShardDecision]:
    """Route choice for a planned projection under ``rules``, or ``None``.

    ``None`` means the mesh cannot take this problem (no mesh object, or
    the token count does not divide the DP axes and no model reduction
    applies) — the caller falls back to the constrained jnp executor.
    """
    if rules is None or rules.mesh is None:
        return None
    axes = rules.token_shard_axes(tokens)
    model_reduce, tp = False, 1
    ma = rules.model_axis
    if rules.tt_model_reduce and ma and ma not in axes:
        tp = int(rules.axis_sizes.get(ma, 1))
        if tp > 1 and in_modes and in_modes[0] % tp == 0:
            model_reduce = True
        else:
            tp = 1
    if not axes and not model_reduce:
        return None
    n = math.prod(rules.axis_sizes[a] for a in axes) if axes else 1
    return ShardDecision(tuple(axes), int(n), model_reduce, tp)


def sharded_tt_linear(
    lp: LayerPlan,
    x2d: jax.Array,
    cores: Sequence[jax.Array],
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    *,
    rules,
    decision: ShardDecision,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Planned TT projection over the mesh: per-shard Pallas execution.

    ``x2d: (tokens, d_in)`` -> ``(tokens, d_out)``.  Token shards stream
    through the plan's backend at per-shard shapes; cores replicate
    (except the leading input core under ``model_reduce``, which is
    split over the model axis with a psum inside the body).
    """
    in_modes = tuple(in_modes)
    out_modes = tuple(out_modes)
    ranks = tuple(ranks)
    tokens, d_in = int(x2d.shape[0]), int(x2d.shape[1])
    n_cores = len(cores)
    tok_entry = decision.axes if decision.axes else None
    shard_tokens = tokens // decision.n_shards

    if not decision.model_reduce:
        def body(xs, *cs):
            return planned_tt_linear(lp, xs, list(cs), in_modes, out_modes,
                                     ranks, interpret=interpret)

        in_specs = (P(tok_entry, None),) + (P(),) * n_cores
        out_specs = P(tok_entry, None)
        shard_shape = (shard_tokens, d_in)
    else:
        ma = rules.model_axis
        tp = decision.tp
        local_in = (in_modes[0] // tp,) + in_modes[1:]
        # cores are ordered out_modes then in_modes, so the core carrying
        # the leading input mode j1 sits at index len(out_modes); its mode
        # dim is axis 1 both for interior (r, m, r) and final (r, m) cores
        j1 = len(out_modes)
        core_specs = []
        for k in range(n_cores):
            if k == j1:
                core_specs.append(
                    P(None, ma) if k == n_cores - 1 else P(None, ma, None))
            else:
                core_specs.append(P())

        def body(xs, *cs):
            # x columns are row-major over in_modes, so a contiguous
            # 1/tp column block IS a j1-mode slice — no relayout
            y = planned_tt_linear(lp, xs, list(cs), local_in, out_modes,
                                  ranks, interpret=interpret)
            return jax.lax.psum(y, ma)

        in_specs = (P(tok_entry, ma),) + tuple(core_specs)
        out_specs = P(tok_entry, None)
        shard_shape = (shard_tokens, d_in // tp)

    desc = decision.describe(rules.axis_sizes, rules.model_axis)
    fn = _smap(body, rules.mesh, in_specs, out_specs)
    with shard_execution(desc, shard_shape):
        return fn(x2d, *cores)
