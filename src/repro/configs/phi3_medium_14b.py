"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

Assigned dims: 40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified].
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100_352,
    head_dim=128,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="phi3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    head_dim=16,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
