"""Normalization layers (functional, param-dict style)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
