"""Optimizer substrate (pure JAX — no optax dependency)."""

from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .schedules import constant_schedule, cosine_schedule, linear_warmup_cosine
from .grad_compress import (
    CompressState,
    compress_init,
    compress_decompress,
    int8_quantize,
    int8_dequantize,
)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "constant_schedule", "cosine_schedule", "linear_warmup_cosine",
    "CompressState", "compress_init", "compress_decompress",
    "int8_quantize", "int8_dequantize",
]
