"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

Assigned dims: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
[arXiv:2404.05892; hf].  head_dim=64 (RWKV standard), so 64 WKV heads.
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # d_model / head_dim WKV heads
    n_kv_heads=64,
    d_ff=14336,
    vocab=65_536,
    head_dim=64,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="rwkv6-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=224,
    vocab=256,
    head_dim=16,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
