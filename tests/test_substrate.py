"""Optimizer / data / checkpoint / runtime substrate tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import make_pipeline
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_decompress,
    compress_init,
    cosine_schedule,
    linear_warmup_cosine,
)
from repro.runtime import FaultTolerantLoop, StragglerMonitor


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    p = {"w": jnp.asarray([3.0, -2.0])}
    st_ = adamw_init(p)
    for _ in range(300):
        g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
        p, st_ = adamw_update(g, st_, p, 0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.asarray([5.0])}
    st_ = adamw_init(p)
    zero_g = {"w": jnp.asarray([0.0])}
    for _ in range(50):
        p, st_ = adamw_update(zero_g, st_, p, 0.1, weight_decay=0.5)
    assert float(p["w"][0]) < 5.0


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert abs(cn - 1.0) < 1e-5


def test_schedules():
    lr = linear_warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(5))) < 1.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    end = float(lr(jnp.asarray(100)))
    assert end < 0.2
    c = cosine_schedule(2.0, 10)
    assert abs(float(c(jnp.asarray(0))) - 2.0) < 1e-6


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_unbiased(seed):
    """Over many steps the int8+EF pipeline transmits the true mean."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(16,)) * rng.uniform(0.1, 10),
                          jnp.float32)}
    state = compress_init(g)
    acc = jnp.zeros(16)
    n = 64
    for _ in range(n):
        dq, state = compress_decompress(g, state)
        acc = acc + dq["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    pipe = make_pipeline(vocab=97, seq_len=12, global_batch=4, seed=5)
    a = pipe.batch(7)
    b = pipe.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_pipeline_shards_partition_global_batch():
    pipe = make_pipeline(vocab=50, seq_len=8, global_batch=8, seed=1)
    full = pipe.batch(3, 0, 1)
    parts = [pipe.batch(3, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


def test_pipeline_learnable_structure():
    """The bigram skeleton makes successor prediction beat chance."""
    pipe = make_pipeline(vocab=100, seq_len=64, global_batch=8, seed=2)
    b = pipe.batch(0)
    hits = 0
    total = 0
    succ = pipe._succ
    for row in b["tokens"]:
        for t in range(1, len(row)):
            total += 1
            hits += int(row[t] == succ[row[t - 1]])
    assert hits / total > 0.3  # ~50% by construction, >>1% chance


def test_pipeline_divisibility_error():
    pipe = make_pipeline(vocab=10, seq_len=4, global_batch=6)
    with pytest.raises(ValueError):
        pipe.batch(0, 0, 4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
        "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "d": jnp.zeros((2, 2), jnp.float32)},
    }


def test_checkpoint_roundtrip_bf16():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = _tree()
        mgr.save(3, tree)
        step, restored = mgr.restore(tree)
        assert step == 3
        assert restored["b"]["c"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["a"], np.int32),
                                      np.asarray(tree["a"], np.int32))


def test_checkpoint_retention_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 5, 9, 12):
            mgr.save(s, _tree())
        assert mgr.all_steps() == [9, 12]
        assert mgr.latest_step() == 12


def test_checkpoint_async_save():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save_async(1, _tree())
        mgr.wait()
        assert mgr.latest_step() == 1


def test_checkpoint_structure_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())
        with pytest.raises(ValueError):
            mgr.restore({"only": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# runtime fault tolerance
# ---------------------------------------------------------------------------

def test_fault_tolerant_loop_recovers_from_failure():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        fail_at = {"step": 7, "armed": True}

        def step_fn(state, step):
            if step == fail_at["step"] and fail_at["armed"]:
                fail_at["armed"] = False
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + 1}

        loop = FaultTolerantLoop(step_fn, mgr, checkpoint_every=5)
        state, done = loop.run({"x": jnp.asarray(0)}, 0, 10)
        assert done == 10
        assert loop.recoveries == 1
        assert int(state["x"]) == 10  # deterministic recovery, no lost steps


def test_fault_tolerant_loop_poison_step_aborts():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)

        def step_fn(state, step):
            if step == 3:
                raise RuntimeError("always fails")
            return state

        loop = FaultTolerantLoop(step_fn, mgr, checkpoint_every=2,
                                 max_retries_per_step=2)
        with pytest.raises(RuntimeError):
            loop.run({"x": jnp.asarray(0)}, 0, 10)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, window=8)
    for i in range(8):
        assert not mon.record(i, 1.0)
    assert mon.record(8, 5.0)
    assert mon.flagged == [8]
