"""Runtime: fault tolerance, stragglers, elastic scaling."""

from .fault import FaultTolerantLoop, PreemptionGuard, StragglerMonitor

__all__ = ["FaultTolerantLoop", "PreemptionGuard", "StragglerMonitor"]
