"""Jitted step builders: train / prefill / decode.

Each builder closes over the ModelConfig and returns a pure function
suitable for ``jax.jit(..., in_shardings=..., out_shardings=...)`` and
``.lower()`` against ShapeDtypeStructs (the dry-run) or real arrays (the
drivers).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, api
from repro.optim import (
    CompressState,
    adamw_update,
    clip_by_global_norm,
    compress_decompress,
)


def make_train_step(
    cfg: ModelConfig,
    lr: Callable | float = 3e-4,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    grad_compress: bool = False,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``grad_compress`` the opt_state is (AdamWState, CompressState)
    and gradients round-trip through int8 error-feedback before AdamW —
    the cross-pod wire format.
    """
    m = api(cfg)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        if grad_compress:
            adam_state, comp_state = opt_state
            grads, comp_state = compress_decompress(grads, comp_state)
            new_params, adam_state = adamw_update(
                grads, adam_state, params, lr, weight_decay=weight_decay)
            new_opt = (adam_state, comp_state)
        else:
            new_params, new_opt = adamw_update(
                grads, opt_state, params, lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return step


def make_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None):
    """(params, batch) -> (last-token logits, primed caches)."""
    m = api(cfg)

    def step(params, batch):
        s = batch["tokens"].shape[1]
        return m.prefill(params, batch, max_seq or s)

    return step


def make_decode_step(cfg: ModelConfig):
    """(params, token, caches, cache_pos) -> (logits, new caches)."""
    m = api(cfg)

    def step(params, token, caches, cache_pos):
        return m.decode_step(params, token, caches, cache_pos)

    return step


def make_eval_loss(cfg: ModelConfig):
    m = api(cfg)

    def step(params, batch):
        return m.train_loss(params, batch)

    return step
