"""The paper's own benchmark models as per-layer tensor networks.

ResNet-18 (CIFAR-10 / Tiny-ImageNet) and ViT-Ti/4 (CIFAR-10) are the
workloads of paper Tables 1-4 and Figs. 3/5.  For the DSE experiments we
need each layer as a contraction problem: TT-conv layers follow eq. (3)-(4)
(5 cores, im2col unfolding), TT-linear layers eq. (2).  The dense
baselines are single-GEMM networks over the same shapes.

These are *cost-model* workloads (the paper's FPGA experiments); the
trainable LM examples live in ``repro.models.lm``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.tensor_network import (
    TensorNetwork,
    dense_linear_network,
    factorize,
    tt_conv_network,
    tt_linear_network,
)


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One network layer: TT network + dense baseline + metadata."""

    name: str
    tt_network: TensorNetwork
    dense_network: TensorNetwork
    dense_macs: int


def _conv_layer(
    name: str,
    c_in: int,
    c_out: int,
    k: int,
    h_out: int,
    w_out: int,
    batch: int,
    rank: int,
) -> LayerDesc:
    patches = h_out * w_out * batch
    in_modes = factorize(c_in, 2)
    out_modes = factorize(c_out, 2)
    # rank clipping at each TT cut (boundary full-rank bounds)
    r1 = min(rank, out_modes[0])
    r2 = min(rank, out_modes[0] * out_modes[1])
    r3 = min(rank, in_modes[1] * k * k)
    r4 = min(rank, k * k)
    tt = tt_conv_network(patches, (in_modes[0], in_modes[1]),
                         (out_modes[0], out_modes[1]), k * k, (r1, r2, r3, r4))
    dense = dense_linear_network(patches, c_in * k * k, c_out)
    return LayerDesc(name, tt, dense, patches * c_in * k * k * c_out)


def _linear_layer(name: str, d_in: int, d_out: int, tokens: int, rank: int,
                  d: int = 3) -> LayerDesc:
    in_modes = factorize(d_in, d)
    out_modes = factorize(d_out, d)
    modes = out_modes + in_modes
    ranks = []
    left, right = 1, math.prod(modes)
    for i in range(len(modes) - 1):
        left *= modes[i]
        right //= modes[i]
        ranks.append(min(rank, left, right))
    tt = tt_linear_network(tokens, in_modes, out_modes, tuple(ranks))
    dense = dense_linear_network(tokens, d_in, d_out)
    return LayerDesc(name, tt, dense, tokens * d_in * d_out)


def resnet18_layers(dataset: str = "cifar10", batch: int = 1,
                    rank: int = 16) -> list[LayerDesc]:
    """ResNet-18 conv backbone (CIFAR-style stem) as contraction problems.

    Spatial sizes: CIFAR-10 starts at 32x32, Tiny-ImageNet at 64x64.
    Downsampling at stages 2-4; two 3x3 convs per basic block.
    """
    side = {"cifar10": 32, "tiny_imagenet": 64}[dataset]
    layers: list[LayerDesc] = []
    layers.append(_conv_layer("stem", 16, 64, 3, side, side, batch, rank))
    stage_ch = [64, 128, 256, 512]
    s = side
    c_prev = 64
    for st, c in enumerate(stage_ch):
        if st > 0:
            s //= 2
        for blk in range(2):
            c_in = c_prev if blk == 0 else c
            layers.append(_conv_layer(f"s{st+1}b{blk+1}c1", c_in, c, 3, s, s, batch, rank))
            layers.append(_conv_layer(f"s{st+1}b{blk+1}c2", c, c, 3, s, s, batch, rank))
            c_prev = c
    layers.append(_linear_layer("fc", 512, 512, batch, rank, d=2))
    return layers


def vit_ti4_layers(batch: int = 1, rank: int = 16,
                   image: int = 32) -> list[LayerDesc]:
    """ViT-Ti/4 on CIFAR-10: 12 blocks, d=192, heads=3, mlp=768.

    Per block: fused QKV (192->576), attn out (192->192), MLP up/down.
    Attention itself (softmax(QK^T)V) is not a weight contraction — the
    DSE operates on weight-bearing layers, as in the paper.
    """
    tokens = (image // 4) ** 2 + 1
    t = tokens * batch
    layers: list[LayerDesc] = []
    for blk in range(12):
        layers.append(_linear_layer(f"b{blk}.qkv", 192, 576, t, rank, d=2))
        layers.append(_linear_layer(f"b{blk}.proj", 192, 192, t, rank, d=2))
        layers.append(_linear_layer(f"b{blk}.fc1", 192, 768, t, rank, d=2))
        layers.append(_linear_layer(f"b{blk}.fc2", 768, 192, t, rank, d=2))
    layers.append(_linear_layer("head", 192, 192, batch, rank, d=2))
    return layers


def model_layers(model: str, dataset: str, batch: int = 1,
                 rank: int = 16) -> list[LayerDesc]:
    if model == "resnet18":
        return resnet18_layers(dataset, batch, rank)
    if model == "vit_ti4":
        return vit_ti4_layers(batch, rank)
    raise ValueError(model)
