"""BENCH_shard — shard_map-routed planned execution vs the jnp fallback.

Before the sharded executor landed, any mesh with an axis > 1 demoted
every planned Pallas layer to the sharding-preserving jnp executor —
multi-device hosts paid the gate exactly where throughput matters.  This
benchmark tracks the payoff of lifting it: wall-clock tokens/s of one
planned TT projection at the serve prefill shape, at 1/2/4/8 forced host
devices, against the jnp-fallback baseline at the same total token count.

Per device count ``n`` a subprocess (device count is fixed at jax init,
so the parent cannot re-width itself) measures two deployments of the
*same* plan layer:

- **planned**  — the plan's ``streaming_tt`` kernel; at ``n > 1`` routed
  through ``jax.shard_map`` over a ``("data",)=n`` mesh, each shard
  running the kernel at its per-shard ``(tokens/n, d_in)`` block
  (``repro.plan.sharded``); at ``n = 1`` the single-device planned path;
- **jnp_fallback** — the same planned contraction steps through the
  reference jnp executor — what the old single-device gate forced on
  every mesh width.

Each width's plan is searched at its per-shard problem size
(``repro.dse --shards n``), so the kernel tilings are the ones the
deployment flow would actually install.  On CPU hosts the kernels run in
interpret mode — absolute numbers are host-speed, but the
planned-vs-fallback *ratio* per width is the quantity the gate decision
hinges on.

  PYTHONPATH=src python -m benchmarks.run --only bench_shard
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from repro.dse_cli import run_dse_plan

from .common import emit

ARCH = "tt-lm-100m"
LAYER = "mlp.wu"          # 768 -> 3072, the widest streamed projection
TOKENS = 512              # serve prefill shape: one batch-1, seq-512 prompt
DEVICE_COUNTS = (1, 2, 4, 8)
REPEATS = 5

_HARNESS = r"""
import dataclasses, json, statistics, sys, time

import jax
import jax.numpy as jnp
import numpy as np

n = int(sys.argv[1])
plan_path = sys.argv[2]
layer = sys.argv[3]
tokens = int(sys.argv[4])
repeats = int(sys.argv[5])
assert jax.device_count() == n, (jax.device_count(), n)

from jax.sharding import Mesh
from repro.configs import get_config
from repro.dse_cli import _block_specs
from repro.nn.linear import linear_init
from repro.plan import load_plan
from repro.plan.executor import planned_tt_linear
from repro.plan.sharded import shard_decision, sharded_tt_linear
from repro.sharding import ShardingRules

cfg = get_config(ARCH_PLACEHOLDER)
spec = next(s for s, _, _ in _block_specs(cfg) if s.name == layer)
lp = load_plan(plan_path).layer(layer)
assert lp.backend == "streaming_tt", lp.backend

n_cores = len(spec.out_modes) + len(spec.in_modes)
params = linear_init(jax.random.PRNGKey(0), spec)
cores = [params[f"core{k}"] for k in range(n_cores)]
x = jax.random.normal(jax.random.PRNGKey(1), (tokens, spec.d_in), jnp.float32)

if n > 1:
    mesh = Mesh(np.array(jax.devices()).reshape(n), ("data",))
    rules = ShardingRules(axis_sizes={"data": n}, mesh=mesh)
    decision = shard_decision(rules, tokens, spec.in_modes)
    assert decision is not None and decision.n_shards == n, decision

    def planned(xs):
        return sharded_tt_linear(lp, xs, cores, spec.in_modes,
                                 spec.out_modes, spec.tt_ranks,
                                 rules=rules, decision=decision)
else:
    def planned(xs):
        return planned_tt_linear(lp, xs, cores, spec.in_modes,
                                 spec.out_modes, spec.tt_ranks)

ref_lp = dataclasses.replace(lp, backend="jnp")

def fallback(xs):
    return planned_tt_linear(ref_lp, xs, cores, spec.in_modes,
                             spec.out_modes, spec.tt_ranks)

planned_j = jax.jit(planned)
fallback_j = jax.jit(fallback)

# numerics sanity: same function, different contraction arithmetic
np.testing.assert_allclose(np.asarray(planned_j(x)),
                           np.asarray(fallback_j(x)), rtol=2e-4, atol=2e-5)

def bench(fn):
    fn(x).block_until_ready()  # compile outside the timed region
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return tokens / statistics.median(ts)

print(json.dumps({"tok_s_planned": bench(planned_j),
                  "tok_s_jnp_fallback": bench(fallback_j)}))
"""


def _measure(n: int, plan_path: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), os.pardir, "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    harness = _HARNESS.replace("ARCH_PLACEHOLDER", repr(ARCH))
    proc = subprocess.run(
        [sys.executable, "-c", harness, str(n), plan_path, LAYER,
         str(TOKENS), str(REPEATS)],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard harness failed at n={n}\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run() -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        for n in DEVICE_COUNTS:
            _, plan = run_dse_plan(ARCH, tokens=TOKENS,
                                   plan_backend="streaming_tt",
                                   shards=(n if n > 1 else None))
            lp = plan.layer(LAYER)
            plan_path = os.path.join(tmp, f"plan_s{n}.json")
            plan.save(plan_path)
            m = _measure(n, plan_path)
            rows.append({
                "arch": ARCH,
                "layer": LAYER,
                "tokens": TOKENS,
                "devices": n,
                "tokens_per_shard": TOKENS // n,
                "block_tokens": lp.tiling.block_tokens,
                "tok_s_planned": m["tok_s_planned"],
                "tok_s_jnp_fallback": m["tok_s_jnp_fallback"],
                "planned_vs_fallback":
                    m["tok_s_planned"] / m["tok_s_jnp_fallback"],
            })
    emit("BENCH_shard", rows)
    return rows


if __name__ == "__main__":
    run()
