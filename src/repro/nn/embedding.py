"""Token embedding and LM head: dense or TT-factorized.

TT embedding follows the TT-Rec / TT-matrix format: the table
``E in R^{V x D}`` is stored as cores ``G_k in R^{r_{k-1} x v_k x d_k x r_k}``
with ``V = prod v_k`` and ``D = prod d_k``.  A row gather decomposes the
token id into mixed-radix digits (i_1..i_d) and contracts the per-digit
slices ``G_k[:, i_k, :, :]`` — per-token cost ``O(d * r^2 * d_k)`` instead
of a ``V x D`` table lookup, and parameter count
``O(sum r^2 v_k d_k)`` instead of ``V * D``.

The LM head (``D -> V`` projection) reuses the *same* cores transposed —
weight tying — or a separate TT-linear when untied.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tensor_network import factorize
from repro.sharding import shard
from .linear import TTConfig


def _shard_tokens_dim(x: jax.Array) -> jax.Array:
    """Constrain dim0 (the flattened token dim) to the DP(+SP) axes — keeps
    the TT chain's intermediates sharded (and consistent with the layout of
    the surrounding tokens-major tensors, avoiding forced reshards)."""
    return shard(x, *(("tokens",) + (None,) * (x.ndim - 1)))


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    name: str
    vocab: int
    d_model: int
    tt: Optional[TTConfig] = None

    @property
    def tensorized(self) -> bool:
        return self.tt is not None and self.tt.enabled and "embed" in self.tt.targets

    @property
    def vocab_modes(self) -> tuple[int, ...]:
        assert self.tt is not None
        return factorize(self.vocab, self.tt.d)

    @property
    def d_modes(self) -> tuple[int, ...]:
        assert self.tt is not None
        return factorize(self.d_model, self.tt.d)

    @property
    def tt_ranks(self) -> tuple[int, ...]:
        """Interior TT-matrix ranks (length d-1), clipped to full rank."""
        assert self.tt is not None
        vm, dm = self.vocab_modes, self.d_modes
        ranks = []
        left, right = 1, self.vocab * self.d_model
        for k in range(len(vm) - 1):
            left *= vm[k] * dm[k]
            right //= vm[k] * dm[k]
            ranks.append(min(self.tt.rank, left, right))
        return tuple(ranks)

    def n_params(self) -> int:
        if not self.tensorized:
            return self.vocab * self.d_model
        vm, dm = self.vocab_modes, self.d_modes
        ranks = (1,) + self.tt_ranks + (1,)
        return sum(
            ranks[k] * vm[k] * dm[k] * ranks[k + 1] for k in range(len(vm))
        )


def embedding_init(rng: jax.Array, spec: EmbeddingSpec, dtype=jnp.float32) -> dict:
    if not spec.tensorized:
        table = jax.random.normal(rng, (spec.vocab, spec.d_model)) * 0.02
        return {"table": table.astype(dtype)}
    vm, dm = spec.vocab_modes, spec.d_modes
    ranks = (1,) + spec.tt_ranks + (1,)
    d = len(vm)
    # product of d gaussian cores -> per-core std for overall 0.02 stddev
    prod_ranks = math.prod(spec.tt_ranks) or 1
    per_core_std = (0.02**2 / prod_ranks) ** (1.0 / (2 * d))
    keys = jax.random.split(rng, d)
    params = {}
    for k in range(d):
        shape = (ranks[k], vm[k], dm[k], ranks[k + 1])
        params[f"core{k}"] = (
            jax.random.normal(keys[k], shape) * per_core_std
        ).astype(dtype)
    return params


def _mixed_radix(ids: jax.Array, modes: tuple[int, ...]) -> list[jax.Array]:
    """Decompose ids into digits for the given mode radices (big-endian)."""
    digits = []
    rem = ids
    for radix in reversed(modes[1:]):
        digits.append(rem % radix)
        rem = rem // radix
    digits.append(rem % modes[0])
    return list(reversed(digits))


def embedding_apply(spec: EmbeddingSpec, params: dict, ids: jax.Array) -> jax.Array:
    """ids (...,) int32 -> embeddings (..., d_model)."""
    if not spec.tensorized:
        return params["table"][ids]
    vm, dm = spec.vocab_modes, spec.d_modes
    digits = _mixed_radix(ids, vm)
    # left-to-right chain contraction: carry (..., r_k, D_prefix)
    out = None
    for k in range(len(vm)):
        core = params[f"core{k}"]             # (r_{k-1}, v_k, d_k, r_k)
        sl = core[:, digits[k]]               # (r_{k-1}, ..., d_k, r_k)
        # move the token axes in front: (..., r_{k-1}, d_k, r_k)
        sl = jnp.moveaxis(sl, 0, -3)
        if out is None:
            out = sl[..., 0, :, :]            # r_0 == 1 -> (..., d_0, r_1)
        else:
            # (..., P, r) x (..., r, d_k, r') -> (..., P, d_k, r')
            out = jnp.einsum("...pr,...rds->...pds", out, sl)
            out = out.reshape(out.shape[:-3] + (out.shape[-3] * out.shape[-2], out.shape[-1]))
        out = _shard_tokens_dim(out)
    out = out[..., 0]                         # r_d == 1
    return out.reshape(ids.shape + (spec.d_model,))


def head_apply(spec: EmbeddingSpec, params: dict, x: jax.Array) -> jax.Array:
    """Tied LM head: x (..., D) -> logits (..., V) through the same weights.

    Right-to-left chain: carry (T, d_1..d_k, V_suffix, r_k); step k folds
    d_k away and grows the vocab suffix by v_k.  Contraction order is a
    *memory* decision (the paper's thesis applied to the LM head): the
    left-to-right order's peak intermediate is ``T * v_1 * r * D/d_1``
    (~8x the logits for a 65k vocab), while right-to-left peaks at ~2x
    the logits buffer.  FLOPs are comparable; memory is not.
    """
    if not spec.tensorized:
        return jnp.einsum("...d,vd->...v", x, params["table"])
    vm, dm = spec.vocab_modes, spec.d_modes
    lead = x.shape[:-1]
    tokens = math.prod(lead) if lead else 1
    carry = x.reshape((tokens,) + tuple(dm))  # (T, d_1, ..., d_d)
    carry = carry[..., None, None]            # (T, d_1..d_d, V_s=1, r_d=1)
    for k in range(len(vm) - 1, -1, -1):
        core = params[f"core{k}"]             # (r_{k-1}, v_k, d_k, r_k)
        # (t, ..., d_k, V_s, r_k) x (r_{k-1}, v_k, d_k, r_k)
        carry = jnp.einsum("t...dvs,rwds->t...wvr", carry, core)
        shp = carry.shape                     # (t, ..., v_k, V_s, r_{k-1})
        carry = carry.reshape(shp[:-3] + (shp[-3] * shp[-2], shp[-1]))
        carry = _shard_tokens_dim(carry)
    logits = carry[:, :, 0]                   # r_0 == 1 -> (T, V)
    return logits.reshape(lead + (spec.vocab,))
