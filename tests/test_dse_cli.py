"""`python -m repro.dse` CLI: report schema, objectives, module hook."""

import json
import os
import subprocess
import sys

import pytest

from repro.dse_cli import main, model_dse_layers, run_dse
from repro.configs import get_config

REQUIRED_KEYS = {
    "arch", "hw", "objective", "top_k", "tokens", "engine", "strategy",
    "total_latency_s", "total_objective", "n_layers", "timings", "table",
    "layers",
}


def test_cli_smoke_json(capsys):
    assert main(["--arch", "tt-lm-100m", "--top-k", "2"]) == 0
    report = json.loads(capsys.readouterr().out)  # must be valid JSON
    assert REQUIRED_KEYS <= set(report)
    assert report["strategy"] in ("monolithic", "split")
    assert report["n_layers"] == len(report["layers"]) > 0
    assert report["total_latency_s"] > 0
    for layer in report["layers"]:
        assert layer["dataflow"] in ("IS", "OS", "WS")
        assert tuple(layer["partitioning"]) in ((1, 1), (1, 2), (2, 1))
        assert 0 <= layer["path_index"] < 2
        assert layer["latency_s"] > 0
    assert pytest.approx(report["total_latency_s"]) == sum(
        l["latency_s"] for l in report["layers"])


def test_cli_out_file(tmp_path, capsys):
    out = tmp_path / "report.json"
    assert main(["--arch", "tt-lm-100m", "--top-k", "2", "--tokens", "64",
                 "--out", str(out)]) == 0
    capsys.readouterr()
    report = json.loads(out.read_text())
    assert report["tokens"] == 64


def test_edp_objective_consistent():
    lat = run_dse("tt-lm-100m", top_k=2, tokens=128)
    edp = run_dse("tt-lm-100m", top_k=2, tokens=128, objective="edp")
    assert edp["total_objective"] <= edp["total_latency_s"] * 1  # joule-seconds, tiny
    # the EDP argmin can only match or exceed the latency argmin's latency
    assert edp["total_latency_s"] >= lat["total_latency_s"] - 1e-15


def test_tpu_target_and_vision_arch():
    r = run_dse("tt-lm-100m", hw="tpu_v5e", top_k=2, tokens=64)
    assert r["hw"] == "tpu_v5e" and r["total_latency_s"] > 0
    v = run_dse("vit_ti4/cifar10", top_k=2)
    assert v["n_layers"] > 0 and v["tokens"] == 1


def test_unknown_arch_and_hw_raise():
    with pytest.raises(KeyError):
        run_dse("no-such-model")
    with pytest.raises(KeyError):
        run_dse("tt-lm-100m", hw="no-such-hw")


def test_model_dse_layers_covers_families():
    """Every config family enumerates at least its head projection when
    tensorized; tt-lm-100m covers attn+mlp+head."""
    cfg = get_config("tt-lm-100m")
    names = [n for n, _ in model_dse_layers(cfg, tokens=64)]
    assert any(n.startswith("attn.") for n in names)
    assert any(n.startswith("mlp.") for n in names)
    assert "head" in names


@pytest.mark.slow
def test_module_invocation_subprocess():
    """The documented entry point: PYTHONPATH=src python -m repro.dse ..."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.dse", "--arch", "tt-lm-100m",
         "--top-k", "2", "--tokens", "64"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    report = json.loads(proc.stdout)
    assert report["arch"] == "tt-lm-100m"
