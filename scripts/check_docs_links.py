"""Docs link checker: every relative markdown link must resolve.

Scans README.md and docs/*.md for ``[text](target)`` links; non-URL
targets (stripped of ``#anchors``) must exist relative to the linking
file (or the repo root as a fallback).  Exits non-zero listing every
broken link — run by CI so docs cross-references stay valid.

  python scripts/check_docs_links.py
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — excluding images' size suffixes and inline code
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def _targets(path: str):
    text = open(path, encoding="utf-8").read()
    # drop fenced code blocks: link-shaped text inside them is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK_RE.finditer(text):
        yield m.group(1)


def check(paths) -> list[str]:
    broken = []
    for md in paths:
        base = os.path.dirname(md)
        for target in _targets(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:           # pure in-page anchor
                continue
            ok = (os.path.exists(os.path.join(base, rel))
                  or os.path.exists(os.path.join(REPO, rel)))
            if not ok:
                broken.append(f"{os.path.relpath(md, REPO)}: {target}")
    return broken


def main() -> int:
    paths = sorted(
        [os.path.join(REPO, "README.md")]
        + glob.glob(os.path.join(REPO, "docs", "*.md"))
    )
    broken = check(paths)
    for b in broken:
        print(f"broken link: {b}", file=sys.stderr)
    print(f"checked {len(paths)} files: "
          f"{'OK' if not broken else f'{len(broken)} broken links'}")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
