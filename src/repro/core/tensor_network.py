"""Tensor-network representation of tensorized (TT) layers.

A tensorized layer's forward pass is a tensor network: TT cores + the input
activation tensor, joined by labelled edges.  A *contraction path* is a
sequence of pairwise contractions that reduces the network to the output
tensor.  Each pairwise contraction is a GEMM whose (M, K, N) shape is
derived from the edge dimensions — this GEMM view is what the latency
simulator (``repro.core.simulator``) consumes.

Graph semantics follow Fig. 1 of the paper: a node with d edges is a d-way
tensor; an edge shared by two nodes is contracted; edges appearing on a
single node are *free* and survive into the output.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Node:
    """One tensor in the network.

    ``edges`` are string labels, one per axis; ``dims`` the matching sizes.
    ``kind`` distinguishes weight cores (resident, small) from the streamed
    activation tensor — the simulator uses this to decide which operand is a
    candidate for the *stationary* role of a dataflow.
    """

    name: str
    edges: tuple[str, ...]
    dims: tuple[int, ...]
    kind: str = "core"  # "core" | "input"

    def __post_init__(self) -> None:
        if len(self.edges) != len(self.dims):
            raise ValueError(
                f"node {self.name}: {len(self.edges)} edges vs {len(self.dims)} dims"
            )
        if len(set(self.edges)) != len(self.edges):
            raise ValueError(f"node {self.name}: repeated edge label")

    @property
    def size(self) -> int:
        return math.prod(self.dims)

    def dim_of(self, edge: str) -> int:
        return self.dims[self.edges.index(edge)]


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """GEMM view of one pairwise contraction: (M x K) @ (K x N).

    ``a_is_input`` / ``b_is_input`` record whether either operand descends
    from the streamed activation tensor (vs. resident weight cores); the
    simulator's IS/WS dataflows care about this distinction.
    """

    M: int
    K: int
    N: int
    a_is_input: bool = False
    b_is_input: bool = False

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def flops(self) -> int:
        return 2 * self.M * self.K * self.N

    def as_tuple(self) -> tuple[int, int, int]:
        return (self.M, self.K, self.N)


class TensorNetwork:
    """An immutable set of nodes with shared-edge contraction semantics."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes: tuple[Node, ...] = tuple(nodes)
        self._check()

    def _check(self) -> None:
        count: dict[str, list[int]] = {}
        for idx, n in enumerate(self.nodes):
            for e, d in zip(n.edges, n.dims):
                count.setdefault(e, []).append(d)
        for e, ds in count.items():
            if len(ds) > 2:
                raise ValueError(f"edge {e} shared by >2 nodes (hyper-edges unsupported)")
            if len(ds) == 2 and ds[0] != ds[1]:
                raise ValueError(f"edge {e}: dim mismatch {ds}")
        self._edge_count = {e: len(ds) for e, ds in count.items()}

    # -- structural queries ------------------------------------------------
    @property
    def free_edges(self) -> tuple[str, ...]:
        return tuple(e for e, c in self._edge_count.items() if c == 1)

    def output_dims(self) -> dict[str, int]:
        out = {}
        for n in self.nodes:
            for e, d in zip(n.edges, n.dims):
                if self._edge_count[e] == 1:
                    out[e] = d
        return out

    def shared_edges(self, i: int, j: int) -> tuple[str, ...]:
        a, b = self.nodes[i], self.nodes[j]
        return tuple(e for e in a.edges if e in b.edges)

    def total_macs(self, path: Sequence[tuple[int, int]]) -> int:
        return sum(g.macs for g in self.gemm_sequence(path))

    # -- contraction -------------------------------------------------------
    def contract_pair(self, i: int, j: int) -> tuple["TensorNetwork", GemmShape]:
        """Contract nodes i and j; returns the reduced network + GEMM shape.

        The result node keeps A's free edges then B's free edges (A = node i).
        """
        if i == j:
            raise ValueError("cannot contract a node with itself")
        a, b = self.nodes[i], self.nodes[j]
        shared = set(a.edges) & set(b.edges)
        a_free = [(e, d) for e, d in zip(a.edges, a.dims) if e not in shared]
        b_free = [(e, d) for e, d in zip(b.edges, b.dims) if e not in shared]
        m = math.prod(d for _, d in a_free)
        n = math.prod(d for _, d in b_free)
        k = math.prod(a.dim_of(e) for e in shared) if shared else 1
        gemm = GemmShape(
            M=m, K=k, N=n,
            a_is_input=(a.kind == "input"),
            b_is_input=(b.kind == "input"),
        )
        new_kind = "input" if (a.kind == "input" or b.kind == "input") else "core"
        merged = Node(
            name=f"({a.name}*{b.name})",
            edges=tuple(e for e, _ in a_free) + tuple(e for e, _ in b_free),
            dims=tuple(d for _, d in a_free) + tuple(d for _, d in b_free),
            kind=new_kind,
        )
        rest = [nd for t, nd in enumerate(self.nodes) if t not in (i, j)]
        return TensorNetwork(rest + [merged]), gemm

    def gemm_sequence(self, path: Sequence[tuple[int, int]]) -> list[GemmShape]:
        """GEMM shapes produced by executing ``path`` (list of index pairs).

        Path indices refer to the *current* node list at each step (the merged
        node is appended at the end), matching ``contract_pair`` semantics.
        """
        tn: TensorNetwork = self
        shapes = []
        for (i, j) in path:
            tn, g = tn.contract_pair(i, j)
            shapes.append(g)
        if len(tn.nodes) != 1:
            raise ValueError("path does not fully contract the network")
        return shapes

    # -- canonical state key for redundancy pruning ------------------------
    def state_key(self) -> frozenset:
        """Order-independent signature of the current node set.

        Two partial contraction orders that produce the same set of
        intermediate tensors (same edge sets) are *computationally
        equivalent* going forward — the DFS prunes revisits (paper §3.2).
        """
        return frozenset(frozenset(n.edges) for n in self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def __repr__(self) -> str:
        return "TN[" + ", ".join(f"{n.name}{n.dims}" for n in self.nodes) + "]"


# ---------------------------------------------------------------------------
# Builders for the paper's layer families
# ---------------------------------------------------------------------------

def tt_linear_network(
    batch: int | Sequence[int],
    in_modes: Sequence[int],
    out_modes: Sequence[int],
    ranks: Sequence[int],
) -> TensorNetwork:
    """TT-format linear layer (paper eq. 2 / Fig. 1e).

    Cores G_1..G_d carry output modes ``m_k``; G_{d+1}..G_{2d} carry input
    modes ``n_k``; consecutive cores share rank edges.  Boundary ranks
    (r_0 = r_2d = 1) are dropped.  ``ranks`` has length 2d-1.

    ``batch`` may be a tuple — the input then keeps multiple leading batch
    edges (``b0``, ``b1``, ...).  Contraction paths and MACs are identical
    to the flattened form; the distributed executor uses the split form so
    (batch, seq) shardings survive without relayout.
    """
    d_out, d_in = len(out_modes), len(in_modes)
    n_cores = d_out + d_in
    if len(ranks) != n_cores - 1:
        raise ValueError(f"need {n_cores - 1} interior ranks, got {len(ranks)}")
    nodes = []
    for k in range(n_cores):
        edges: list[str] = []
        dims: list[int] = []
        if k > 0:
            edges.append(f"r{k}")
            dims.append(ranks[k - 1])
        if k < d_out:
            edges.append(f"i{k + 1}")
            dims.append(out_modes[k])
        else:
            edges.append(f"j{k - d_out + 1}")
            dims.append(in_modes[k - d_out])
        if k < n_cores - 1:
            edges.append(f"r{k + 1}")
            dims.append(ranks[k])
        nodes.append(Node(f"G{k + 1}", tuple(edges), tuple(dims), kind="core"))
    if isinstance(batch, (tuple, list)):
        b_edges = tuple(f"b{t}" for t in range(len(batch)))
        b_dims = tuple(batch)
    else:
        b_edges, b_dims = ("b",), (batch,)
    x_edges = b_edges + tuple(f"j{t + 1}" for t in range(d_in))
    x_dims = b_dims + tuple(in_modes)
    nodes.append(Node("X", x_edges, x_dims, kind="input"))
    return TensorNetwork(nodes)


def tt_conv_network(
    patches: int,
    in_modes: tuple[int, int],
    out_modes: tuple[int, int],
    kernel: int,
    ranks: Sequence[int],
) -> TensorNetwork:
    """TT-format convolution (paper eq. 3-4 / Fig. 1f), im2col view.

    Five cores: G1 (O1), G2 (O2), G3 (I1), G4 (I2), G5 (K=Kh*Kw); the
    unfolded input X_unf has edges (I1, I2, K, L) with L = spatial patches
    x batch.  ``ranks`` = (r1, r2, r3, r4).
    """
    (o1, o2), (i1, i2) = out_modes, in_modes
    r1, r2, r3, r4 = ranks
    nodes = [
        Node("G1", ("o1", "r1"), (o1, r1)),
        Node("G2", ("r1", "o2", "r2"), (r1, o2, r2)),
        Node("G3", ("r2", "i1", "r3"), (r2, i1, r3)),
        Node("G4", ("r3", "i2", "r4"), (r3, i2, r4)),
        Node("G5", ("r4", "k"), (r4, kernel)),
        Node("X", ("i1", "i2", "k", "l"), (i1, i2, kernel, patches), kind="input"),
    ]
    return TensorNetwork(nodes)


def dense_linear_network(batch: int, n_in: int, n_out: int) -> TensorNetwork:
    """Uncompressed baseline: one weight node, one GEMM."""
    return TensorNetwork(
        [
            Node("W", ("j", "i"), (n_in, n_out)),
            Node("X", ("b", "j"), (batch, n_in), kind="input"),
        ]
    )


def factorize(n: int, d: int) -> tuple[int, ...]:
    """Balanced d-way factorization of n (largest factors first).

    Greedy: repeatedly peel the largest prime factor onto the currently
    smallest bucket.  Guarantees prod == n; buckets as equal as possible.
    """
    if d <= 0:
        raise ValueError("d must be positive")
    if d == 1:
        return (n,)
    primes: list[int] = []
    m = n
    p = 2
    while p * p <= m:
        while m % p == 0:
            primes.append(p)
            m //= p
        p += 1
    if m > 1:
        primes.append(m)
    buckets = [1] * d
    for f in sorted(primes, reverse=True):
        buckets[buckets.index(min(buckets))] *= f
    return tuple(sorted(buckets, reverse=True))
