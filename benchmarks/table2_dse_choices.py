"""Table 2 — distribution of layer-wise optimal configuration choices.

For each benchmark model the global DSE (Algorithm 1) selects per layer:
the hardware strategy (monolithic M vs split S), the contraction path
(Path-1 = MAC-optimal vs Path-k), and the dataflow.  The paper's
observation: 25-50% of layers pick a non-MAC-optimal path, and dataflow
choices vary per model/mode — the same distributions are reported here.

Training mode approximates the backward pass as the forward contraction
set at 3x the token count (dL/dX and dL/dW have forward-like shapes) —
an explicit, documented modelling choice.

Distributions are derived from the ``repro.dse`` CLI's JSON report
(``repro.dse_cli.run_dse``), so this benchmark exercises the same
end-to-end pipeline as ``python -m repro.dse --arch resnet18/cifar10``.
"""

from __future__ import annotations

from repro.dse_cli import run_dse
from .common import emit

MODELS = [
    ("resnet18", "tiny_imagenet"),
    ("resnet18", "cifar10"),
    ("vit_ti4", "cifar10"),
]


def run() -> list[dict]:
    rows = []
    for model, dataset in MODELS:
        for mode, batch in (("inference", 1), ("training", 3)):
            report = run_dse(f"{model}/{dataset}", top_k=4, tokens=batch)
            layers = report["layers"]
            n = len(layers)
            path1 = sum(1 for l in layers if l["mac_optimal_path"])
            split = sum(1 for l in layers if l["partitioning"] != [1, 1])
            dfs = {d: 0 for d in ("IS", "OS", "WS")}
            for l in layers:
                dfs[l["dataflow"]] += 1
            rows.append({
                "model": model,
                "dataset": dataset,
                "mode": mode,
                "strategy": report["strategy"],
                "split_pct": 100.0 * split / n,
                "path1_pct": 100.0 * path1 / n,
                "pathk_pct": 100.0 * (n - path1) / n,
                "IS_pct": 100.0 * dfs["IS"] / n,
                "OS_pct": 100.0 * dfs["OS"] / n,
                "WS_pct": 100.0 * dfs["WS"] / n,
                "total_latency_ms": report["total_latency_s"] * 1e3,
            })
    emit("table2_dse_choices", rows)
    return rows


if __name__ == "__main__":
    run()
