"""Shared test config.  NOTE: no global XLA device-count flags here —
smoke tests and benches must see the real single CPU device; only the
dry-run subprocess tests use forced host platform device counts."""

import os
import sys

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # offline containers: register the minimal deterministic fallback so the
    # property-test modules collect and run (see _hypothesis_fallback.py)
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    _hypothesis_fallback.install()

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
