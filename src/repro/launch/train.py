"""Training driver: data pipeline -> jitted step -> fault-tolerant loop.

Runs any registry config end-to-end (CPU-feasible with ``--smoke`` or the
``tt-lm-100m`` example arch).  The loop composes every substrate layer:
deterministic resumable data, AdamW + cosine schedule, optional int8
error-feedback gradient compression, async atomic checkpoints, straggler
monitoring and preemption-safe shutdown.

  PYTHONPATH=src python -m repro.launch.train --arch tt-lm-100m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_pipeline
from repro.launch.mesh import make_rules, make_test_mesh, param_shardings
from repro.launch.steps import make_train_step
from repro.models import api
from repro.models.config import ShapeConfig
from repro.optim import adamw_init, compress_init, linear_warmup_cosine
from repro.runtime import FaultTolerantLoop, StragglerMonitor
from repro.sharding import use_rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tt-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="install a DSE execution plan (repro.dse --emit-plan, "
                         "ideally --mode train): projections contract along "
                         "the planned paths through the planned Pallas "
                         "kernels, forward AND backward — the kernels' "
                         "custom VJPs contract the plan's gradient networks, "
                         "so jax.grad crosses pallas_call end-to-end")
    ap.add_argument("--plan-backend", default=None,
                    choices=("jnp", "tt_gemm", "streaming_tt"),
                    help="force one kernel backend for every plan layer "
                         "(jnp = the pre-v2 reference behaviour)")
    args = ap.parse_args()

    cfg = get_config(args.arch, tt=not args.dense, smoke=args.smoke)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_test_mesh()
    rules = make_rules(cfg, shape, mesh)
    if args.plan:
        from repro.plan import check_plan_for_config, load_plan, reset_execution_log

        plan = load_plan(args.plan)
        problems = check_plan_for_config(plan, args.arch, cfg)
        if problems:
            raise SystemExit(
                "error: plan/model mismatch: " + "; ".join(problems))
        reset_execution_log()
        m = api(cfg, plan=plan, plan_backend=args.plan_backend)
        backends = sorted({lp.backend for lp in plan.layers})
        forced = (f", backends forced to {args.plan_backend}"
                  if args.plan_backend else "")
        print(f"installed plan {args.plan} (backends {backends}{forced})")
    else:
        m = api(cfg)
    pipe = make_pipeline(cfg.vocab, args.seq, args.batch)

    lr = linear_warmup_cosine(args.lr, args.warmup, args.steps)
    step_fn = make_train_step(cfg, lr=lr, grad_compress=args.grad_compress)

    with use_rules(rules):
        p_shapes = jax.eval_shape(m.init_params, jax.random.PRNGKey(0))
        p_sh = param_shardings(p_shapes, mesh)
        params = jax.jit(m.init_params, out_shardings=p_sh)(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        if args.grad_compress:
            opt = (opt, compress_init(params))
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        start = 0
        state = {"params": params, "opt": opt}
        if args.resume and mgr.latest_step() is not None:
            start, state = mgr.restore(state)
            print(f"resumed from step {start}")

        monitor = StragglerMonitor()
        t_start = time.time()

        def one_step(state, step):
            batch = pipe.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = jit_step(state["params"], state["opt"], batch)
            if step % args.log_every == 0:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.time() - t_start
                print(f"step {step:5d}  loss {loss:.4f}  gnorm {gn:.3f}  "
                      f"({dt:.1f}s)", flush=True)
            return {"params": params, "opt": opt}

        loop = FaultTolerantLoop(one_step, mgr, checkpoint_every=args.ckpt_every,
                                 straggler=monitor)
        state, done = loop.run(state, start, args.steps - start)
        mgr.save(done, state)
        if args.plan:
            from repro.plan import execution_log

            log = execution_log()
            fwd = sorted({r["backend"] for r in log
                          if r.get("phase", "fwd") == "fwd"})
            bwd = sorted({r["backend"] for r in log
                          if r.get("phase") == "bwd"})
            print(f"planned execution under grad: fwd backends {fwd}, "
                  f"bwd backends {bwd}")
            fused = [r for r in log if r.get("segment")
                     and r["segment"][1] - r["segment"][0] >= 2]
            if fused:
                print(f"fused segments under grad: {len(fused)} chain runs "
                      "(VMEM-resident intermediates)")
            meshes = sorted({r.get("mesh", "") for r in log} - {""})
            if meshes:
                print(f"sharded planned execution: mesh {' '.join(meshes)} "
                      f"(per-shard kernels via shard_map)")
        print(f"finished at step {done}; stragglers flagged: {monitor.flagged}")


if __name__ == "__main__":
    main()
