"""Per-architecture smoke tests (reduced same-family configs) +
decode-vs-full-forward consistency — the strongest cache-machinery check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models import lm as lm_mod


def _batch(cfg, b=2, s=16, seed=1):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family in ("vlm", "encdec"):
        n = cfg.n_frontend_tokens or 8
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(b, n, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: finite loss, finite grads, shapes."""
    cfg = get_config(arch, smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, caches = m.prefill(params, batch, 32)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = m.decode_step(params, tok, caches,
                                     jnp.asarray(16, jnp.int32))
    assert logits2.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "zamba2-1.2b", "rwkv6-7b",
                                  "chatglm3-6b"])
def test_decode_equals_full_forward(arch):
    """prefill(x[:8]) + decode(x[8]) logits == full forward at position 8.

    (MoE archs excluded: capacity-based routing depends on the token GROUP
    — a decoded token routes alone while prefill routes it among its
    neighbours, so exact equality is not a property of capacity MoE.)"""
    cfg = get_config(arch, smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)), jnp.int32)
    full_logits, _, _ = lm_mod.forward(cfg, params, toks)
    _, caches = m.prefill(params, {"tokens": toks[:, :8]}, 16)
    dec, _ = m.decode_step(params, toks[:, 8:9], caches,
                           jnp.asarray(8, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_dense_vs_tt_param_count():
    """TT must actually compress: full-size configs, analytic param counts."""
    from repro.models.lm import count_params
    cfg_tt = get_config("chatglm3-6b", smoke=True)
    cfg_dense = get_config("chatglm3-6b", tt=False, smoke=True)
    m_tt, m_dense = api(cfg_tt), api(cfg_dense)
    p_tt = jax.eval_shape(m_tt.init_params, jax.random.PRNGKey(0))
    p_dn = jax.eval_shape(m_dense.init_params, jax.random.PRNGKey(0))
    n_tt = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p_tt))
    n_dn = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p_dn))
    assert n_tt < n_dn


def test_loss_chunking_matches_unchunked():
    cfg = get_config("phi3-medium-14b", smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    full = lm_mod.train_loss(cfg.with_(loss_chunk=0), params, batch)
    chunked = lm_mod.train_loss(cfg.with_(loss_chunk=4), params, batch)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_scan_vs_unrolled_layers_identical():
    cfg = get_config("glm4-9b", smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l_scan = m.train_loss(params, batch)
    l_unroll = api(cfg.with_(scan_layers=False)).train_loss(params, batch)
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)
