"""Feasible kernel-variant enumeration for the autotuner.

The heuristic plan compiler derives one tiling per layer from the path's
dominant GEMM (``plan/compiler._tiling_for_path``); the autotuner instead
*measures* a sweep of feasible variants and keeps the argmin.  The sweep
space mirrors what the runtime can actually execute:

- GEMM blocks come from the same power-of-two ladder ``ops.clamp_block``
  resolves against at trace time, clamped per dimension — a variant
  never exceeds the next power of two above the dimension (larger blocks
  only pad with zeros, see ``kernels/tt_gemm``'s automatic padding);
- streaming token blocks are power-of-two sweeps additionally filtered
  by the VMEM feasibility predicate (``plan.compiler.streaming_fits``) —
  a measured ``block_tokens`` never violates the budget the backend
  choice assumed.

The heuristic default is always injected into the sweep, so a measured
tiling can tie the heuristic but never lose to it (up to measurement
noise on the machine doing the tuning).
"""

from __future__ import annotations

from typing import Sequence

from repro.kernels.ops import clamp_block
from repro.plan.compiler import VMEM_BUDGET_BYTES, streaming_fits
from repro.core.tensor_network import TensorNetwork

#: power-of-two block caps swept per GEMM dimension; each is clamped to
#: the dimension (``clamp_block``) and the set deduped, so small dims
#: contribute one candidate and large dims up to ``len(GEMM_BLOCK_CAPS)``
GEMM_BLOCK_CAPS = (64, 128, 256, 512)

#: token-block caps swept for the streaming kernel (clamped + VMEM-filtered)
STREAM_BLOCK_CAPS = (32, 64, 128, 256, 512, 1024)


def block_candidates(dim: int,
                     caps: Sequence[int] = GEMM_BLOCK_CAPS) -> list[int]:
    """Deduped feasible blocks for one dimension (pow2, >= 8, <= ~dim)."""
    return sorted({clamp_block(c, dim) for c in caps})


def gemm_variants(
    M: int, K: int, N: int,
    *,
    caps: Sequence[int] = GEMM_BLOCK_CAPS,
    include: Sequence[tuple[int, int, int]] = (),
) -> list[tuple[int, int, int]]:
    """Feasible ``(block_m, block_k, block_n)`` sweep for one GEMM shape.

    ``include`` injects extra variants (the compiler's heuristic tiling)
    so the measured argmin is never worse than the default.  The list is
    sorted for deterministic measurement order.
    """
    out = {
        (bm, bk, bn)
        for bm in block_candidates(M, caps)
        for bk in block_candidates(K, caps)
        for bn in block_candidates(N, caps)
    }
    for bm, bk, bn in include:
        out.add((clamp_block(int(bm), M), clamp_block(int(bk), K),
                 clamp_block(int(bn), N)))
    return sorted(out)


def streaming_variants(
    tn: TensorNetwork,
    steps,
    tokens: int,
    *,
    caps: Sequence[int] = STREAM_BLOCK_CAPS,
    budget_bytes: int = VMEM_BUDGET_BYTES,
    include: Sequence[int] = (),
) -> list[int]:
    """Feasible ``block_tokens`` sweep for one streaming-layer problem.

    Candidates are clamped to the streamed token count, then filtered by
    the same VMEM-fit predicate the plan compiler's backend choice uses —
    every returned value can actually execute as a fused in-VMEM block.
    ``include`` injects the heuristic default (kept even if the dominant
    sweep dedups it away).
    """
    cands = {clamp_block(c, tokens) for c in caps}
    for bt in include:
        cands.add(clamp_block(int(bt), tokens))
    return sorted(
        bt for bt in cands
        if streaming_fits(tn, steps, bt, budget_bytes=budget_bytes)
    )


def fused_token_variants(
    tn: TensorNetwork,
    steps,
    segments,
    tokens: int,
    *,
    caps: Sequence[int] = STREAM_BLOCK_CAPS,
    budget_bytes: int = VMEM_BUDGET_BYTES,
    include: Sequence[int] = (),
) -> list[int]:
    """Feasible ``block_tokens`` sweep for one fused-segment problem.

    Candidates are clamped to the streamed token count and filtered to
    the blocks at which the greedy segmentation *reproduces exactly the
    given segments* — a measured variant always executes the same fused
    chain runs the cost model priced, never a re-segmented layout.
    ``include`` injects the compiler's heuristic default.
    """
    from repro.core import fusion

    steps = tuple(tuple(s) for s in steps)
    segments = tuple((int(s), int(e)) for s, e in segments)
    cands = {clamp_block(c, tokens) for c in caps}
    for bt in include:
        cands.add(clamp_block(int(bt), tokens))
    return sorted(
        bt for bt in cands
        if fusion.segment_path(tn, steps, block_tokens=bt,
                               budget_bytes=budget_bytes) == segments
    )


def dominant_gemm(path) -> tuple[int, int, int]:
    """The (M, K, N) of a candidate path's highest-MAC GEMM."""
    g = max(path.gemms, key=lambda g: g.macs)
    return (int(g.M), int(g.K), int(g.N))


def network_signature(tn: TensorNetwork, steps) -> str:
    """A stable, human-greppable identity for a streaming-layer problem.

    Encodes every node's edges/dims/kind plus the contraction order —
    two layers with the same signature contract identically, so they
    share one cache entry (the same dedup the cost-table engine applies
    to repeated transformer blocks).
    """
    nodes = ";".join(
        f"{n.name}[{','.join(n.edges)}|{','.join(map(str, n.dims))}|{n.kind}]"
        for n in tn.nodes
    )
    order = ",".join(f"{i}-{j}" for i, j in steps)
    return f"{nodes}@{order}"


def dominant_gemm_of_steps(tn: TensorNetwork, steps) -> tuple[int, int, int]:
    """The dominant (M, K, N) of raw plan steps replayed on ``tn``."""
    gemms = tuple(tn.gemm_sequence(tuple(tuple(s) for s in steps)))
    g = max(gemms, key=lambda g: g.macs)
    return (int(g.M), int(g.K), int(g.N))
