"""Batched serving: prefill a prompt batch, decode with KV caches.

  PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-1.2b --smoke]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tt-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family in ("vlm", "encdec"):
        n = cfg.n_frontend_tokens or 8
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(args.batch, n, cfg.d_model)), jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {(time.time()-t0)*1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decode {args.gen - 1} steps: {dt/(args.gen-1)*1e3:.2f} ms/step "
          f"({(args.gen-1)*args.batch/dt:,.0f} tok/s at batch {args.batch})")
    print("sample:", np.concatenate(generated, 1)[0][:12].tolist())


if __name__ == "__main__":
    main()
