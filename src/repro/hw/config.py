"""The hardware-architecture description shared by every cost model.

:class:`HardwareConfig` is the single parameterization of the closed-form
systolic cost model (``repro.core.simulator.gemm_cost_model``): the
paper's FPGA target, the TPU-v5e reading, and every candidate in the
searched architecture space (``repro.hw.space``) are all instances of
this one dataclass.  It lives here — below ``repro.core`` — so the
simulator, the cost-table engine, the plan schema (which embeds the
winning architecture since format v3) and the architecture-space
generator can all share it without import cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """Systolic target description.  Defaults = the paper's FPGA setup."""

    name: str = "fpga_vu9p"
    pe_rows: int = 32
    pe_cols: int = 32
    freq_hz: float = 200e6
    sram_input_bytes: int = 3072 * 1024   # inputs + filters (paper 5.1)
    sram_output_bytes: int = 1024 * 1024
    dram_words_per_cycle: float = 256.0   # paper: "bandwidth of 256"
    bytes_per_word: int = 1               # INT8
    gemm_overhead_cycles: int = 64        # per-GEMM reconfig/drain constant

    @property
    def macs_per_cycle(self) -> int:
        return self.pe_rows * self.pe_cols

    @property
    def peak_macs_per_s(self) -> float:
        return self.macs_per_cycle * self.freq_hz

    @property
    def sram_total_bytes(self) -> int:
        return self.sram_input_bytes + self.sram_output_bytes

    # -- JSON embedding (plan schema v3) ----------------------------------
    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping) -> "HardwareConfig":
        return cls(
            name=str(d["name"]),
            pe_rows=int(d["pe_rows"]),
            pe_cols=int(d["pe_cols"]),
            freq_hz=float(d["freq_hz"]),
            sram_input_bytes=int(d["sram_input_bytes"]),
            sram_output_bytes=int(d["sram_output_bytes"]),
            dram_words_per_cycle=float(d["dram_words_per_cycle"]),
            bytes_per_word=int(d["bytes_per_word"]),
            gemm_overhead_cycles=int(d["gemm_overhead_cycles"]),
        )
