"""Fig. 5 — latency across top-K paths x dataflows x core partitionings.

Shows the full (P x C x D) latency surface for one tensorized layer:
even for a fixed contraction path, IS/OS/WS and 1x1 / 1x2 / 2x1 core
splits change latency substantially — the coupling the joint DSE exploits.
"""

from __future__ import annotations

from repro.core import (
    ALL_DATAFLOWS,
    ALL_PARTITIONINGS,
    FPGA_VU9P,
    find_topk_paths,
    layer_latency,
)
from repro.models.vision import vit_ti4_layers
from .common import emit


def run() -> list[dict]:
    layer = vit_ti4_layers(batch=64)[2]  # b0.fc1: 192 -> 768
    paths = find_topk_paths(layer.tt_network, k=4)
    rows = []
    for pi, path in enumerate(paths):
        for part in ALL_PARTITIONINGS:
            for df in ALL_DATAFLOWS:
                rep = layer_latency(path, df, part, FPGA_VU9P)
                rows.append({
                    "path": f"path-{pi + 1}",
                    "macs": path.macs,
                    "partitioning": f"{part[0]}x{part[1]}",
                    "dataflow": df.value,
                    "latency_us": rep.seconds * 1e6,
                    "utilization": rep.utilization,
                    "parallel_stages": rep.n_parallel_stages,
                })
    emit("fig5_dataflow", rows)
    return rows


if __name__ == "__main__":
    run()
