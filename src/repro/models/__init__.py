"""Model zoo + family-dispatched API.

``api(cfg)`` returns the family's (init_params, train_loss, prefill,
decode_step, init_caches) callables with a uniform signature, and
``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins the
multi-pod dry-run lowers against (no device allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import encdec as _encdec
from . import lm as _lm
from .config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ENC_LEN_CAP = 4096   # encoder frame length for enc-dec decode shapes


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_caches: Callable
    #: full-logits prefill — (params, batch, max_seq) -> ((B, S, V), caches).
    #: The serve scheduler slices the last *real* token of a bucket-padded
    #: prompt from it.  ``None`` for families without one (encdec).
    prefill_full: Optional[Callable] = None


_PLAN_UNSET = object()  # sentinel: "plan argument not given"


def api(cfg: ModelConfig, plan=_PLAN_UNSET, *,
        plan_backend: Optional[str] = None) -> ModelAPI:
    """Family-dispatched model API.

    ``plan`` (an :class:`repro.plan.ExecutionPlan`, a plan-file path, or a
    legacy ``{name: path_index}`` dict) is installed into the TT linear
    layers before any callable is traced, so every projection contracts
    along its planned path / kernel backend.  ``plan_backend`` forces one
    executor for all layers (the train driver passes ``"jnp"`` — autodiff
    never crosses a ``pallas_call``).

    Plan state is global and *explicit*: omitting ``plan`` leaves
    whatever is installed untouched (so the step builders' internal
    ``api(cfg)`` dispatch never un-installs a driver's plan), while
    passing ``plan=None`` clears it — use that when building an unplanned
    baseline after a planned model in the same process.
    """
    if plan_backend is not None:
        from repro.plan.schema import BACKENDS

        if plan_backend not in BACKENDS:
            raise ValueError(
                f"unknown plan_backend {plan_backend!r}; have {BACKENDS}")
    if plan is not _PLAN_UNSET or plan_backend is not None:
        from repro.nn import install_plan

        if plan is _PLAN_UNSET or plan is None:
            if plan_backend is not None:
                raise ValueError(
                    "plan_backend given without a plan to apply it to")
            plan = None
        if isinstance(plan, str):
            from repro.plan import load_plan

            plan = load_plan(plan)
        install_plan(plan, force_backend=plan_backend)
    if cfg.family == "encdec":
        return ModelAPI(
            init_params=lambda rng: _encdec.init_params(rng, cfg),
            train_loss=lambda p, b: _encdec.train_loss(cfg, p, b),
            prefill=lambda p, b, max_seq: _encdec.prefill(cfg, p, b, max_seq),
            decode_step=lambda p, t, c, pos: _encdec.decode_step(cfg, p, t, c, pos),
            init_caches=lambda batch, max_seq: _encdec.init_caches(
                cfg, batch, max_seq, min(ENC_LEN_CAP, max_seq), jnp.dtype(cfg.dtype)),
        )
    return ModelAPI(
        init_params=lambda rng: _lm.init_params(rng, cfg),
        train_loss=lambda p, b: _lm.train_loss(cfg, p, b),
        prefill=lambda p, b, max_seq: _lm.prefill(cfg, p, b, max_seq),
        decode_step=lambda p, t, c, pos: _lm.decode_step(cfg, p, t, c, pos),
        init_caches=lambda batch, max_seq: _lm.init_caches(
            cfg, batch, max_seq, jnp.dtype(cfg.dtype)),
        prefill_full=lambda p, b, max_seq: _lm.prefill_full(cfg, p, b, max_seq),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step.

    train:   {tokens, labels[, frontend]}
    prefill: {tokens[, frontend]}
    decode:  {token, cache_pos, caches}
    """
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((gb, s), i32)

    def frontend_spec(seq: int):
        if cfg.family == "vlm":
            n = cfg.n_frontend_tokens or 256
            return jax.ShapeDtypeStruct((gb, n, cfg.d_model), dt)
        if cfg.family == "encdec":
            n = min(ENC_LEN_CAP, seq)
            return jax.ShapeDtypeStruct((gb, n, cfg.d_model), dt)
        return None

    if shape.step == "train":
        batch = {"tokens": tok, "labels": jax.ShapeDtypeStruct((gb, s), i32)}
        fe = frontend_spec(s)
        if fe is not None:
            batch["frontend"] = fe
        return batch
    if shape.step == "prefill":
        batch = {"tokens": tok}
        fe = frontend_spec(s)
        if fe is not None:
            batch["frontend"] = fe
        return batch
    if shape.step == "decode":
        max_seq = s + (cfg.n_frontend_tokens or 256 if cfg.family == "vlm" else 0)
        caches = jax.eval_shape(lambda: api(cfg).init_caches(gb, max_seq))
        return {
            "token": jax.ShapeDtypeStruct((gb, 1), i32),
            "cache_pos": jax.ShapeDtypeStruct((), i32),
            "caches": caches,
        }
    raise ValueError(shape.step)


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "ModelAPI", "api", "input_specs", "ENC_LEN_CAP",
]
