"""Serving scheduler suite: continuous-batching equivalence, phase-plan
switching, and scheduler robustness.

The load-bearing property: per-request token ids under continuous
batching are bit-identical to one-shot serving of each request alone.
This is *structural* — both modes prefill at batch 1 and decode at the
same fixed slot width (one-shot = concurrency 1 on the same engine), so
no cross-batch-size GEMM comparison is involved (XLA GEMMs are not
batch-size invariant).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import api
from repro.plan import execution_log, reset_execution_log
from repro.plan.compiler import check_plan_for_config
from repro.serve import (
    Request,
    Scheduler,
    ServeEngine,
    ServePolicy,
    load_trace,
    save_trace,
    synthetic_trace,
)

ARCH = "tt-lm-100m"
N_SLOTS = 2
MAX_SEQ = 16
BUCKET = 4

_CACHE: dict = {}


def _model():
    if "params" not in _CACHE:
        cfg = get_config(ARCH, smoke=True)
        _CACHE["cfg"] = cfg
        _CACHE["params"] = api(cfg).init_params(jax.random.PRNGKey(0))
    return _CACHE["cfg"], _CACHE["params"]


def _engine(**kw) -> ServeEngine:
    """The shared plain engine (jit caches reused across tests)."""
    if kw:
        cfg, params = _model()
        return ServeEngine(cfg, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           prompt_bucket=BUCKET, **kw)
    if "engine" not in _CACHE:
        cfg, params = _model()
        _CACHE["engine"] = ServeEngine(cfg, params, n_slots=N_SLOTS,
                                       max_seq=MAX_SEQ, prompt_bucket=BUCKET)
    return _CACHE["engine"]


def _requests(raw: list[int]) -> list[Request]:
    """Decode a flat integer draw into requests (p 1..6, gen 1..4,
    arrival 0..6; prompt ids deterministic per request index)."""
    reqs = []
    for i in range(len(raw) // 3):
        p = 1 + raw[3 * i] % 6
        g = 1 + raw[3 * i + 1] % 4
        arrival = float(raw[3 * i + 2] % 7)
        rng = np.random.default_rng((0xC0FFEE, i))
        cfg, _ = _model()
        prompt = tuple(int(t) for t in rng.integers(0, cfg.vocab, size=p))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=g,
                            arrival=arrival))
    return reqs


def _run(schedule: str, reqs, *, temperature=0.0, seed=0, engine=None,
         policy_kw=None):
    eng = engine if engine is not None else _engine()
    policy = ServePolicy(schedule=schedule, **(policy_kw or {}))
    return Scheduler(eng, policy, temperature=temperature, seed=seed).run(reqs)


# ---------------------------------------------------------------------------
# property: continuous batching == one-shot, bit-identical per request
# ---------------------------------------------------------------------------

@given(raw=st.lists(st.integers(0, 10**9), min_size=3, max_size=12))
@settings(max_examples=8, deadline=None)
def test_continuous_matches_oneshot_bitexact(raw):
    reqs = _requests(raw)
    if not reqs:
        return
    cont = _run("continuous", reqs)
    solo = _run("oneshot", reqs)
    assert cont.tokens_by_rid() == solo.tokens_by_rid()


def test_sampled_continuous_matches_oneshot():
    """Per-(seed, rid) Gumbel-max sampling is lane-independent too."""
    reqs = _requests([5, 2, 0, 1, 3, 1, 4, 1, 2, 2, 0, 4])
    cont = _run("continuous", reqs, temperature=0.7, seed=11)
    solo = _run("oneshot", reqs, temperature=0.7, seed=11)
    assert cont.tokens_by_rid() == solo.tokens_by_rid()
    # a different seed genuinely resamples
    other = _run("continuous", reqs, temperature=0.7, seed=12)
    assert other.tokens_by_rid() != cont.tokens_by_rid()


# ---------------------------------------------------------------------------
# phase-switch coverage: plan pair drives each stream
# ---------------------------------------------------------------------------

def _plan_pair():
    if "pair" not in _CACHE:
        from repro.dse_cli import run_dse_plan

        _, plan_p = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=64,
                                 plan_backend="jnp", phase="prefill")
        _, plan_d = run_dse_plan(ARCH, smoke=True, top_k=2, tokens=8,
                                 plan_backend="jnp", phase="decode")
        _CACHE["pair"] = (plan_p, plan_d)
    return _CACHE["pair"]


def test_phase_switch_runs_each_stream_under_its_plan():
    plan_p, plan_d = _plan_pair()
    assert plan_p.phase == "prefill" and plan_d.phase == "decode"
    tilings_p = {lp.name: lp.tiling.to_json() for lp in plan_p.layers}
    tilings_d = {lp.name: lp.tiling.to_json() for lp in plan_d.layers}
    # the pair is genuinely specialized: decode tilings differ (fewer
    # streamed tokens per step than a 64-token prefill)
    assert tilings_p != tilings_d

    eng = _engine(prefill_plan=plan_p, decode_plan=plan_d, arch=ARCH)
    reqs = _requests([0, 2, 0, 2, 2, 0])  # 2 requests, gen 3 each
    reset_execution_log()
    res = _run("continuous", reqs, engine=eng)
    assert len(res.completions) == 2
    log = execution_log()
    by_stream = {"prefill": [], "decode": []}
    for rec in log:
        assert rec["stream"] in by_stream, rec
        by_stream[rec["stream"]].append(rec)
    assert by_stream["prefill"] and by_stream["decode"]
    for rec in by_stream["prefill"]:
        assert rec["backend"] == "jnp"
        assert rec["tiling"] == tilings_p[rec["name"]]
    for rec in by_stream["decode"]:
        assert rec["backend"] == "jnp"
        assert rec["tiling"] == tilings_d[rec["name"]]


def test_swapped_pair_rejected_before_any_step():
    plan_p, plan_d = _plan_pair()
    problems = check_plan_for_config(plan_d, ARCH, _model()[0],
                                     phase="prefill")
    assert any("swapped" in p for p in problems)
    with pytest.raises(ValueError, match="prefill half"):
        _engine(prefill_plan=plan_d, decode_plan=plan_p, arch=ARCH)


def test_foreign_arch_plan_rejected():
    plan_p, _ = _plan_pair()
    foreign = dataclasses.replace(plan_p, arch="glm4-9b")
    assert check_plan_for_config(foreign, ARCH, _model()[0],
                                 phase="prefill")
    with pytest.raises(ValueError):
        _engine(prefill_plan=foreign, arch=ARCH)


# ---------------------------------------------------------------------------
# robustness: starvation, full queue, edge cases, replay
# ---------------------------------------------------------------------------

def _prompt(i, p=4):
    cfg, _ = _model()
    rng = np.random.default_rng((7, i))
    return tuple(int(t) for t in rng.integers(0, cfg.vocab, size=p))


def test_long_request_does_not_starve_later_short_one():
    reqs = [
        Request(rid=0, prompt=_prompt(0), max_new_tokens=6, arrival=0.0),
        Request(rid=1, prompt=_prompt(1), max_new_tokens=6, arrival=0.0),
        Request(rid=2, prompt=_prompt(2), max_new_tokens=1, arrival=0.0),
    ]
    res = _run("continuous", reqs)
    by = {c.rid: c for c in res.completions}
    assert len(by) == 3
    # FIFO bound: the short request is admitted the moment a lane frees
    first_free = min(by[0].done_step, by[1].done_step)
    assert by[2].admitted_step == first_free
    assert by[2].done_step <= max(by[0].done_step, by[1].done_step)


def test_full_queue_burst_admission():
    reqs = [Request(rid=i, prompt=_prompt(i), max_new_tokens=2, arrival=0.0)
            for i in range(5)]
    res = _run("continuous", reqs)
    assert sorted(c.rid for c in res.completions) == list(range(5))
    assert all(len(c.tokens) == 2 for c in res.completions)
    # 5 requests over 2 lanes, 1 decode step each -> 3 admission waves
    assert res.steps == 3
    assert res.occupancy > 0.5


def test_admission_cap_bounds_prefills_per_step():
    reqs = [Request(rid=i, prompt=_prompt(i), max_new_tokens=2, arrival=0.0)
            for i in range(4)]
    res = _run("continuous", reqs,
               policy_kw={"max_admissions_per_step": 1})
    by = {c.rid: c for c in res.completions}
    assert len(by) == 4
    # one prefill per tick: admission steps are strictly increasing
    steps = [by[i].admitted_step for i in range(4)]
    assert steps == sorted(steps) and len(set(steps)) == 4


def test_zero_requests():
    res = _run("continuous", [])
    assert res.completions == () and res.steps == 0
    assert res.occupancy == 0.0


def test_single_token_gen_completes_at_admission():
    reqs = [Request(rid=0, prompt=_prompt(0), max_new_tokens=1, arrival=0.0)]
    res = _run("continuous", reqs)
    (c,) = res.completions
    assert len(c.tokens) == 1
    assert c.done_step == c.admitted_step
    assert res.occupancy == 0.0  # never occupied a decode lane


def test_deterministic_trace_replay():
    reqs = _requests([9, 9, 9, 3, 1, 4, 1, 5, 2])
    a = _run("continuous", reqs)
    b = _run("continuous", reqs)
    assert [c.replay_key for c in a.completions] == \
        [c.replay_key for c in b.completions]
    assert a.steps == b.steps


def test_trace_roundtrip(tmp_path):
    cfg, _ = _model()
    reqs = synthetic_trace(3, cfg.vocab, prompt_len=(1, 6), gen=(1, 3),
                           arrival_rate=1.0, seed=5)
    path = str(tmp_path / "trace.json")
    save_trace(path, reqs)
    loaded = load_trace(path, cfg.vocab)
    assert [(r.prompt, r.max_new_tokens, r.arrival) for r in loaded] == \
        [(r.prompt, r.max_new_tokens, r.arrival) for r in reqs]


def test_validation_errors():
    with pytest.raises(ValueError, match="duplicate"):
        _run("continuous", [
            Request(rid=0, prompt=_prompt(0), max_new_tokens=1),
            Request(rid=0, prompt=_prompt(1), max_new_tokens=1),
        ])
    with pytest.raises(ValueError, match="max_seq"):
        _run("continuous", [Request(rid=0, prompt=_prompt(0, p=10),
                                    max_new_tokens=MAX_SEQ)])
    with pytest.raises(ValueError, match="unknown schedule"):
        ServePolicy(schedule="batch")
    with pytest.raises(ValueError, match="empty prompt"):
        Request(rid=0, prompt=(), max_new_tokens=1)


def test_arrival_gating_idles_until_next_request():
    reqs = [Request(rid=0, prompt=_prompt(0), max_new_tokens=2,
                    arrival=5.0)]
    res = _run("continuous", reqs)
    (c,) = res.completions
    assert c.admitted_step >= 5
