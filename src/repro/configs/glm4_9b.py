"""glm4-9b [dense] — RoPE, GQA kv=2, large vocab.

Assigned dims: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
[hf:THUDM/glm-4-9b; hf].
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151_552,
    head_dim=128,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="glm4-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
