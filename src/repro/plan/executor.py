"""Planned execution: route a TT contraction through its LayerPlan backend.

Entry point is :func:`planned_tt_linear` — called by
``repro.nn.linear.linear_apply`` when a plan entry is installed for the
projection.  Three backends:

- ``jnp``        — the pure-jnp reference executor (``kernels/ref.py``)
                   along the plan's path steps: numerical ground truth.
- ``streaming_tt`` — the fused in-VMEM Pallas kernel: cores pinned whole
                   in VMEM, activations streamed in ``block_tokens``
                   blocks, the entire searched path unrolled inside the
                   kernel body (``kernels/streaming_tt.py``).
- ``tt_gemm``    — every pairwise contraction of the path lowered to the
                   dataflow-configurable Pallas GEMM
                   (``kernels/tt_gemm.py``) with the plan's IS/OS/WS grid
                   order and <T_M, T_K, T_N> block shapes.  Any pairwise
                   tensor contraction *is* a GEMM (free-edges x
                   shared-edges reshape), which is the paper's §3.1 view.

**Training.**  A ``pallas_call`` has no autodiff rule, so the Pallas
backends are wrapped in a ``jax.custom_vjp`` whose backward pass
contracts the layer's *gradient networks* (``repro.core.backward``) —
dL/dx and one dL/dG_k per core — along the plan's searched backward
paths (schema v2 ``backward`` entries; inference-only plans fall back to
the MAC-optimal backward path per gradient).  Each backward contraction
is itself routed through a planned kernel: dx may stream through the
same Pallas pipeline as the forward, weight gradients lower to the
Pallas GEMM.  ``launch/train.py --plan`` therefore runs Pallas
end-to-end under ``jax.grad``.

Every planned call appends a record to a trace-time execution log —
``execution_log()`` — so callers (tests, the serve/train drivers) can
assert *which* path/dataflow/kernel actually executed, in which autodiff
``phase`` (``"fwd"`` at forward trace, ``"bwd"`` inside the VJP).  Under
``jit`` the record is appended once per trace, not per step.
"""

from __future__ import annotations

import contextlib
import functools
import math
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import fusion
from repro.core.backward import backward_networks, grad_input_network
from repro.core.contraction import core_tensors, execute_path
from repro.core.paths import CandidatePath, find_topk_paths
from repro.core.tensor_network import TensorNetwork, tt_linear_network
from repro.kernels import ops, ref

from .schema import BackwardOp, LayerPlan

# ---------------------------------------------------------------------------
# trace-time execution log
# ---------------------------------------------------------------------------

#: ring capacity: long-running serving loops retrace at many token
#: counts; the log keeps the most recent records, and the drop counter
#: (``execution_log_dropped``) lets summary consumers state when their
#: window is partial instead of silently under-counting
_EXEC_LOG_MAX = 4096

_EXEC_LOG: list[dict] = []
_EXEC_DROPPED = 0

#: serving-stream tag stack (``execution_stream``): records appended
#: inside the context carry ``stream`` = the innermost tag, so the serve
#: scheduler's per-phase plan switching is assertable from the log
_STREAM: list[str] = []

#: shard-execution stack (``shard_execution``): records traced inside a
#: shard_map body carry the mesh layout and the per-shard problem shape,
#: so "the kernel ran at per-shard shapes on this mesh" is assertable
_SHARD: list[tuple[str, tuple[int, ...]]] = []


def reset_execution_log() -> None:
    global _EXEC_DROPPED
    _EXEC_LOG.clear()
    _EXEC_DROPPED = 0


def execution_log() -> tuple[dict, ...]:
    """Records of planned executions since the last reset (trace-time).

    At most the newest :data:`_EXEC_LOG_MAX` records are retained;
    :func:`execution_log_dropped` counts the ones that aged out.
    """
    return tuple(_EXEC_LOG)


def execution_log_dropped() -> int:
    """Records evicted from the ring since the last reset."""
    return _EXEC_DROPPED


@contextlib.contextmanager
def execution_stream(name: str) -> Iterator[None]:
    """Tag every execution record traced inside with ``stream=name``.

    The serve engine wraps each prefill/decode call in
    ``execution_stream("prefill"/"decode")`` so the log distinguishes
    which *serving stream* a contraction was traced under — orthogonal
    to the autodiff ``phase`` (fwd/bwd) the record already carries.
    Under ``jit`` a record appears once per trace, so the tag marks the
    stream that *first* traced the shape.
    """
    _STREAM.append(str(name))
    try:
        yield
    finally:
        _STREAM.pop()


@contextlib.contextmanager
def shard_execution(mesh: str, shard_shape: tuple[int, ...]) -> Iterator[None]:
    """Tag records traced inside with their shard_map placement.

    ``mesh`` is a human-readable axis layout (``"data=4"`` or
    ``"data=4+reduce(model=2)"``); ``shard_shape`` is the per-device
    ``(tokens, d_in)`` the kernel actually sees.  The sharded dispatcher
    (:mod:`repro.plan.sharded`) wraps its shard_map call in this context
    — the body traces within, so per-shard kernel records pick up the
    fields.  Single-device records carry ``mesh=""``/``shard_shape=None``.
    """
    _SHARD.append((str(mesh), tuple(int(d) for d in shard_shape)))
    try:
        yield
    finally:
        _SHARD.pop()


def record_execution(
    lp: LayerPlan,
    tokens: int,
    *,
    phase: str = "fwd",
    backend: Optional[str] = None,
    wrt: Optional[str] = None,
    path_steps=None,
    tiling=None,
    segment: Optional[tuple[int, int]] = None,
) -> None:
    """Append one planned-execution record (called at trace time).

    ``tiling`` defaults to the layer plan's forward tiling; backward
    records pass the per-gradient op's.  Logging the blocks makes
    "the kernel tilings follow the plan's (searched) architecture" an
    assertable property, not an inference — the serve driver and
    ``tests/test_hw.py`` both read it.  ``segment`` marks per-segment
    provenance records of a fusion-segmented layer (the step range the
    record covers); the layer-level record carries no segment.
    """
    global _EXEC_DROPPED
    rec = {
        "name": lp.name,
        "backend": backend if backend is not None else lp.backend,
        "dataflow": lp.dataflow,
        "path_index": lp.path_index,
        "path_steps": lp.path_steps if path_steps is None else path_steps,
        "tokens": tokens,
        "phase": phase,
        "stream": _STREAM[-1] if _STREAM else "",
        "mesh": _SHARD[-1][0] if _SHARD else "",
        "shard_shape": list(_SHARD[-1][1]) if _SHARD else None,
        "tiling": (lp.tiling if tiling is None else tiling).to_json(),
    }
    if wrt is not None:
        rec["wrt"] = wrt
    if segment is not None:
        rec["segment"] = [int(segment[0]), int(segment[1])]
    if len(_EXEC_LOG) >= _EXEC_LOG_MAX:
        del _EXEC_LOG[0]
        _EXEC_DROPPED += 1
    _EXEC_LOG.append(rec)


# ---------------------------------------------------------------------------
# path plumbing
# ---------------------------------------------------------------------------

def has_pallas_backward(lp: LayerPlan) -> bool:
    """Whether any of the plan's backward ops names a Pallas backend.

    The auto-compiler can pair a jnp *forward* (small forward GEMMs)
    with Pallas *backward* ops (the weight-gradient GEMMs reduce over
    the whole batch, so they clear ``MIN_KERNEL_MACS`` when the forward
    does not) — such layers still need the custom-VJP route.
    """
    return any(op.backend != "jnp" for op in lp.backward)


def as_candidate_path(tn: TensorNetwork, steps) -> CandidatePath:
    """Reconstruct a CandidatePath (with GEMM shapes) from raw plan steps."""
    steps = tuple(tuple(s) for s in steps)
    gemms = tuple(tn.gemm_sequence(steps))
    return CandidatePath(steps, sum(g.macs for g in gemms), gemms)


def _gemm_contract(lp: LayerPlan, tiling, interpret: Optional[bool]):
    """Pallas-GEMM ``contract_fn`` with the plan's dataflow and blocks."""
    return ops.gemm_contract(
        dataflow=lp.dataflow,
        block_m=tiling.block_m,
        block_k=tiling.block_k,
        block_n=tiling.block_n,
        interpret=interpret,
    )


def _bwd_token_bucket(tokens: int) -> int:
    """Pow2 bucket for the backward-path cache key.

    A serving/decode loop retraces at many distinct token counts; keying
    the derivation cache on the raw count would re-run the path search
    (and grow the cache) once per count.  The MAC-optimal backward
    contraction *order* is stable within a pow2 bucket (asserted by
    ``tests/test_fused_exec.py``), so the bucket is the cache key — the
    returned steps are pure index pairs, valid at any batch size.
    """
    p = 1
    while p < max(1, tokens):
        p *= 2
    return p


@functools.lru_cache(maxsize=256)
def _default_bwd_steps(
    batch: int,
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
) -> tuple[tuple[str, tuple[tuple[int, int], ...]], ...]:
    """MAC-optimal backward path per gradient (fallback for v1 plans).

    ``batch`` should be a :func:`_bwd_token_bucket` value — callers
    bucket before the lookup so the cache stays small under decode-style
    token-count churn (the cap is a backstop, not a working set).
    """
    tn = tt_linear_network(batch, in_modes, out_modes, ranks)
    return tuple(
        (wrt, find_topk_paths(net, k=1)[0].steps)
        for wrt, net in backward_networks(tn)
    )


def _resolve_backward_ops(
    lp: LayerPlan,
    tokens: int,
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
) -> tuple[BackwardOp, ...]:
    """The plan's backward ops, or derived defaults for inference plans.

    Defaults: MAC-optimal path per gradient; dx inherits the forward
    backend (it is the same kind of streaming contraction), weight
    gradients lower to the Pallas GEMM (two streamed operands cannot use
    the streaming kernel), everything stays jnp under a jnp forward.
    A partial ``backward`` list (hand-edited plan installed without the
    driver-side ``check_plan_for_config`` guard) keeps its entries and
    fills the missing gradients with the same defaults.
    """
    planned = {op.wrt: op for op in lp.backward}
    if lp.backend == "jnp":
        dx_backend = grad_backend = "jnp"
    else:
        dx_backend = lp.backend
        grad_backend = "tt_gemm"
    return tuple(
        planned.get(wrt) or BackwardOp(
            wrt=wrt,
            path_index=0,
            path_steps=steps,
            backend=dx_backend if wrt == "dx" else grad_backend,
            tiling=lp.tiling,
        )
        for wrt, steps in _default_bwd_steps(
            _bwd_token_bucket(tokens), in_modes, out_modes, ranks)
    )


# ---------------------------------------------------------------------------
# fusion-segmented execution (tt_gemm backend with LayerPlan.segments)
# ---------------------------------------------------------------------------

def _execute_segmented(
    lp: LayerPlan,
    tn: TensorNetwork,
    tensors: dict,
    out_edges: tuple[str, ...],
    tokens: int,
    interpret: Optional[bool],
) -> jax.Array:
    """Walk the plan's fusion segments over the live work list.

    Multi-step segments execute as ONE ``pallas_call`` with fp32
    VMEM-resident intermediates (``kernels/fused_path.py``); singleton
    segments keep the per-step dataflow-configurable GEMM route, so the
    result is bit-identical to the unsegmented execution (the fused
    kernel replicates the per-step k-block accumulation order).  Each
    segment appends its own provenance record — ``segment=(s, e)`` — in
    addition to the layer-level record ``planned_tt_linear`` wrote.
    """
    steps = tuple(tuple(s) for s in lp.path_steps)
    contract = _gemm_contract(lp, lp.tiling, interpret)
    work: list = [(n.edges, tensors[n.name]) for n in tn.nodes]
    bt = ops.clamp_block(lp.tiling.block_tokens, tokens)
    for (s, e) in lp.segments:
        record_execution(lp, tokens, path_steps=steps[s:e], segment=(s, e))
        if e - s >= 2:
            ec, val = ops.fused_segment(
                work, steps[s:e], block_tokens=bt,
                block_m=lp.tiling.block_m, block_k=lp.tiling.block_k,
                block_n=lp.tiling.block_n, interpret=interpret)
            # replay the per-step removals; interior placeholders are all
            # consumed by the chain, only the final result survives
            for (i, j) in steps[s:e]:
                work = [w for k, w in enumerate(work) if k not in (i, j)]
                work.append(None)
            work[-1] = (ec, val)
        else:
            i, j = steps[s]
            (ea, ta), (eb, tb) = work[i], work[j]
            shared = [x for x in ea if x in eb]
            ax_a = tuple(ea.index(x) for x in shared)
            ax_b = tuple(eb.index(x) for x in shared)
            val = contract(ta, tb, (ax_a, ax_b))
            ec = tuple(x for x in ea if x not in shared) + tuple(
                x for x in eb if x not in shared)
            work = [w for k, w in enumerate(work) if k not in (i, j)]
            work.append((ec, val))
    ec, val = work[-1]
    if tuple(ec) != tuple(out_edges):
        val = jnp.transpose(val, tuple(ec.index(x) for x in out_edges))
    return val


# ---------------------------------------------------------------------------
# forward bodies (shared by the inference path and the custom-VJP wrapper)
# ---------------------------------------------------------------------------

def _forward_planned(
    lp: LayerPlan,
    x2d: jax.Array,
    cores: Sequence[jax.Array],
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    interpret: Optional[bool],
) -> jax.Array:
    """The plan's forward contraction: ``(tokens, d_in) -> (tokens, d_out)``."""
    tokens = x2d.shape[0]
    if lp.backend == "streaming_tt":
        bt = ops.clamp_block(lp.tiling.block_tokens, tokens)
        tn_block = tt_linear_network(bt, in_modes, out_modes, ranks)
        path = as_candidate_path(tn_block, lp.path_steps)
        return ops.tt_linear(x2d, list(cores), tn_block, path,
                             block_tokens=bt, interpret=interpret)

    tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
    if lp.backend == "tt_gemm":
        tensors = {"X": x2d.reshape((tokens,) + tuple(in_modes))}
        tensors.update(core_tensors(tn, list(cores)))
        out_edges = ("b",) + tuple(f"i{t + 1}" for t in range(len(out_modes)))
        if fusion.has_fused(lp.segments):
            y = _execute_segmented(lp, tn, tensors, out_edges, tokens,
                                   interpret)
        else:
            y = execute_path(
                tn, lp.path_steps, tensors, out_edges=out_edges,
                contract_fn=_gemm_contract(lp, lp.tiling, interpret))
        return y.reshape(tokens, -1)

    # "jnp": the reference executor along the planned steps
    path = as_candidate_path(tn, lp.path_steps)
    return ref.tt_linear_ref(x2d, list(cores), tn, path)


def _backward_planned(
    lp: LayerPlan,
    x2d: jax.Array,
    cores: Sequence[jax.Array],
    dy2d: jax.Array,
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    interpret: Optional[bool],
):
    """Contract the layer's gradient networks along the planned backward
    paths, each through its planned backend.  Returns ``(dx2d, dcores)``.
    """
    tokens = x2d.shape[0]
    tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
    core_names = [n.name for n in tn.nodes if n.name != "X"]
    named = dict(zip(core_names, cores))
    node_edges = {n.name: n.edges for n in tn.nodes}
    bwd_ops = {op.wrt: op
               for op in _resolve_backward_ops(lp, tokens, in_modes,
                                               out_modes, ranks)}
    dy = dy2d.astype(x2d.dtype)

    dx2d = None
    dcores: dict[str, jax.Array] = {}
    for wrt, net in backward_networks(tn):
        op = bwd_ops[wrt]
        record_execution(lp, tokens, phase="bwd", backend=op.backend,
                         wrt=wrt, path_steps=op.path_steps,
                         tiling=op.tiling)
        if wrt == "dx" and op.backend == "streaming_tt":
            bt = ops.clamp_block(op.tiling.block_tokens, tokens)
            net_block = grad_input_network(
                tt_linear_network(bt, in_modes, out_modes, ranks))
            path = as_candidate_path(net_block, op.path_steps)
            dx2d = ops.tt_linear(dy, list(cores), net_block, path,
                                 block_tokens=bt, interpret=interpret)
            continue
        tensors = {n.name: named[n.name] for n in net.nodes
                   if n.name in named}
        if wrt != "dx":
            tensors["X"] = x2d.reshape((tokens,) + tuple(in_modes))
        tensors["dY"] = dy.reshape((tokens,) + tuple(out_modes))
        contract_fn = (_gemm_contract(lp, op.tiling, interpret)
                       if op.backend == "tt_gemm" else None)
        out_edges = node_edges["X"] if wrt == "dx" else node_edges[wrt]
        g = execute_path(net, op.path_steps, tensors, out_edges=out_edges,
                         preferred_dtype=jnp.float32,
                         contract_fn=contract_fn)
        if wrt == "dx":
            dx2d = g.reshape(tokens, -1)
        else:
            dcores[wrt] = g.astype(named[wrt].dtype)
    assert dx2d is not None
    return dx2d.astype(x2d.dtype), tuple(
        dcores[name] for name in core_names)


# ---------------------------------------------------------------------------
# the planned TT-linear entry point
# ---------------------------------------------------------------------------

def planned_tt_linear(
    lp: LayerPlan,
    x2d: jax.Array,
    cores: Sequence[jax.Array],
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Apply one planned TT projection to ``x2d: (tokens, d_in)``.

    Returns ``(tokens, d_out)``.  The plan's ``path_steps`` are replayed
    verbatim; the backend decides *how* each step runs.  Pallas backends
    are differentiable: the custom VJP contracts the plan's backward
    networks (see module docstring).
    """
    in_modes = tuple(in_modes)
    out_modes = tuple(out_modes)
    ranks = tuple(ranks)
    tokens = x2d.shape[0]
    record_execution(lp, tokens)

    if lp.backend == "jnp" and not has_pallas_backward(lp):
        # pure-reference layer (jnp forward, no Pallas backward ops):
        # plain jnp is natively differentiable, keep native autodiff
        return _forward_planned(lp, x2d, cores, in_modes, out_modes, ranks,
                                interpret)

    @jax.custom_vjp
    def f(x2d, cores):
        return _forward_planned(lp, x2d, cores, in_modes, out_modes, ranks,
                                interpret)

    def fwd(x2d, cores):
        return f(x2d, cores), (x2d, cores)

    def bwd(res, dy2d):
        x2d, cores = res
        return _backward_planned(lp, x2d, cores, dy2d, in_modes, out_modes,
                                 ranks, interpret)

    f.defvjp(fwd, bwd)
    return f(x2d, tuple(cores))
