"""Differential-oracle contract for the guided joint search.

The exhaustive ``global_search`` (Algorithm 1 + hw co-search outer
loop) is the permanent test oracle; ``repro.search.guided_search`` must
satisfy three properties against it:

1. **Oracle parity** — with a generous budget (enough to refine every
   candidate), guided returns the *exact* exhaustive optimum on
   hypothesis-randomized small joint spaces: same cost, same chosen
   architecture (by identity), same strategy and per-layer choices,
   tie-breaks included.
2. **Determinism** — the same seed yields an identical ``DSEResult``
   (dataclass equality, so every field including provenance matches).
3. **Budget-monotonicity** — a larger budget never returns a worse
   optimum (the evaluation stream is budget-independent; budget is a
   prefix cutoff).

Plus the ROADMAP gap (c) regression: ``calibration`` now composes with
``hw_space`` — the combo runs and a skewed calibration can genuinely
flip the co-search argmin.
"""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    find_topk_paths,
    global_search,
    memoised_layer_backwards,
    tt_linear_network,
)
from repro.core.simulator import ALL_DATAFLOWS
from repro.hw import ArchSpace, FPGA_VU9P
from repro.search import (
    BudgetExhausted,
    Genome,
    JointSpace,
    guided_search,
)

# ---------------------------------------------------------------------------
# fixtures: a handful of tiny layer stacks + arch-candidate pool
# ---------------------------------------------------------------------------

_NETS = {
    "a": lambda: [
        find_topk_paths(tt_linear_network(64, (2, 8), (8, 2), (4, 4, 4)), k=3),
        find_topk_paths(tt_linear_network(4, (4, 4), (4, 4), (4, 4, 4)), k=2),
    ],
    "b": lambda: [
        find_topk_paths(tt_linear_network(16, (4, 4), (4, 4), (6, 6, 6)), k=2),
    ],
    "c": lambda: [
        find_topk_paths(tt_linear_network(32, (8, 4), (4, 8), (4, 4, 4)), k=2),
        find_topk_paths(tt_linear_network(8, (2, 4), (4, 2), (2, 2, 2)), k=3),
        find_topk_paths(tt_linear_network(64, (4, 8), (8, 4), (4, 4, 4)), k=2),
    ],
}
_LAYERS = {name: f() for name, f in _NETS.items()}
_CANDS = ArchSpace(base=FPGA_VU9P).candidates()


def _space(start: int, n: int):
    """``n`` candidates from the VU9P arch space, base always included
    (guided refines index 0 first; keep that the semantic base)."""
    picked = [_CANDS[0]]
    step = max(1, (len(_CANDS) - 1) // max(1, n))
    i = 1 + (start % step)
    while len(picked) < n and i < len(_CANDS):
        picked.append(_CANDS[i])
        i += step
    return tuple(picked)


def _assert_same_result(g, e):
    assert g.total_latency_s == e.total_latency_s
    assert g.hw is e.hw
    assert g.strategy == e.strategy
    assert g.choices == e.choices
    assert g.objective == e.objective


# ---------------------------------------------------------------------------
# 1. oracle parity on randomized small joint spaces
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    net=st.sampled_from(sorted(_LAYERS)),
    start=st.integers(min_value=0, max_value=40),
    n_arch=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_guided_generous_budget_matches_exhaustive(net, start, n_arch, seed):
    layer_paths = _LAYERS[net]
    space = _space(start, n_arch)
    exhaustive = global_search(layer_paths, space[0], hw_space=space)
    guided = guided_search(layer_paths, space[0], hw_space=space,
                           budget=exhaustive.evals, seed=seed)
    _assert_same_result(guided, exhaustive)
    assert guided.search == "guided"
    assert exhaustive.search == "exhaustive"
    # generous budget visits everything: guided charges each cell at
    # most once, so it costs exactly the exhaustive count
    assert guided.evals == exhaustive.evals
    assert len(guided.hw_candidates) == len(space)
    assert guided.found_at_eval <= guided.evals


def test_guided_fixed_target_is_algorithm_one():
    layer_paths = _LAYERS["a"]
    exhaustive = global_search(layer_paths, FPGA_VU9P)
    guided = guided_search(layer_paths, FPGA_VU9P)
    _assert_same_result(guided, exhaustive)
    assert guided.hw_candidates == ()
    assert guided.evals == exhaustive.evals == len(exhaustive.cost_table)


def test_guided_train_latency_parity():
    layer_paths = _LAYERS["a"]
    nets = [tt_linear_network(64, (2, 8), (8, 2), (4, 4, 4)),
            tt_linear_network(4, (4, 4), (4, 4), (4, 4, 4))]
    backwards = memoised_layer_backwards(nets, k=3)
    space = _space(3, 6)
    exhaustive = global_search(layer_paths, space[0], hw_space=space,
                               objective="train-latency",
                               layer_backwards=backwards)
    guided = guided_search(layer_paths, space[0], hw_space=space,
                           objective="train-latency",
                           layer_backwards=backwards,
                           budget=exhaustive.evals, seed=7)
    _assert_same_result(guided, exhaustive)


# ---------------------------------------------------------------------------
# 2. fixed-seed determinism
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_arch=st.integers(min_value=2, max_value=12))
def test_guided_same_seed_bit_identical(seed, n_arch):
    layer_paths = _LAYERS["b"]
    space = _space(seed, n_arch)
    runs = [guided_search(layer_paths, space[0], hw_space=space, seed=seed)
            for _ in range(2)]
    # DSEResult is a dataclass: equality covers cost, choices, table,
    # hw_candidates, and the search/evals/found_at_eval provenance
    assert runs[0] == runs[1]


def test_guided_different_seeds_still_reach_oracle_with_full_budget():
    layer_paths = _LAYERS["b"]
    space = _space(0, 8)
    exhaustive = global_search(layer_paths, space[0], hw_space=space)
    for seed in range(5):
        guided = guided_search(layer_paths, space[0], hw_space=space,
                               budget=exhaustive.evals, seed=seed)
        _assert_same_result(guided, exhaustive)


# ---------------------------------------------------------------------------
# 3. budget-monotonicity
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1_000))
def test_guided_budget_monotone(seed):
    layer_paths = _LAYERS["b"]
    space = _space(2, 10)
    from repro.core.cost_table import table_cells

    n_cells = table_cells(layer_paths)
    costs = []
    for mult in (1, 2, 3, 5, 10):
        res = guided_search(layer_paths, space[0], hw_space=space,
                            budget=mult * n_cells, seed=seed)
        assert res.evals <= mult * n_cells
        costs.append(res.total_latency_s)
    assert costs == sorted(costs, reverse=True)  # never worse as budget grows


def test_guided_budget_below_one_table_rejected():
    layer_paths = _LAYERS["b"]
    from repro.core.cost_table import table_cells

    with pytest.raises(ValueError, match="cannot refine even one"):
        guided_search(layer_paths, FPGA_VU9P,
                      budget=table_cells(layer_paths) - 1)


def test_guided_minimal_budget_equals_fixed_target():
    """One table of budget => exactly the base architecture's optimum."""
    layer_paths = _LAYERS["a"]
    space = _space(1, 8)
    from repro.core.cost_table import table_cells

    fixed = global_search(layer_paths, space[0])
    res = guided_search(layer_paths, space[0], hw_space=space,
                        budget=table_cells(layer_paths), seed=0)
    assert res.total_latency_s == fixed.total_latency_s
    assert res.hw is space[0]


# ---------------------------------------------------------------------------
# guided-search input validation
# ---------------------------------------------------------------------------

def test_guided_rejects_unsupported_objectives():
    layer_paths = _LAYERS["b"]
    with pytest.raises(ValueError, match="exhaustive path"):
        guided_search(layer_paths, FPGA_VU9P, objective="edp")
    with pytest.raises(ValueError, match="layer_backwards"):
        guided_search(layer_paths, FPGA_VU9P, objective="train-latency")


# ---------------------------------------------------------------------------
# ROADMAP gap (c): calibration x hw-search composes
# ---------------------------------------------------------------------------

def test_calibration_with_hw_space_runs_and_rescales():
    layer_paths = _LAYERS["a"]
    space = _space(0, 6)
    plain = global_search(layer_paths, space[0], hw_space=space)
    scale = {d: 2.0 for d in ALL_DATAFLOWS}
    scaled = global_search(layer_paths, space[0], hw_space=space,
                           calibration=scale)
    # uniform rescale: same winner, exactly doubled cost
    assert scaled.hw is plain.hw
    assert scaled.total_latency_s == pytest.approx(2.0 * plain.total_latency_s)
    for c_plain, c_scaled in zip(plain.hw_candidates, scaled.hw_candidates):
        assert c_scaled.total_latency_s == pytest.approx(
            2.0 * c_plain.total_latency_s)


def test_calibration_can_flip_hw_cosearch_argmin():
    """A skewed per-dataflow calibration must be able to change which
    architecture wins the co-search (the regression: this combination
    used to be rejected outright)."""
    layer_paths = _LAYERS["a"]
    space = _space(0, 10)
    plain = global_search(layer_paths, space[0], hw_space=space)
    flipped = None
    for skew in (10.0, 100.0, 1e4, 1e6):
        for d in ALL_DATAFLOWS:
            cal = {x: (skew if x == d else 1.0) for x in ALL_DATAFLOWS}
            res = global_search(layer_paths, space[0], hw_space=space,
                                calibration=cal)
            if res.hw is not plain.hw or res.choices != plain.choices:
                flipped = (d, skew, res)
                break
        if flipped:
            break
    assert flipped is not None, (
        "no per-dataflow skew changed the co-search outcome — the "
        "calibration is not reaching the per-candidate tables")


def test_guided_calibration_parity_with_exhaustive():
    layer_paths = _LAYERS["a"]
    space = _space(0, 6)
    cal = {"IS": 3.0, "OS": 0.5, "WS": 1.5}
    exhaustive = global_search(layer_paths, space[0], hw_space=space,
                               calibration=cal)
    guided = guided_search(layer_paths, space[0], hw_space=space,
                           calibration=cal, budget=exhaustive.evals, seed=1)
    _assert_same_result(guided, exhaustive)


# ---------------------------------------------------------------------------
# encoding invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_genome_operators_always_produce_valid_table_coords(seed):
    layer_paths = _LAYERS["c"]
    space_hw = _space(seed, 9)
    js = JointSpace(layer_paths, space_hw)
    rng = random.Random(seed)
    table_keys = None
    genomes = [js.random_genome(rng) for _ in range(6)]
    for _ in range(10):
        a, b = rng.sample(genomes, 2)
        genomes.append(js.mutate(js.crossover(a, b, rng), rng))
    for g in genomes:
        assert 0 <= g.arch < len(space_hw)
        assert g.strategy in js.strategy_space
        c_h = js.strategy_space[g.strategy]
        for (l, p, c, d) in g.keys():
            assert 0 <= p < len(layer_paths[l])
            assert c in c_h          # repair keeps partitioning feasible
            assert d in js.dataflows


def test_budget_exhausted_is_internal_control_flow():
    """BudgetExhausted never escapes guided_search; it is exported only
    so extensions (and this test) can name it."""
    assert issubclass(BudgetExhausted, Exception)
    layer_paths = _LAYERS["b"]
    from repro.core.cost_table import table_cells

    res = guided_search(layer_paths, _CANDS[0], hw_space=_space(0, 12),
                        budget=table_cells(layer_paths), seed=0)
    assert res.search == "guided"  # returned normally at minimal budget
