"""Unified model configuration covering all assigned architecture families.

One dataclass drives decoder-only dense/GQA, MoE, SSM-hybrid (Zamba2),
attention-free (RWKV6), encoder-decoder (Seamless) and VLM (InternVL2)
backbones.  ``family`` selects the block program; everything else is
dimensioning.  ``tt`` is the paper's technique switch: with
``tt.enabled=True`` every qualifying projection/embedding in the model is
TT-factorized and contracted along DSE-searched paths.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.nn.linear import TTConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    rope: str = "full"           # full | glm2d | none
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_shared_d_ff: Optional[int] = None
    capacity_factor: float = 1.25

    # hybrid (Zamba2): Mamba2 backbone + a shared attention block applied
    # every ``attn_every`` layers (single shared parameter set)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    attn_every: int = 0

    # encoder-decoder
    encoder_layers: int = 0

    # modality frontend (stub): precomputed embeddings via input_specs()
    frontend: str = "none"       # none | patches | frames
    n_frontend_tokens: int = 0

    # the paper's technique
    tt: TTConfig = dataclasses.field(default_factory=TTConfig)

    # execution
    dtype: str = "bfloat16"
    remat: str = "full"          # none | full | dots
    scan_layers: bool = True
    q_chunk: int = 4096          # attention query-chunk (1 chunk at 4k train)
    tie_embeddings: bool = True
    aux_loss_weight: float = 0.01
    # sequence-chunked head+CE (fused linear-cross-entropy): bounds the
    # (B, S, V) logits buffer when the vocab cannot shard on the model axis
    loss_chunk: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "rwkv", "vlm")

    @property
    def supports_long_context(self) -> bool:
        """O(1)-state decode (SSM / hybrid / linear-attention families)."""
        return self.family in ("hybrid", "rwkv")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs decode (enc-dec decodes text)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assigned grid."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.step == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason).  long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 500k decode skipped (documented)"
    return True, ""
