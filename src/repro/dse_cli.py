"""``python -m repro.dse`` — end-to-end design-space exploration CLI.

Runs the paper's Algorithm 1 for any named config in ``repro.configs``
(e.g. ``tt-lm-100m``, ``glm4-9b``) or the paper's vision workloads
(``resnet18/cifar10``, ``resnet18/tiny_imagenet``, ``vit_ti4/cifar10``):

    PYTHONPATH=src python -m repro.dse --arch tt-lm-100m
    PYTHONPATH=src python -m repro.dse --arch resnet18/cifar10 --hw tpu_v5e \
        --top-k 8 --objective edp --out report.json
    PYTHONPATH=src python -m repro.dse --arch vit_ti4/cifar10 \
        --hw-search budget --emit-plan plan.json   # joint arch co-search (v3)

Pipeline: enumerate the model's tensorized projections as per-layer
tensor networks -> MAC-guided top-K path search (memoised across the
model's repeated layers) -> batched cost-table build
(``repro.core.cost_table``) -> hierarchical global argmin.  Emits a JSON
report (schema documented in the README) with the winning strategy,
per-layer (path, partitioning, dataflow) choices and stage timings;
``examples/dse_explore.py`` and ``benchmarks/table2_dse_choices.py``
consume the same report via ``run_dse``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
import time
from typing import Optional, Sequence

from repro.configs import ARCH_IDS, get_config
from repro.core import (
    ALL_PARTITIONINGS,
    TensorNetwork,
    build_cost_tables,
    find_topk_paths,
    global_search,
)
from repro.core.cost_table import table_cells as _table_cells
from repro.core.dse import build_cost_table
from repro.hw import ArchSpace, get_target, list_targets
from repro.hw import HW_TARGETS  # noqa: F401  (re-export; registry is repro.hw)
from repro.models.config import ModelConfig
from repro.nn.linear import LinearSpec
from repro.rank import RANK_SEARCH_MODES

OBJECTIVES = ("latency", "edp", "throughput")
MODES = ("infer", "train", "both")
HW_SEARCH_MODES = ("off", "budget")
TUNE_MODES = ("off", "cache", "measure")
SEARCH_MODES = ("exhaustive", "guided")

#: dominant-GEMM shapes measured for the --tune calibration table (per
#: dataflow, at the heuristic tiling; heaviest shapes first)
TUNE_CALIBRATION_SHAPES = 8

#: vision workloads of the paper's Tables 1-4 (model_layers-backed)
VISION_ARCHS = ("resnet18/cifar10", "resnet18/tiny_imagenet", "vit_ti4/cifar10")


# ---------------------------------------------------------------------------
# config -> per-layer DSE problems
# ---------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig) -> list[tuple[LinearSpec, int, float]]:
    """(spec, instance_count, token_scale) for every projection family.

    ``token_scale`` rescales the streamed token count for projections that
    see a fraction of the batch (MoE expert capacity).
    """
    from repro.models.blocks import attn_spec, mlp_spec, moe_spec, ssm_spec, rwkv_spec
    from repro.models.lm import head_spec

    L = cfg.n_layers
    out: list[tuple[LinearSpec, int, float]] = []

    def attn(spec, count):
        out.extend([(spec.q_spec, count, 1.0), (spec.k_spec, count, 1.0),
                    (spec.v_spec, count, 1.0), (spec.o_spec, count, 1.0)])

    def mlp(spec, count, scale=1.0):
        if spec.kind == "swiglu":
            out.append((spec.gate_spec, count, scale))
        out.extend([(spec.up_spec, count, scale), (spec.down_spec, count, scale)])

    if cfg.family in ("dense", "vlm"):
        attn(attn_spec(cfg), L)
        mlp(mlp_spec(cfg), L)
    elif cfg.family == "moe":
        attn(attn_spec(cfg), L)
        ms = moe_spec(cfg)
        # capacity-padded execution (nn/moe.py): ALL experts run, each on
        # its capacity slice of ~ top_k * cf / E of the token stream
        cap = ms.top_k * cfg.capacity_factor / max(ms.n_experts, 1)
        if ms.kind == "swiglu":
            out.append((ms.expert_gate, L * ms.n_experts, cap))
        out.extend([(ms.expert_up, L * ms.n_experts, cap),
                    (ms.expert_down, L * ms.n_experts, cap)])
        if ms.shared_spec is not None:
            # shared experts are merged into ONE wider MLP per layer
            mlp(ms.shared_spec, L)
    elif cfg.family == "hybrid":
        ss = ssm_spec(cfg)
        out.extend([(ss.in_spec, L, 1.0), (ss.out_spec, L, 1.0)])
        n_groups = L // cfg.attn_every if cfg.attn_every else 0
        if n_groups:  # one shared parameter set, applied once per group
            attn(attn_spec(cfg, name="shared_attn"), n_groups)
    elif cfg.family == "rwkv":
        rs = rwkv_spec(cfg)
        for tag in ("wr", "wk", "wv", "wg", "wo", "cmv"):
            out.append((rs.proj(tag), L, 1.0))
        out.append((rs.proj("cmk", rs.ffn), L, 1.0))
        out.append((LinearSpec(f"{rs.name}.cmr", rs.ffn, cfg.d_model,
                               False, "attn", cfg.tt), L, 1.0))
    elif cfg.family == "encdec":
        attn(attn_spec(cfg, "enc_attn", causal=False), cfg.encoder_layers)
        mlp(mlp_spec(cfg, "enc_mlp"), cfg.encoder_layers)
        attn(attn_spec(cfg), L)
        attn(attn_spec(cfg, "xattn"), L)
        mlp(mlp_spec(cfg), L)
    else:  # pragma: no cover
        raise ValueError(cfg.family)
    out.append((head_spec(cfg), 1, 1.0))
    return out


def model_dse_layers(
    cfg: ModelConfig, tokens: int,
    factorizations: Optional[dict] = None,
) -> list[tuple[str, TensorNetwork]]:
    """Tensorized projections of ``cfg`` as named contraction problems.

    One entry per projection *instance* (repeated transformer layers
    appear L times — the batched cost-table engine dedups them), with the
    streamed token count as the batch edge.

    ``factorizations`` maps family names to explicit ``(out_modes,
    in_modes, ranks)`` overrides (the rank search's candidate handle);
    families not named keep their TTConfig-derived decomposition.
    """
    layers: list[tuple[str, TensorNetwork]] = []
    for spec, count, scale in _block_specs(cfg):
        if factorizations is not None and spec.name in factorizations:
            spec = spec.with_factorization(*factorizations[spec.name])
        if not spec.tensorized:
            continue  # dense projections have no path/dataflow freedom here
        t = max(1, math.ceil(tokens * scale))
        tn = spec.network(t)
        for i in range(count):
            layers.append((f"{spec.name}[{i}]" if count > 1 else spec.name, tn))
    if not layers:
        raise ValueError(
            f"config {cfg.name!r} has no tensorized projections "
            f"(tt.enabled={cfg.tt.enabled}, min_dim={cfg.tt.min_dim})"
        )
    return layers


def _vision_dse_layers(arch: str, tokens: int) -> list[tuple[str, TensorNetwork]]:
    from repro.models.vision import model_layers

    model, dataset = arch.split("/")
    batch = max(1, tokens)
    return [(l.name, l.tt_network) for l in model_layers(model, dataset, batch=batch)]


def dse_problems(
    arch: str, tokens: Optional[int] = None, smoke: bool = False
) -> tuple[list[tuple[str, TensorNetwork]], int]:
    """Enumerate ``arch``'s per-layer DSE problems.

    Returns ``(named_layers, tokens)`` — one (instance name, tensor
    network) pair per tensorized projection instance, plus the effective
    streamed-token count (1024 default; im2col batch 1 for vision archs).
    """
    if arch in VISION_ARCHS:
        tokens = 1 if tokens is None else tokens
        return _vision_dse_layers(arch, tokens), tokens
    tokens = 1024 if tokens is None else tokens
    try:
        cfg = get_config(arch, smoke=smoke)
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; have ('tt-lm-100m',) + "
            f"{tuple(ARCH_IDS)} + {VISION_ARCHS}"
        ) from None
    return model_dse_layers(cfg, tokens), tokens


def model_layer_paths(
    named: Sequence[tuple[str, TensorNetwork]], top_k: int
) -> list:
    """Stage 1: top-K path search, memoised over repeated layers."""
    memo: dict = {}
    out = []
    for _, tn in named:
        key = tuple((n.edges, n.dims, n.kind) for n in tn.nodes)
        if key not in memo:
            memo[key] = find_topk_paths(tn, k=top_k)
        out.append(memo[key])
    return out


# ---------------------------------------------------------------------------
# end-to-end run
# ---------------------------------------------------------------------------

def run_dse(
    arch: str,
    hw: str = "fpga_vu9p",
    top_k: int = 4,
    objective: str = "latency",
    tokens: Optional[int] = None,
    smoke: bool = False,
    engine: str = "vectorized",
    mode: str = "infer",
    hw_search: str = "off",
    hw_budget: Optional[int] = None,
    tune: str = "off",
    tune_cache: Optional[str] = None,
    serve_gen: int = 128,
    serve_slots: int = 8,
    decode_tokens: Optional[int] = None,
    search: str = "exhaustive",
    search_budget: Optional[int] = None,
    search_seed: int = 0,
    rank_search: str = "off",
    accuracy_budget: Optional[float] = None,
    shards: Optional[int] = None,
    fused_cost: bool = False,
) -> dict:
    """Run Algorithm 1 end-to-end; returns the JSON-serializable report.

    ``tokens`` is the streamed token count per projection (default 1024);
    for vision archs it is the im2col batch size (default 1).

    ``mode="train"`` optimizes the training step (joint fwd+bwd+update —
    per-layer reports carry the latency decomposition and the backward
    path choices); ``"both"`` runs both searches and nests their reports
    under ``"infer"`` / ``"train"`` with the layers whose choices diverge.

    ``hw_search="budget"`` turns on the joint architecture co-search: the
    ``--hw`` target becomes the *base* of a feasible architecture space
    (``repro.hw.ArchSpace``, PE shape x SRAM split x bandwidth tier under
    ``hw_budget`` MACs — default: the base target's own PE count), every
    candidate is evaluated through the hw-batched cost-table engine, and
    the report gains a per-candidate ``hw_search`` section.

    ``tune`` turns on the measured-latency loop (``repro.tune``): the
    model's dominant GEMM shapes are measured per dataflow on this
    machine (``"cache"`` = only cache misses, ``"measure"`` = re-measure)
    and the resulting calibration rescales the analytic table before the
    argmin.  The report gains a ``tune`` section; with ``--emit-plan``
    the plan additionally carries measured kernel tilings.

    ``search="guided"`` replaces the exhaustive sweep with the budgeted
    explorer of ``repro.search`` (``search_budget`` cost-model
    evaluations, ``search_seed`` for the proposal stream); the report's
    ``search`` section records the provenance (evals, found-at-eval,
    the exhaustive count it avoided).

    ``rank_search="budget"`` adds the decomposition itself as a fourth
    searched axis (``repro.rank``): every TT factorization candidate is
    evaluated end-to-end and the report gains a ``rank_search`` section
    with the (latency, accuracy-proxy) frontier; ``accuracy_budget``
    caps the chosen candidate's reconstruction-error proxy (default:
    no worse than the frozen decomposition).

    ``shards=N`` searches at per-device shard shapes (``tokens / N``)
    so the emitted tilings match what the shard_map executor streams per
    device on an N-way data-parallel mesh; defaults to an installed
    ``ShardingRules`` mesh, else unsharded.
    """
    if mode == "both":
        _check_train_compatible(objective, engine)  # fail before any search
        _check_tune_compatible(tune, "both", objective, hw_search)
        _check_rank_compatible(rank_search, "both", objective, engine, tune)
        _check_fused_compatible(fused_cost, "both", objective, engine,
                                hw_search, search, rank_search)
        infer, _, _, _, _, _ = _run_dse(
            arch, hw, top_k, objective, tokens, smoke, engine, "infer",
            hw_search, hw_budget, search=search, search_budget=search_budget,
            search_seed=search_seed, shards=shards)
        train, _, _, _, _, _ = _run_dse(
            arch, hw, top_k, objective, tokens, smoke, engine, "train",
            hw_search, hw_budget, search=search, search_budget=search_budget,
            search_seed=search_seed, shards=shards)
        return _both_report(infer, train)
    report, _, _, _, tuner, _ = _run_dse(
        arch, hw, top_k, objective, tokens, smoke, engine, mode, hw_search,
        hw_budget, tune, tune_cache, serve_gen, serve_slots, decode_tokens,
        search, search_budget, search_seed, rank_search, accuracy_budget,
        shards, fused_cost)
    _save_tuner(tuner)
    return report


def _both_report(infer: dict, train: dict) -> dict:
    """Combined infer+train report with the per-layer choice divergence.

    Under ``hw_search`` each mode co-searches its *own* architecture, so
    the per-layer deltas may partly reflect the architecture change; the
    top-level ``hw_search`` block names both winners and flags
    ``hw_divergent`` so consumers can tell the two apart (an emitted plan
    always embeds the train winner — plans are compiled from the train
    leg).
    """
    div = []
    train_by_name = {l["name"]: l for l in train["layers"]}
    for li in infer["layers"]:
        lt = train_by_name.get(li["name"])
        if lt is None:
            continue
        delta = {
            k: [li[k], lt[k]]
            for k in ("path_index", "partitioning", "dataflow")
            if li[k] != lt[k]
        }
        if delta:
            div.append({"name": li["name"], **delta})
    out = {
        "arch": infer["arch"],
        "hw": infer["hw"],
        "mode": "both",
        "tokens": infer["tokens"],
        "infer": infer,
        "train": train,
        "divergent_layers": div,
        "n_divergent_layers": len(div),
    }
    hs_i, hs_t = infer.get("hw_search"), train.get("hw_search")
    if hs_i is not None and hs_t is not None:
        out["hw_search"] = {
            "infer_chosen": hs_i["chosen"]["name"],
            "train_chosen": hs_t["chosen"]["name"],
            "hw_divergent": hs_i["chosen"]["name"] != hs_t["chosen"]["name"],
        }
    return out


def run_dse_plan(
    arch: str,
    hw: str = "fpga_vu9p",
    top_k: int = 4,
    objective: str = "latency",
    tokens: Optional[int] = None,
    smoke: bool = False,
    engine: str = "vectorized",
    plan_backend: str = "auto",
    mode: str = "infer",
    hw_search: str = "off",
    hw_budget: Optional[int] = None,
    tune: str = "off",
    tune_cache: Optional[str] = None,
    serve_gen: int = 128,
    serve_slots: int = 8,
    decode_tokens: Optional[int] = None,
    phase: str = "",
    search: str = "exhaustive",
    search_budget: Optional[int] = None,
    search_seed: int = 0,
    rank_search: str = "off",
    accuracy_budget: Optional[float] = None,
    shards: Optional[int] = None,
    fused_cost: bool = False,
):
    """Run the DSE and compile its result into an ExecutionPlan.

    ``phase`` stamps the emitted plan as one half of a serving plan pair
    (``"prefill"`` / ``"decode"``); the serve driver then refuses to
    install it as the other half.  ``--emit-plan-pair`` runs this twice
    — once per phase, each at its own token count.

    Returns ``(report, plan)`` — the same report as :func:`run_dse` plus
    the installable plan (``repro.plan.ExecutionPlan``).  This is the
    search->compile half of the deploy loop; ``launch/serve.py --plan``
    / ``launch/train.py --plan`` is the install->execute half.  Under
    ``mode="train"`` (or ``"both"``) the emitted plan is schema v2-style
    with per-layer backward paths/backends/tilings.  Under
    ``hw_search="budget"`` the plan embeds the co-searched winning
    architecture (schema v3 ``hardware``) and its kernel tilings derive
    from that architecture's array shape and buffer sizes.  Under
    ``tune`` the search is measured-calibrated and the plan's tilings
    are the autotuner's measured argmins (``tilings: "measured"``) —
    served from the persistent cache, so a warm cache re-emits the
    identical plan without measuring.  Under ``rank_search`` the plan
    embeds the chosen candidate's factorizations (schema v4) so the
    executor contracts the *searched* decomposition — vision archs
    excepted (their conv decompositions are structural, not
    plan-installable).
    """
    from repro.plan import BACKENDS, compile_plan

    if plan_backend != "auto" and plan_backend not in BACKENDS:
        raise ValueError(
            f"unknown plan backend {plan_backend!r}; have "
            f"{('auto',) + BACKENDS}")
    if mode not in MODES:
        raise KeyError(f"unknown mode {mode!r}; have {MODES}")
    infer_report = None
    if mode == "both":
        _check_train_compatible(objective, engine)  # fail before any search
        _check_tune_compatible(tune, "both", objective, hw_search)
        _check_rank_compatible(rank_search, "both", objective, engine, tune)
        _check_fused_compatible(fused_cost, "both", objective, engine,
                                hw_search, search, rank_search)
        infer_report, _, _, _, _, _ = _run_dse(
            arch, hw, top_k, objective, tokens, smoke, engine, "infer",
            hw_search, hw_budget, search=search, search_budget=search_budget,
            search_seed=search_seed, shards=shards)
    plan_mode = "train" if mode in ("train", "both") else "infer"
    report, named, res, plan_hw, tuner, calibration = _run_dse(
        arch, hw, top_k, objective, tokens, smoke, engine, plan_mode,
        hw_search, hw_budget, tune, tune_cache,
        serve_gen, serve_slots, decode_tokens,
        search, search_budget, search_seed, rank_search, accuracy_budget,
        shards, fused_cost)
    factorizations = None
    rank_report = report.get("rank_search")
    if rank_report is not None and rank_report.get("plan_embeddable"):
        from repro.plan import Factorization

        factorizations = {
            f["name"]: Factorization(
                out_modes=tuple(f["out_modes"]),
                in_modes=tuple(f["in_modes"]),
                ranks=tuple(f["ranks"]),
                accuracy_proxy=float(f["accuracy_proxy"]))
            for f in rank_report["chosen"]["families"]
        }
    plan_sharding = None
    shard_rep = report.get("sharding")
    if shard_rep is not None:
        from repro.plan import PlanSharding

        plan_sharding = PlanSharding(
            n_shards=int(shard_rep["n_shards"]),
            axes=tuple((str(a), int(s)) for a, s in shard_rep["axes"]),
            tokens_per_shard=int(shard_rep["tokens_per_shard"]))
    plan = compile_plan(
        named, res, plan_hw,
        arch=arch,
        objective=report["objective"],
        tokens=report["tokens"],
        backend=plan_backend,
        total_latency_s=report["total_latency_s"],
        tilings="heuristic" if tuner is None else "measured",
        tuner=tuner,
        phase=phase,
        factorizations=factorizations,
        sharding=plan_sharding,
    )
    if tuner is not None:
        if calibration is not None and report["objective"] == "latency":
            # the argmin ran over the calibrated table, so each choice's
            # latency landed in measured-rescaled units; divide the scale
            # back out so the plan's per-layer provenance stays in the
            # same analytic seconds as its total_latency_s (up to float
            # rounding — (analytic * cal) / cal can differ from analytic
            # by an ulp).  The correction model scales per (shape bucket,
            # dataflow), so each family's scale comes from its own
            # choice's dominant GEMM.  Train-mode searches run analytic
            # (calibration is None) — their latencies need no unscaling.
            # Throughput objectives keep calibrated units: the combined
            # value mixes two phases' scales, so no single factor
            # recovers analytic seconds.
            from repro.plan.compiler import base_name
            from repro.tune.variants import dominant_gemm

            fam_choice = {}
            for (inst_name, _), choice in zip(named, res.choices):
                fam_choice.setdefault(base_name(inst_name), choice)

            def _unscale(lp):
                c = fam_choice[lp.name]
                M, K, N = dominant_gemm(c.path)
                return dataclasses.replace(
                    lp, latency_s=lp.latency_s / calibration.scale(
                        M, K, N, lp.dataflow))

            plan = dataclasses.replace(
                plan, layers=tuple(_unscale(lp) for lp in plan.layers))
        # compilation may have measured additional (per-family) sweeps;
        # refresh the report's counters and persist the cache
        report["tune"]["n_measured"] = tuner.n_measured
        report["tune"]["n_cache_hits"] = tuner.n_cache_hits
        report["tune"]["n_cache_entries"] = len(tuner.cache)
        _save_tuner(tuner)
    if mode == "both":
        report = _both_report(infer_report, report)
    return report, plan


def _hw_search_report(space: ArchSpace, res, base_cfg,
                      n_space: int) -> dict:
    """Per-candidate section of the report (sorted best-first).

    ``res.hw_candidates`` is the full space for an exhaustive co-search
    and the *visited* (exactly refined) candidates for a guided one —
    the guided driver always visits the base target first, so ``fixed``
    is present either way.
    """
    def row(cand) -> dict:
        return {
            **space.describe(cand.hw),
            "strategy": cand.strategy,
            "total_latency_s": cand.total_latency_s,
        }

    by_latency = sorted(res.hw_candidates,
                        key=lambda c: (c.total_latency_s, c.hw.name))
    fixed = next((c for c in res.hw_candidates
                  if c.hw.name == base_cfg.name), None)
    chosen = next(c for c in res.hw_candidates if c.hw is res.hw)
    return {
        "mode": "budget",
        "search": res.search,
        "mac_budget": space.mac_budget,
        "n_candidates": len(res.hw_candidates),
        "n_space": n_space,
        "chosen": row(chosen),
        "fixed": row(fixed) if fixed is not None else None,
        "improvement_pct": (
            100.0 * (1.0 - chosen.total_latency_s / fixed.total_latency_s)
            if fixed is not None and fixed.total_latency_s > 0 else None),
        "candidates": [row(c) for c in by_latency],
    }


def _check_train_compatible(objective: str, engine: str) -> None:
    """Reject mode/objective/engine combinations the train search cannot
    honour — called up front so ``--mode both`` fails before the (valid)
    inference leg burns any search time."""
    if objective != "latency":
        raise ValueError(
            "--mode train optimizes the train-latency objective; "
            f"--objective {objective} is an inference objective")
    if engine == "scalar":
        raise ValueError("--mode train requires the vectorized engine")


def _check_tune_compatible(tune: str, mode: str, objective: str,
                           hw_search: str) -> None:
    """Reject combinations the measured-latency loop cannot honour yet.

    The calibration rescales the inference latency table — per candidate
    under an architecture co-search (ROADMAP gap c, closed).  Train mode
    is allowed since the tiling lift (ROADMAP gap b): the train *search*
    stays analytic, but train-mode plans serve measured forward tilings
    and any backward-op tilings already in the cache.  The throughput
    objective is calibrated per phase (ROADMAP serving follow-on (a),
    closed): the correction rescales the prefill and decode tables at
    their own GEMM shapes inside ``combine_phase_tables``.  Composing
    the calibration with the fwd+bwd decomposition or the EDP objective
    are still open items (ROADMAP.md)."""
    if tune == "off":
        return
    if tune not in TUNE_MODES:
        raise KeyError(f"unknown tune mode {tune!r}; have {TUNE_MODES}")
    if mode == "both":
        raise ValueError(
            "--tune with --mode both is ambiguous (the infer leg searches "
            "a calibrated table, the train leg an analytic one); run the "
            "modes separately")
    if objective not in ("latency", "throughput"):
        raise ValueError(
            "--tune calibrates the latency and throughput objectives; "
            f"--objective {objective} is analytic-only for now")


def _check_rank_compatible(rank_search: str, mode: str, objective: str,
                           engine: str, tune: str) -> None:
    """Reject combinations the rank search cannot honour.

    The decomposition axis re-derives every layer's tensor network per
    candidate, so it composes with the path/partitioning/dataflow axes,
    the architecture co-search, and the guided explorer — but not (yet)
    with the train decomposition, non-latency objectives, the scalar
    engine, or the measured calibration (whose cache keys would have to
    span every candidate's GEMM shapes)."""
    if rank_search == "off":
        return
    if rank_search not in RANK_SEARCH_MODES:
        raise KeyError(
            f"unknown rank_search {rank_search!r}; have {RANK_SEARCH_MODES}")
    if mode != "infer":
        raise ValueError(
            "--rank-search explores the inference latency/accuracy "
            f"frontier; --mode {mode} is frozen-decomposition only")
    if objective != "latency":
        raise ValueError(
            "--rank-search trades latency against the accuracy proxy; "
            f"--objective {objective} is frozen-decomposition only")
    if engine == "scalar":
        raise ValueError("--rank-search requires the vectorized engine")
    if tune != "off":
        raise ValueError(
            "--rank-search is analytic: the measured calibration would "
            "need per-candidate GEMM coverage (open item)")


def _check_fused_compatible(fused_cost: bool, mode: str, objective: str,
                            engine: str, hw_search: str, search: str,
                            rank_search: str) -> None:
    """Reject combinations the fusion-aware cost tables cannot honour.

    ``--fused-cost`` overrides the (1,1)-partitioning cells of the
    inference seconds/traffic tables with the fused-segment accounting
    (``repro.core.cost_table.fused_cost_tables``), so it composes with
    the latency and EDP objectives on a fixed target under the
    exhaustive vectorized search.  The throughput objective would need
    both phase tables fused, the architecture co-search would need the
    per-candidate hw-batched engine to know about segments, the guided
    explorer reads raw tables rather than the provided objective table,
    and the rank search re-derives networks per candidate — all open
    items (ROADMAP.md)."""
    if not fused_cost:
        return
    if mode != "infer":
        raise ValueError(
            "--fused-cost overrides the inference cost tables; "
            f"--mode {mode} is spill-always only for now")
    if objective not in ("latency", "edp"):
        raise ValueError(
            "--fused-cost composes with the latency and EDP objectives; "
            f"--objective {objective} would need fused per-phase tables "
            "(open item)")
    if engine == "scalar":
        raise ValueError("--fused-cost requires the vectorized engine")
    if hw_search != "off":
        raise ValueError(
            "--fused-cost with --hw-search would need fused hw-batched "
            "tables per candidate (open item)")
    if search != "exhaustive":
        raise ValueError(
            "--fused-cost requires --search exhaustive (the guided "
            "explorer rebuilds its own tables)")
    if rank_search != "off":
        raise ValueError(
            "--fused-cost with --rank-search would need per-candidate "
            "segmentation (open item)")


def _make_tuner(tune: str, tune_cache: Optional[str], shards: int = 1):
    """Build the Autotuner over the persistent cache (lazy import)."""
    from repro.tune import Autotuner, DEFAULT_CACHE_PATH, TuningCache

    path = tune_cache or DEFAULT_CACHE_PATH
    return Autotuner(TuningCache.load_or_empty(path), tune, cache_path=path,
                     shards=shards)


def _shard_context(shards: Optional[int]) -> Optional[dict]:
    """Resolve the per-device shard context for the search, or ``None``.

    An explicit ``--shards N`` wins; otherwise an installed
    :class:`~repro.sharding.ShardingRules` mesh supplies its token axes
    (library callers running the DSE under ``use_rules``).  When a
    context is active the searched problems, cost tables, tilings, and
    tuning sweeps are all built at ``tokens / n_shards`` — the per-device
    block the shard_map executor streams (``repro.plan.sharded``).
    """
    if shards is not None:
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards == 1:
            return None
        return {"n_shards": int(shards), "axes": [["data", int(shards)]]}
    from repro.sharding import get_rules

    rules = get_rules()
    if rules is None or rules.mesh is None:
        return None
    axes = [[a, int(rules.axis_sizes[a])] for a in rules.resolve("tokens")
            if rules.axis_sizes.get(a, 1) > 1]
    n = math.prod(s for _, s in axes)
    if n <= 1:
        return None
    return {"n_shards": int(n), "axes": axes}


def _save_tuner(tuner) -> None:
    if tuner is not None and tuner.cache_path is not None:
        tuner.save()


def _apply_fused_cost(tables, named, layer_paths, hw_cfg, tokens, tuner):
    """Overlay the fusion-aware accounting on the inference cost tables.

    Re-costs every fuseable monolithic cell with the fused-segment model
    (``core.cost_table.fused_cost_tables``) at the same ``block_tokens``
    the plan compiler's tiling heuristic would stream and the same VMEM
    budget its segmentation pass enforces (fixed target => no hw caps,
    ``_streaming_budget(None)``).  With a live ``tuner``, each fused
    layer is additionally *measured* — fused vs per-step wall-clock on
    this machine (``Autotuner.tune_fused``) — and its fused cells are
    rescaled by measured/analytic fusion-advantage disagreement, so
    ``--tune cache|measure`` calibrates the fused path too.

    Returns ``(tables, report_section)``.
    """
    from repro.core import fusion
    from repro.core.cost_table import fused_cost_tables
    from repro.plan.compiler import _pow2_le, _streaming_budget

    block_tokens = max(8, _pow2_le(min(256, tokens)))
    budget_bytes = _streaming_budget(None)
    base_seconds = dict(tables.seconds)
    t0 = time.perf_counter()
    tables = fused_cost_tables(
        layer_paths, [tn for _, tn in named], hw_cfg,
        block_tokens=block_tokens, budget_bytes=budget_bytes, base=tables)
    fused_cells = sorted(k for k, s in tables.seconds.items()
                         if s != base_seconds[k])
    tune_rows = None
    if tuner is not None and fused_cells:
        tune_rows = []
        done: dict[str, float] = {}  # layer signature -> measured scale
        from repro.tune import network_signature

        for li, ((name, tn), paths) in enumerate(zip(named, layer_paths)):
            keys = [k for k in fused_cells if k[0] == li]
            if not keys:
                continue
            p_idx = min(k[1] for k in keys)
            steps = tuple(tuple(s) for s in paths[p_idx].steps)
            sig = network_signature(tn, steps)
            if sig not in done:
                segs = fusion.segment_path(
                    tn, steps, block_tokens=block_tokens,
                    budget_bytes=budget_bytes)
                meas = tuner.tune_fused(
                    tn, steps, segs, tokens, include=(block_tokens,),
                    budget_bytes=budget_bytes)
                scale = 1.0
                if meas is not None and meas["per_step_s"] > 0:
                    k_rep = next(k for k in keys if k[1] == p_idx)
                    analytic_adv = tables.seconds[k_rep] / base_seconds[k_rep]
                    measured_adv = meas["fused_s"] / meas["per_step_s"]
                    if analytic_adv > 0 and measured_adv > 0:
                        scale = measured_adv / analytic_adv
                done[sig] = scale
                tune_rows.append({
                    "layer": name,
                    "path_index": int(p_idx),
                    "measured": meas,
                    "scale": scale,
                })
            if done[sig] != 1.0:
                for k in keys:
                    tables.seconds[k] *= done[sig]
    report = {
        "enabled": True,
        "block_tokens": int(block_tokens),
        "budget_bytes": int(budget_bytes),
        "n_fused_cells": len(fused_cells),
        "n_fused_layers": len({k[0] for k in fused_cells}),
        "tune": tune_rows,
        "build_s": time.perf_counter() - t0,
    }
    return tables, report


def _run_dse(
    arch: str,
    hw: str = "fpga_vu9p",
    top_k: int = 4,
    objective: str = "latency",
    tokens: Optional[int] = None,
    smoke: bool = False,
    engine: str = "vectorized",
    mode: str = "infer",
    hw_search: str = "off",
    hw_budget: Optional[int] = None,
    tune: str = "off",
    tune_cache: Optional[str] = None,
    serve_gen: int = 128,
    serve_slots: int = 8,
    decode_tokens: Optional[int] = None,
    search: str = "exhaustive",
    search_budget: Optional[int] = None,
    search_seed: int = 0,
    rank_search: str = "off",
    accuracy_budget: Optional[float] = None,
    shards: Optional[int] = None,
    fused_cost: bool = False,
):
    """Shared pipeline; returns (report, named_layers, DSEResult, hw_cfg,
    tuner, calibration).

    ``shards`` activates the per-device shard context
    (:func:`_shard_context`): every problem network — and therefore
    every cost table, tiling, and tuning sweep — is built at the
    per-shard token count the shard_map executor streams, and the report
    gains a ``sharding`` section for plan provenance.

    The returned hardware config is the one the plan should compile for:
    the co-searched winner under ``hw_search``, else the fixed target.
    The tuner is the live ``repro.tune.Autotuner`` when ``tune`` is on
    (``run_dse_plan`` hands it to the plan compiler for measured
    tilings, then persists its cache), else ``None``; the calibration is
    the fitted ``repro.tune.CostCorrection`` the search ran under
    (``run_dse_plan`` divides its scales back out of plan latencies).

    ``search="guided"`` routes the argmin through
    ``repro.search.guided_search`` — a budgeted explorer over the same
    cost tables (latency / train-latency objectives; EDP and throughput
    tables are pre-combined and stay exhaustive).  With ``hw_search``
    it replaces the exhaustive outer architecture loop; without it the
    single target is refined exactly (same result, guided provenance).

    ``objective="throughput"`` optimizes serving tokens/s under a
    sustained continuous-batching load: each layer's cost becomes
    ``T_prefill(tokens) + (serve_gen / serve_slots) * T_decode``, where
    the decode table replays the same candidate paths at
    ``decode_tokens`` streamed tokens (default ``serve_slots`` — one
    fixed-width decode step).  The report gains a ``serving`` section
    with the phase decomposition of the winning configuration.
    """
    hw_cfg = get_target(hw)
    if objective not in OBJECTIVES:
        raise KeyError(f"unknown objective {objective!r}; have {OBJECTIVES}")
    if mode not in ("infer", "train"):
        raise KeyError(f"unknown mode {mode!r}; have {MODES}")
    if engine == "scalar" and objective in ("edp", "throughput"):
        raise ValueError(
            f"objective={objective} requires the vectorized engine")
    if objective == "throughput":
        if arch in VISION_ARCHS:
            raise ValueError(
                "objective=throughput models the serving prefill/decode "
                "split of causal LMs; vision archs have no decode phase")
        if serve_gen < 1 or serve_slots < 1:
            raise ValueError("serve_gen and serve_slots must be >= 1")
    if mode == "train":
        _check_train_compatible(objective, engine)
    if hw_search not in HW_SEARCH_MODES:
        raise KeyError(
            f"unknown hw_search {hw_search!r}; have {HW_SEARCH_MODES}")
    if hw_search != "off":
        if objective != "latency":
            raise ValueError(
                "--hw-search optimizes the latency (or train-latency) "
                f"objective; --objective {objective} is fixed-architecture "
                "only")
        if engine == "scalar":
            raise ValueError("--hw-search requires the vectorized engine")
    if search not in SEARCH_MODES:
        raise KeyError(f"unknown search {search!r}; have {SEARCH_MODES}")
    if search == "guided":
        if objective != "latency":
            raise ValueError(
                "--search guided explores the latency (or train-latency) "
                f"objective; the pre-combined --objective {objective} "
                "table stays on the exhaustive path")
        if engine == "scalar":
            raise ValueError("--search guided requires the vectorized "
                             "engine")
    if search_budget is not None and search != "guided":
        raise ValueError("search_budget requires search='guided'")
    _check_tune_compatible(tune, mode, objective, hw_search)
    _check_fused_compatible(fused_cost, mode, objective, engine, hw_search,
                            search, rank_search)
    shard_ctx = _shard_context(shards)
    if rank_search != "off":
        _check_rank_compatible(rank_search, mode, objective, engine, tune)
        if shard_ctx is not None:
            raise ValueError(
                "--rank-search re-derives networks per decomposition "
                "candidate; composing it with the --shards context is not "
                "supported yet")
        return _run_rank_dse(
            arch, hw, top_k, tokens, smoke, engine, hw_search, hw_budget,
            search, search_budget, search_seed, accuracy_budget)
    if accuracy_budget is not None:
        raise ValueError("accuracy_budget requires rank_search='budget'")

    named, tokens = dse_problems(arch, tokens, smoke)
    if shard_ctx is not None:
        # per-device problems: the searched tilings/tables must match the
        # (tokens / n_shards) block each device actually streams
        from repro.core.cost_table import shard_streamed_tokens

        global_tokens = tokens
        tokens = shard_streamed_tokens(tokens, shard_ctx["n_shards"])
        named, _ = dse_problems(arch, tokens, smoke)
        shard_ctx = {**shard_ctx, "tokens_per_shard": tokens,
                     "global_tokens": global_tokens}

    # stage 1 — top-K path search, memoised over repeated layers
    t0 = time.perf_counter()
    layer_paths = model_layer_paths(named, top_k)
    path_search_s = time.perf_counter() - t0

    # stage 2 — batched cost table (scalar engine kept for benchmarking)
    all_parts = ALL_PARTITIONINGS
    train_tables = None
    layer_backwards = None
    hw_search_report = None
    if mode == "train":
        from repro.core import memoised_layer_backwards

        t0 = time.perf_counter()
        layer_backwards = memoised_layer_backwards(
            [tn for _, tn in named], k=top_k)
        bwd_search_s = time.perf_counter() - t0
        path_search_s += bwd_search_s

    # stage 2b — measured calibration (repro.tune): measure the model's
    # dominant GEMM shapes per dataflow on this machine, fit the learned
    # per-(shape-bucket, dataflow) correction from the cache, and rescale
    # the analytic table(s) before the argmin.  Runs before the search
    # stages because the co-search applies it per candidate (gap c).
    tuner = None
    tune_report = None
    calibration = None
    fused_report = None
    if tune != "off" and mode == "train":
        # ROADMAP gap (b): train-mode plans may serve measured tilings —
        # forward ops through the usual measured sweep, backward ops from
        # whatever the cache already holds (analytic fallback on miss) —
        # but the train *search* stays analytic: composing the measured
        # calibration with the fwd+bwd+update decomposition is open.
        tuner = _make_tuner(tune, tune_cache,
                            shards=(shard_ctx or {}).get("n_shards", 1))
        tune_report = {
            "mode": tune,
            "cache": tuner.cache_path,
            "device_kind": tuner.device_kind,
            "interpret": tuner.interpret,
            "n_calibration_shapes": 0,
            "calibration": None,
            "correction": None,
            "note": "train search is analytic; measured tilings only",
            "n_measured": tuner.n_measured,
            "n_cache_hits": tuner.n_cache_hits,
            "n_cache_entries": len(tuner.cache),
            "measure_s": 0.0,
        }
    elif tune != "off":
        from repro.tune import (
            fit_cost_correction,
            gemm_work_items,
            measured_calibration,
        )

        tuner = _make_tuner(tune, tune_cache,
                            shards=(shard_ctx or {}).get("n_shards", 1))
        t0 = time.perf_counter()
        shapes = gemm_work_items(layer_paths,
                                 max_shapes=TUNE_CALIBRATION_SHAPES)
        flat_calibration = measured_calibration(shapes, tuner, hw_cfg)
        # the learned correction: per (shape bucket, dataflow) geomean
        # ratios, falling back to the flat per-dataflow scales above on
        # sparse buckets.  The fit is pinned to the calibration shape
        # set so a warm cache holding extra sweep entries still fits the
        # identical model (bit-identical re-emission is CI-asserted).
        calibration = fit_cost_correction(
            tuner.cache, hw_cfg, device_kind=tuner.device_kind,
            interpret=tuner.interpret, shapes=shapes)
        tune_report = {
            "mode": tune,
            "cache": tuner.cache_path,
            "device_kind": tuner.device_kind,
            "interpret": tuner.interpret,
            "n_calibration_shapes": len(shapes),
            "calibration": flat_calibration,
            "correction": calibration.describe(),
            "n_measured": tuner.n_measured,
            "n_cache_hits": tuner.n_cache_hits,
            "n_cache_entries": len(tuner.cache),
            "measure_s": time.perf_counter() - t0,
        }

    n_space = 1
    if hw_search != "off":
        # stage 2+3 joint: hw-batched tables + outer architecture loop
        # (exhaustive), or the budgeted guided explorer (repro.search)
        from repro.core import build_cost_tables_hw, build_train_cost_tables_hw

        space = ArchSpace(base=hw_cfg, mac_budget=hw_budget)
        cands = space.candidates()
        n_space = len(cands)
        if search == "guided":
            from repro.search import guided_search

            t0 = time.perf_counter()
            res = guided_search(
                layer_paths, hw_cfg,
                objective=("train-latency" if mode == "train"
                           else "latency"),
                hw_space=cands, budget=search_budget, seed=search_seed,
                layer_backwards=layer_backwards, calibration=calibration)
            argmin_s = time.perf_counter() - t0
            # rebuild the winner's analytic tables for the report: the
            # per-layer latencies below must stay in analytic seconds
            # even when the argmin ran over a calibrated table
            if mode == "train":
                train_tables = build_train_cost_tables_hw(
                    layer_paths, layer_backwards, (res.hw,), all_parts)[0]
                tables = train_tables.fwd
                table_build_s = train_tables.build_seconds
            else:
                tables = build_cost_tables_hw(layer_paths, (res.hw,),
                                              all_parts)[0]
                table_build_s = tables.build_seconds
        elif mode == "train":
            trains = build_train_cost_tables_hw(
                layer_paths, layer_backwards, cands, all_parts)
            table_build_s = trains[0].build_seconds
            t0 = time.perf_counter()
            res = global_search(layer_paths, objective="train-latency",
                                hw_space=cands, hw_train_tables=trains)
            argmin_s = time.perf_counter() - t0
            win = cands.index(res.hw)
            train_tables = trains[win]
            tables = train_tables.fwd
        else:
            per_hw = build_cost_tables_hw(layer_paths, cands, all_parts)
            table_build_s = per_hw[0].build_seconds
            t0 = time.perf_counter()
            res = global_search(layer_paths, hw_space=cands,
                                hw_tables=[t.seconds for t in per_hw],
                                calibration=calibration)
            argmin_s = time.perf_counter() - t0
            win = cands.index(res.hw)
            tables = per_hw[win]
        seconds_table = tables.seconds
        hw_search_report = _hw_search_report(space, res, hw_cfg, n_space)
    elif mode == "train":
        from repro.core import build_train_cost_tables

        train_tables = build_train_cost_tables(
            layer_paths, layer_backwards, hw_cfg, all_parts)
        tables = train_tables.fwd
        seconds_table = tables.seconds
        table_build_s = train_tables.build_seconds
    elif engine == "scalar":
        t0 = time.perf_counter()
        seconds_table = build_cost_table(
            layer_paths, hw_cfg, all_parts, engine="scalar"
        )
        tables = None
        table_build_s = time.perf_counter() - t0
        obj_table = seconds_table
    decode_seconds = None
    dec_tokens = decode_tokens if decode_tokens is not None else serve_slots
    if shard_ctx is not None:
        from repro.core.cost_table import shard_streamed_tokens

        dec_tokens = shard_streamed_tokens(dec_tokens,
                                           shard_ctx["n_shards"])
    if hw_search == "off" and mode != "train" and engine != "scalar":
        tables = build_cost_tables(layer_paths, hw_cfg, all_parts)
        seconds_table = tables.seconds
        table_build_s = tables.build_seconds
        if fused_cost:
            tables, fused_report = _apply_fused_cost(
                tables, named, layer_paths, hw_cfg, tokens, tuner)
            seconds_table = tables.seconds
            table_build_s += fused_report["build_s"]
        if objective == "edp":
            obj_table = tables.edp(hw_cfg)
        elif objective == "throughput":
            # second cost table at decode shape: same contraction orders,
            # activations replayed at dec_tokens streamed tokens so the
            # (layer, path) keys line up across the phase tables
            from repro.core.dse import combine_phase_tables, replay_paths

            decode_named, _ = dse_problems(arch, dec_tokens, smoke)
            decode_paths = replay_paths(
                layer_paths, [tn for _, tn in decode_named])
            decode_tables = build_cost_tables(decode_paths, hw_cfg, all_parts)
            decode_seconds = decode_tables.seconds
            table_build_s += decode_tables.build_seconds
            # measured calibration applies per phase, at each phase's own
            # GEMM shapes (ROADMAP serving follow-on (a)); the combined
            # table is then final — stage 3 must not rescale it again
            obj_table = combine_phase_tables(
                seconds_table, decode_seconds,
                w_decode=serve_gen / serve_slots,
                calibration=calibration,
                prefill_paths=layer_paths,
                decode_paths=decode_paths)
        else:
            obj_table = seconds_table

    # stage 3 — hierarchical global argmin over the chosen objective
    # (already folded into the outer architecture loop under hw search)
    if hw_search == "off":
        t0 = time.perf_counter()
        if search == "guided":
            from repro.search import guided_search

            res = guided_search(
                layer_paths, hw_cfg,
                objective=("train-latency" if mode == "train"
                           else "latency"),
                budget=search_budget, seed=search_seed,
                layer_backwards=layer_backwards, calibration=calibration)
        elif mode == "train":
            res = global_search(layer_paths, hw_cfg,
                                objective="train-latency",
                                train_tables=train_tables)
        else:
            res = global_search(
                layer_paths, hw_cfg, table=obj_table,
                # throughput tables arrive pre-calibrated per phase
                calibration=(None if objective == "throughput"
                             else calibration),
                objective="throughput" if objective == "throughput"
                else "latency")
        argmin_s = time.perf_counter() - t0

    layers = []
    total_latency = 0.0
    for (name, _), choice in zip(named, res.choices):
        key = (choice.layer, choice.path_index, choice.partitioning,
               choice.dataflow)
        # train mode: per-step cost = fwd + bwd + update; infer: fwd only
        latency_s = choice.latency_s if mode == "train" else seconds_table[key]
        total_latency += latency_s
        entry = {
            "name": name,
            "path_index": choice.path_index,
            "mac_optimal_path": choice.path_index == 0,
            "macs": choice.path.macs,
            "partitioning": list(choice.partitioning),
            "dataflow": choice.dataflow.value,
            "latency_s": latency_s,
            # the argmin's objective value: == latency_s unless EDP or
            # --tune (then in measured-rescaled units, see the tune
            # section's calibration scales)
            "objective": choice.latency_s,
        }
        if mode == "train":
            entry["fwd_latency_s"] = choice.fwd_latency_s
            entry["bwd_latency_s"] = choice.bwd_latency_s
            entry["update_latency_s"] = choice.update_latency_s
            entry["backward"] = [
                {"wrt": ch.wrt, "path_index": ch.path_index,
                 "latency_s": ch.latency_s}
                for ch in choice.backward
            ]
        layers.append(entry)
    report = {
        "arch": arch,
        "hw": hw,
        # the architecture the numbers below describe: the co-searched
        # winner under --hw-search, else the --hw target itself
        "hw_chosen": res.hw.name if res.hw is not None else hw,
        "hw_search": hw_search_report,
        "tune": tune_report,
        "fused_cost": fused_report,
        "mode": mode,
        "objective": "train-latency" if mode == "train" else objective,
        "top_k": top_k,
        "tokens": tokens,
        "engine": engine,
        "strategy": res.strategy,
        "total_latency_s": total_latency,
        "total_objective": res.total_latency_s,
        "search": {
            "mode": res.search,
            "budget": search_budget,
            "seed": search_seed if search == "guided" else None,
            "evals": res.evals,
            "found_at_eval": res.found_at_eval,
            "exhaustive_evals": n_space * _table_cells(layer_paths,
                                                      all_parts),
        },
        "sharding": shard_ctx,
        "n_layers": len(layers),
        "timings": {
            "path_search_s": path_search_s,
            "table_build_s": table_build_s,
            "argmin_s": argmin_s,
        },
        "table": {
            "n_cells": len(seconds_table),
            "n_unique_gemm_evals": tables.n_unique_gemm_evals if tables else None,
            "n_unique_layers": tables.n_unique_layers if tables else None,
        },
        "layers": layers,
    }
    if mode == "train":
        report["total_fwd_latency_s"] = sum(
            c.fwd_latency_s for c in res.choices)
        report["total_bwd_latency_s"] = sum(
            c.bwd_latency_s for c in res.choices)
        report["total_update_latency_s"] = sum(
            c.update_latency_s for c in res.choices)
    if decode_seconds is not None:
        # phase decomposition of the winning serving configuration:
        # total objective = prefill + (gen/slots) * decode per admission
        keys = [(c.layer, c.path_index, c.partitioning, c.dataflow)
                for c in res.choices]
        report["serving"] = {
            "prefill_tokens": tokens,
            "decode_tokens": dec_tokens,
            "gen_tokens": serve_gen,
            "n_slots": serve_slots,
            "decode_weight": serve_gen / serve_slots,
            # True when the combined table was measured-calibrated per
            # phase (--tune with --objective throughput); the analytic
            # phase split below stays in analytic seconds either way
            "calibrated": calibration is not None,
            "total_prefill_s": sum(seconds_table[k] for k in keys),
            "total_decode_step_s": sum(decode_seconds[k] for k in keys),
            "total_combined_s": res.total_latency_s,
        }
    return (report, named, res,
            (res.hw if res.hw is not None else hw_cfg), tuner, calibration)


def _run_rank_dse(
    arch: str,
    hw: str,
    top_k: int,
    tokens: Optional[int],
    smoke: bool,
    engine: str,
    hw_search: str,
    hw_budget: Optional[int],
    search: str,
    search_budget: Optional[int],
    search_seed: int,
    accuracy_budget: Optional[float],
):
    """The ``--rank-search budget`` pipeline (repro.rank).

    Evaluates every decomposition candidate through the same cost-table
    /argmin stack as :func:`_run_dse` and reports the chosen candidate's
    per-layer choices plus a ``rank_search`` frontier section.  Same
    return contract as ``_run_dse`` — ``run_dse_plan`` compiles the
    chosen candidate's networks/choices into a (v4) plan.
    """
    from repro.rank import rank_search as _rank_search

    hw_cfg = get_target(hw)
    hw_space = None
    space = None
    n_space = 1
    if hw_search == "budget":
        space = ArchSpace(base=hw_cfg, mac_budget=hw_budget)
        hw_space = space.candidates()
        n_space = len(hw_space)

    t0 = time.perf_counter()
    rres = _rank_search(
        arch, hw_cfg, top_k=top_k, tokens=tokens, smoke=smoke,
        hw_space=hw_space, search=search, search_budget=search_budget,
        search_seed=search_seed, accuracy_budget=accuracy_budget)
    rank_search_s = time.perf_counter() - t0

    ce = rres.chosen_eval
    named, res = ce.named, ce.res
    plan_hw = res.hw if res.hw is not None else hw_cfg

    # rebuild the chosen candidate's analytic table for the per-layer
    # report (under --hw-search: on its winning architecture)
    t0 = time.perf_counter()
    layer_paths = model_layer_paths(named, top_k)
    path_search_s = time.perf_counter() - t0
    if hw_search == "budget":
        from repro.core import build_cost_tables_hw

        tables = build_cost_tables_hw(layer_paths, (plan_hw,),
                                      ALL_PARTITIONINGS)[0]
    else:
        tables = build_cost_tables(layer_paths, hw_cfg, ALL_PARTITIONINGS)
    seconds_table = tables.seconds
    hw_search_report = (_hw_search_report(space, res, hw_cfg, n_space)
                        if hw_search == "budget" else None)

    layers = []
    total_latency = 0.0
    for (name, _), choice in zip(named, res.choices):
        key = (choice.layer, choice.path_index, choice.partitioning,
               choice.dataflow)
        latency_s = seconds_table[key]
        total_latency += latency_s
        layers.append({
            "name": name,
            "path_index": choice.path_index,
            "mac_optimal_path": choice.path_index == 0,
            "macs": choice.path.macs,
            "partitioning": list(choice.partitioning),
            "dataflow": choice.dataflow.value,
            "latency_s": latency_s,
            "objective": choice.latency_s,
        })

    def cand_row(i: int) -> dict:
        e = rres.evals[i]
        c = e.candidate
        return {
            "name": c.name,
            "d": c.d,
            "rank": c.rank,
            "n_params": c.n_params,
            "compression": c.compression,
            "accuracy_proxy": e.accuracy_proxy,
            "total_latency_s": e.total_latency_s,
            "strategy": e.res.strategy,
            "on_frontier": i in rres.frontier,
            "eval_seconds": e.eval_seconds,
        }

    rows = [cand_row(i) for i in range(len(rres.evals))]
    chosen_row = dict(rows[rres.chosen])
    chosen_row["families"] = [
        {
            "name": f.name,
            "out_modes": list(f.out_modes),
            "in_modes": list(f.in_modes),
            "ranks": list(f.ranks),
            "instances": f.instances,
            "accuracy_proxy": ce.family_proxies[f.name],
        }
        for f in ce.candidate.families
    ]
    rank_report = {
        "mode": "budget",
        "accuracy_budget": accuracy_budget,
        "param_budget_ratio": rres.param_budget_ratio,
        "n_candidates": len(rres.evals),
        "frontier": [rres.evals[i].candidate.name for i in rres.frontier],
        "chosen": chosen_row,
        "frozen": rows[rres.frozen],
        "dominates_frozen": rres.dominates_frozen,
        "improvement_pct": rres.improvement_pct,
        # vision decompositions are structural (TT-conv) — their rank
        # rides in the networks, not in an installable plan
        "plan_embeddable": arch not in VISION_ARCHS,
        "rank_search_s": rank_search_s,
        "candidates": sorted(
            rows, key=lambda r: (r["total_latency_s"], r["name"])),
    }

    report = {
        "arch": arch,
        "hw": hw,
        "hw_chosen": res.hw.name if res.hw is not None else hw,
        "hw_search": hw_search_report,
        "tune": None,
        "mode": "infer",
        "objective": "latency",
        "top_k": top_k,
        "tokens": rres.tokens,
        "engine": engine,
        "strategy": res.strategy,
        "total_latency_s": total_latency,
        "total_objective": res.total_latency_s,
        "search": {
            "mode": res.search,
            "budget": search_budget,
            "seed": search_seed if search == "guided" else None,
            "evals": sum(e.res.evals for e in rres.evals),
            "found_at_eval": res.found_at_eval,
            # per-candidate table sizes differ; scale the chosen
            # candidate's cell count by the candidate count
            "exhaustive_evals": (n_space * len(rres.evals)
                                 * _table_cells(layer_paths,
                                                ALL_PARTITIONINGS)),
        },
        "rank_search": rank_report,
        "n_layers": len(layers),
        "timings": {
            "path_search_s": path_search_s,
            "table_build_s": tables.build_seconds,
            "argmin_s": rank_search_s,
            "rank_search_s": rank_search_s,
        },
        "table": {
            "n_cells": len(seconds_table),
            "n_unique_gemm_evals": tables.n_unique_gemm_evals,
            "n_unique_layers": tables.n_unique_layers,
        },
        "layers": layers,
    }
    return report, named, res, plan_hw, None, None


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.dse",
        description="Global latency/EDP-driven DSE (paper Algorithm 1).",
    )
    p.add_argument("--arch", help="named config (see --list-archs)")
    p.add_argument("--hw", default="fpga_vu9p",
                   help="hardware target name (see --list-hw; "
                        "default fpga_vu9p)")
    p.add_argument("--hw-search", default="off", choices=HW_SEARCH_MODES,
                   help="off: fixed --hw target (default); budget: joint "
                        "(architecture, path, dataflow) co-search over the "
                        "feasible variants of --hw under a MAC/DSP budget "
                        "(repro.hw.ArchSpace); the report gains a "
                        "per-candidate hw_search section and --emit-plan "
                        "embeds the winning architecture (plan v3)")
    p.add_argument("--hw-budget", type=int, default=None, metavar="MACS",
                   help="MAC/DSP budget for --hw-search budget "
                        "(default: the base target's own PE count)")
    p.add_argument("--search", default="exhaustive", choices=SEARCH_MODES,
                   help="exhaustive: Algorithm 1's full sweep, optimal over "
                        "the pruned space (default); guided: the budgeted "
                        "evolutionary explorer (repro.search) over the same "
                        "cost tables — exact per visited architecture, "
                        "bounded by --search-budget evaluations; the report "
                        "gains a search provenance section")
    p.add_argument("--search-budget", type=int, default=None, metavar="N",
                   help="evaluation budget for --search guided, in cost-"
                        "table cells read (default: the full table for a "
                        "fixed target, 25%% of the exhaustive count under "
                        "--hw-search)")
    p.add_argument("--search-seed", type=int, default=0, metavar="SEED",
                   help="RNG seed of the guided proposal stream (same seed "
                        "-> bit-identical result; default 0)")
    p.add_argument("--rank-search", default="off", choices=RANK_SEARCH_MODES,
                   help="off: frozen TT decomposition (default); budget: "
                        "search the decomposition (modes-per-side x rank "
                        "ladder per projection family, repro.rank) jointly "
                        "with the mapping axes under a parameter budget — "
                        "the report gains a rank_search frontier section "
                        "and --emit-plan embeds the chosen factorizations "
                        "(plan v4)")
    p.add_argument("--accuracy-budget", type=float, default=None,
                   metavar="EPS",
                   help="cap the chosen candidate's accuracy proxy "
                        "(relative TT-SVD reconstruction error) at EPS "
                        "(default: no worse than the frozen decomposition; "
                        "requires --rank-search budget)")
    p.add_argument("--top-k", type=int, default=4, metavar="K",
                   help="candidate paths kept per layer (default 4)")
    p.add_argument("--objective", default="latency", choices=OBJECTIVES,
                   help="latency: single-pass latency (default); edp: "
                        "energy-delay product; throughput: serving tokens/s "
                        "under sustained continuous-batching load — per "
                        "layer, prefill latency at --tokens plus "
                        "(--serve-gen / --serve-slots) decode steps at "
                        "--decode-tokens (one compromise plan; for a "
                        "per-phase pair see --emit-plan-pair)")
    p.add_argument("--serve-gen", type=int, default=128, metavar="N",
                   help="throughput objective: generated tokens per request "
                        "(default 128)")
    p.add_argument("--serve-slots", type=int, default=8, metavar="N",
                   help="throughput objective: fixed decode batch width "
                        "(default 8)")
    p.add_argument("--decode-tokens", type=int, default=None, metavar="N",
                   help="streamed tokens of one decode step for the "
                        "throughput objective / the decode leg of "
                        "--emit-plan-pair (default: --serve-slots)")
    p.add_argument("--mode", default="infer", choices=MODES,
                   help="infer: forward-only DSE (default); train: joint "
                        "fwd+bwd+update search (per-layer decomposition in "
                        "the report, --emit-plan writes schema v2 with "
                        "backward entries); both: run both and report the "
                        "divergent layer choices")
    p.add_argument("--fused-cost", action="store_true",
                   help="fusion-aware cost tables: re-cost fuseable "
                        "(1,1)-partitioned paths with the fused-segment "
                        "accounting (interior intermediates charge zero "
                        "HBM traffic, one launch overhead per chain run) "
                        "so the argmin can prefer paths that segment well; "
                        "with --tune the fused advantage is additionally "
                        "measured per layer (infer mode, fixed target, "
                        "latency/EDP objectives, exhaustive search)")
    p.add_argument("--tokens", type=int, default=None,
                   help="streamed tokens per projection (default 1024; "
                        "vision archs: im2col batch, default 1)")
    p.add_argument("--shards", type=int, default=None, metavar="N",
                   help="search per-shard problems for an N-way token-"
                        "parallel mesh: each projection is costed at "
                        "--tokens/N streamed tokens and emitted plans carry "
                        "sharding provenance (default: installed sharding "
                        "rules, else 1)")
    p.add_argument("--smoke", action="store_true",
                   help="use the config's reduced SMOKE variant")
    p.add_argument("--engine", default="vectorized",
                   choices=("vectorized", "scalar"),
                   help="cost-table engine (scalar = per-cell oracle)")
    p.add_argument("--tune", default="off", choices=TUNE_MODES,
                   help="off: analytic search (default); cache/measure: "
                        "measure dominant GEMM shapes per dataflow on this "
                        "machine (cache = only cache misses, measure = "
                        "re-measure), rescale the analytic table by the "
                        "measured calibration before the argmin, and give "
                        "--emit-plan measured kernel tilings "
                        "(repro.tune; warm the cache with "
                        "python -m repro.tune)")
    p.add_argument("--tune-cache", default=None, metavar="PATH",
                   help="tuning-cache file for --tune "
                        "(default results/tuning_cache.json)")
    p.add_argument("--out", default="-", metavar="PATH",
                   help="report destination ('-' = stdout, default)")
    p.add_argument("--emit-plan", default=None, metavar="PATH",
                   help="compile the result into an executable plan "
                        "(docs/plan_format.md) and write it to PATH")
    p.add_argument("--phase", default=None, choices=("prefill", "decode"),
                   help="stamp the --emit-plan plan as one half of a "
                        "serving plan pair (the serve driver refuses to "
                        "install it as the other half)")
    p.add_argument("--emit-plan-pair", default=None, metavar="PREFIX",
                   help="run two searches — prefill at --tokens, decode at "
                        "--decode-tokens — and write the phase-stamped pair "
                        "to PREFIX.prefill.json / PREFIX.decode.json "
                        "(serve with --plan-prefill/--plan-decode)")
    p.add_argument("--plan-backend", default="auto",
                   choices=("auto", "jnp", "tt_gemm", "streaming_tt"),
                   help="force one kernel backend for every emitted layer "
                        "plan (default: per-layer heuristic)")
    p.add_argument("--list-archs", action="store_true",
                   help="print supported --arch values and exit")
    p.add_argument("--list-hw", action="store_true",
                   help="print registered --hw targets and exit")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_archs:
        for a in ("tt-lm-100m",) + tuple(ARCH_IDS) + VISION_ARCHS:
            print(a)
        return 0
    if args.list_hw:
        for name in list_targets():
            print(name)
        return 0
    if not args.arch:
        _build_parser().error("--arch is required (see --list-archs)")
    if args.plan_backend != "auto" and not (args.emit_plan
                                            or args.emit_plan_pair):
        _build_parser().error(
            "--plan-backend requires --emit-plan or --emit-plan-pair")
    if args.phase and not args.emit_plan:
        _build_parser().error("--phase requires --emit-plan "
                              "(--emit-plan-pair stamps both phases itself)")
    if args.emit_plan_pair:
        if args.emit_plan:
            _build_parser().error(
                "--emit-plan-pair and --emit-plan are mutually exclusive")
        if args.objective == "throughput":
            _build_parser().error(
                "--objective throughput emits one compromise plan via "
                "--emit-plan; --emit-plan-pair optimizes each phase "
                "separately (pick one)")
        if args.mode != "infer":
            _build_parser().error(
                "--emit-plan-pair compiles serving (inference) plans; "
                f"--mode {args.mode} is not applicable")
        if args.hw_search != "off":
            _build_parser().error(
                "--emit-plan-pair compiles a pair for one fixed --hw "
                "target; co-searching a different architecture per phase "
                "is unservable in one engine")
    if args.hw_budget is not None and args.hw_search == "off":
        _build_parser().error("--hw-budget requires --hw-search budget")
    if args.search_budget is not None and args.search != "guided":
        _build_parser().error("--search-budget requires --search guided")
    if args.tune_cache is not None and args.tune == "off":
        _build_parser().error("--tune-cache requires --tune cache|measure")
    if args.accuracy_budget is not None and args.rank_search == "off":
        _build_parser().error("--accuracy-budget requires --rank-search "
                              "budget")
    if args.rank_search != "off" and args.emit_plan_pair:
        _build_parser().error(
            "--rank-search with --emit-plan-pair would search a different "
            "decomposition per phase; factorizations set parameter shapes, "
            "so a serving pair must share one (use --emit-plan)")
    try:
        if args.emit_plan_pair:
            common = dict(
                arch=args.arch, hw=args.hw, top_k=args.top_k,
                objective=args.objective, smoke=args.smoke,
                engine=args.engine, plan_backend=args.plan_backend,
                mode="infer", tune=args.tune, tune_cache=args.tune_cache,
                search=args.search, search_budget=args.search_budget,
                search_seed=args.search_seed, shards=args.shards,
                fused_cost=args.fused_cost,
            )
            dec_tokens = (args.decode_tokens if args.decode_tokens is not None
                          else args.serve_slots)
            report_p, plan_p = run_dse_plan(
                tokens=args.tokens, phase="prefill", **common)
            report_d, plan_d = run_dse_plan(
                tokens=dec_tokens, phase="decode", **common)
            for plan, path in ((plan_p, f"{args.emit_plan_pair}.prefill.json"),
                               (plan_d, f"{args.emit_plan_pair}.decode.json")):
                plan.save(path)
                backends = sorted({lp.backend for lp in plan.layers})
                print(f"wrote {plan.phase} plan {path} "
                      f"({len(plan.layers)} layer plans, backends {backends}"
                      f", tokens {plan.tokens}, tilings {plan.tilings})",
                      file=sys.stderr)
            report = {
                "arch": args.arch, "hw": args.hw, "mode": "plan-pair",
                "prefill": report_p, "decode": report_d,
            }
        elif args.emit_plan:
            report, plan = run_dse_plan(
                arch=args.arch,
                hw=args.hw,
                top_k=args.top_k,
                objective=args.objective,
                tokens=args.tokens,
                smoke=args.smoke,
                engine=args.engine,
                plan_backend=args.plan_backend,
                mode=args.mode,
                hw_search=args.hw_search,
                hw_budget=args.hw_budget,
                tune=args.tune,
                tune_cache=args.tune_cache,
                serve_gen=args.serve_gen,
                serve_slots=args.serve_slots,
                decode_tokens=args.decode_tokens,
                phase=args.phase or "",
                search=args.search,
                search_budget=args.search_budget,
                search_seed=args.search_seed,
                rank_search=args.rank_search,
                accuracy_budget=args.accuracy_budget,
                shards=args.shards,
                fused_cost=args.fused_cost,
            )
            plan.save(args.emit_plan)
            backends = sorted({lp.backend for lp in plan.layers})
            hw_note = (f", hardware {plan.hardware.name}"
                       if plan.hardware is not None else "")
            print(f"wrote plan {args.emit_plan} "
                  f"({len(plan.layers)} layer plans, backends {backends}"
                  f"{hw_note}, tilings {plan.tilings})",
                  file=sys.stderr)
        else:
            report = run_dse(
                arch=args.arch,
                hw=args.hw,
                top_k=args.top_k,
                objective=args.objective,
                tokens=args.tokens,
                smoke=args.smoke,
                engine=args.engine,
                mode=args.mode,
                hw_search=args.hw_search,
                hw_budget=args.hw_budget,
                tune=args.tune,
                tune_cache=args.tune_cache,
                serve_gen=args.serve_gen,
                serve_slots=args.serve_slots,
                decode_tokens=args.decode_tokens,
                search=args.search,
                search_budget=args.search_budget,
                search_seed=args.search_seed,
                rank_search=args.rank_search,
                accuracy_budget=args.accuracy_budget,
                shards=args.shards,
                fused_cost=args.fused_cost,
            )
    except (KeyError, ValueError) as e:
        msg = e.args[0] if e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return 2
    text = json.dumps(report, indent=2)
    if args.out == "-":
        print(text)
    else:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
