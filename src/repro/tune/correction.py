"""Learned cost-correction: per (shape-bucket, dataflow) measured ratios.

PR 5's calibration compressed every measurement into one geometric-mean
measured/analytic ratio per dataflow.  That is the right first-order
term, but the disagreement between the analytic cost model and the
machine is *shape-dependent* too: small GEMMs pay fixed launch/dispatch
overheads the closed-form model under-weights, huge GEMMs approach the
roofline the model idealizes.  This module fits the second-order term
from the persistent tuning cache — free training data every
``repro.tune`` run accumulates:

- :func:`shape_bucket` quantizes a GEMM's MAC volume ``M*K*N`` onto a
  coarse log2 grid (bucket = ``floor(log2(MKN) / 2)`` — one bucket per
  4x volume step, wide enough that a handful of measured shapes lands
  multiple samples per bucket);
- :func:`fit_cost_correction` walks the cache's GEMM entries (filtered
  to one device/interpret mode so machines never mix) and takes the
  geometric mean of measured/analytic ratios per (bucket, dataflow), at
  the compiler's heuristic-blocks operating point;
- :class:`CostCorrection` answers ``scale(M, K, N, dataflow)`` with a
  fallback chain: exact bucket (when it holds >= ``min_samples``
  measurements) -> the per-dataflow geomean (PR 5's flat model) -> 1.0.

``dse.apply_calibration`` accepts the fitted model anywhere it accepted
the flat per-dataflow mapping, including the architecture co-search —
the correction is a property of the cost model vs the machine, so the
same scales rescale every candidate's analytic table.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

from repro.core.simulator import ALL_DATAFLOWS, Dataflow
from repro.hw import HardwareConfig

from .cache import TuningCache, variant_key

#: log2 width of one shape bucket: volumes within a 2**2 = 4x band share
#: a bucket, so a model's handful of distinct GEMM shapes still lands
#: multiple samples per bucket instead of one singleton each
SHAPE_BUCKET_LOG2_WIDTH = 2

#: minimum measurements a bucket needs before its own geomean is trusted
#: over the per-dataflow fallback (a single sample is indistinguishable
#: from noise)
MIN_BUCKET_SAMPLES = 2


def shape_bucket(M: int, K: int, N: int) -> int:
    """Quantize a GEMM's MAC volume onto the coarse log2 grid."""
    volume = int(M) * int(K) * int(N)
    if volume <= 0:
        raise ValueError(f"GEMM volume must be positive, got {volume}")
    return int(math.floor(math.log2(volume) / SHAPE_BUCKET_LOG2_WIDTH))


def _df_value(dataflow) -> str:
    return dataflow.value if isinstance(dataflow, Dataflow) else str(dataflow)


@dataclasses.dataclass(frozen=True)
class CostCorrection:
    """Fitted measured/analytic rescale model (see module docstring).

    ``bucket_scales`` maps ``(shape_bucket, dataflow value)`` to the
    bucket's geomean ratio — only buckets with >= ``min_samples``
    measurements are present.  ``dataflow_scales`` is the flat fallback
    (PR 5's calibration, fit from the same entries).
    """

    bucket_scales: Mapping[tuple[int, str], float]
    dataflow_scales: Mapping[str, float]
    bucket_samples: Mapping[tuple[int, str], int]
    device_kind: str = ""
    interpret: Optional[bool] = None
    n_ratios: int = 0
    min_samples: int = MIN_BUCKET_SAMPLES

    def scale(self, M: int, K: int, N: int, dataflow) -> float:
        """Rescale factor for one GEMM: bucket -> dataflow geomean -> 1."""
        d = _df_value(dataflow)
        s = self.bucket_scales.get((shape_bucket(M, K, N), d))
        if s is not None:
            return s
        return self.dataflow_scales.get(d, 1.0)

    def describe(self) -> dict:
        """JSON-friendly summary for the DSE report's ``tune`` section."""
        return {
            "model": "shape-bucket-geomean",
            "bucket_log2_width": SHAPE_BUCKET_LOG2_WIDTH,
            "min_samples": self.min_samples,
            "n_ratios": self.n_ratios,
            "n_buckets": len(self.bucket_scales),
            "device_kind": self.device_kind,
            "interpret": self.interpret,
            "dataflow_scales": {d: self.dataflow_scales[d]
                                for d in sorted(self.dataflow_scales)},
            "bucket_scales": {
                f"b{b}:{d}": self.bucket_scales[(b, d)]
                for (b, d) in sorted(self.bucket_scales)
            },
        }


def fit_cost_correction(
    cache: TuningCache,
    hw: HardwareConfig,
    *,
    device_kind: Optional[str] = None,
    interpret: Optional[bool] = None,
    shapes: Optional[Sequence[tuple[int, int, int]]] = None,
    dataflows: Sequence[Dataflow] = ALL_DATAFLOWS,
    min_samples: int = MIN_BUCKET_SAMPLES,
) -> CostCorrection:
    """Fit a :class:`CostCorrection` from the persistent tuning cache.

    Walks every GEMM entry matching ``device_kind`` / ``interpret``
    (both default to "any"), reads the measurement at the compiler's
    heuristic-blocks operating point — the tiling the analytic argmin
    would deploy, so ratios compare like with like; sweep-only variants
    are ignored — and accumulates log ratios against the closed-form
    prediction for ``hw``.  ``shapes`` optionally restricts the fit to a
    fixed shape set: ``repro.dse --tune`` passes its calibration work
    items so a warm cache holding extra sweep entries (e.g. from a prior
    ``tilings="measured"`` compile) still fits the identical model —
    bit-identical re-emission is CI-asserted.
    """
    from .autotune import analytic_gemm_seconds, heuristic_blocks

    shape_set = ({(int(M), int(K), int(N)) for (M, K, N) in shapes}
                 if shapes is not None else None)
    df_values = {_df_value(d) for d in dataflows}
    bucket_logs: dict[tuple[int, str], list[float]] = {}
    df_logs: dict[str, list[float]] = {}
    for key in sorted(cache.entries):
        e = cache.entries[key]
        if e.kind != "gemm":
            continue
        if device_kind is not None and e.device_kind != device_kind:
            continue
        if interpret is not None and e.interpret != interpret:
            continue
        d = str(e.problem.get("dataflow", ""))
        if d not in df_values:
            continue
        M, K, N = (int(e.problem["M"]), int(e.problem["K"]),
                   int(e.problem["N"]))
        if shape_set is not None and (M, K, N) not in shape_set:
            continue
        measured = e.measured_s.get(variant_key(heuristic_blocks(M, K, N)))
        if measured is None or measured <= 0:
            continue
        analytic = analytic_gemm_seconds(M, K, N, d, hw)
        if analytic <= 0:
            continue
        lr = math.log(measured / analytic)
        bucket_logs.setdefault((shape_bucket(M, K, N), d), []).append(lr)
        df_logs.setdefault(d, []).append(lr)

    bucket_scales = {bd: math.exp(sum(ls) / len(ls))
                     for bd, ls in bucket_logs.items()
                     if len(ls) >= min_samples}
    dataflow_scales = {d: math.exp(sum(ls) / len(ls))
                       for d, ls in df_logs.items()}
    return CostCorrection(
        bucket_scales=bucket_scales,
        dataflow_scales=dataflow_scales,
        bucket_samples={bd: len(ls) for bd, ls in bucket_logs.items()},
        device_kind=device_kind or "",
        interpret=interpret,
        n_ratios=sum(len(ls) for ls in df_logs.values()),
        min_samples=min_samples,
    )
