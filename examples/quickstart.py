"""Quickstart: tensorize one layer, search paths, run the DSE, execute —
then compile the DSE result into an execution plan and run *that*, and
finally co-search the hardware architecture itself.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import explore_model, find_topk_paths, tt_linear_network
from repro.hw import ArchSpace, get_target
from repro.nn import LinearSpec, TTConfig, install_plan, linear_apply, linear_init
from repro.plan import ExecutionPlan, compile_plan, execution_log

FPGA_VU9P = get_target("fpga_vu9p")
TPU_V5E = get_target("tpu_v5e")

# 1. A 1024 -> 4096 projection, TT-factorized at rank 16 --------------------
tt = TTConfig(enabled=True, d=3, rank=16, min_dim=512)
spec = LinearSpec("demo", 1024, 4096, tag="mlp", tt=tt)
print(f"dense params: {1024 * 4096:,}   TT params: {spec.n_params():,} "
      f"({1024 * 4096 / spec.n_params():.1f}x compression)")

# 2. The layer as a tensor network; MAC-guided top-K path search ------------
tn = tt_linear_network(batch=256, in_modes=spec.in_modes,
                       out_modes=spec.out_modes, ranks=spec.tt_ranks)
paths = find_topk_paths(tn, k=4)
print("top-K path MACs:", [f"{p.macs:,}" for p in paths])
print(f"dense GEMM MACs: {256 * 1024 * 4096:,}")

# 3. Global latency-driven DSE (Algorithm 1) over (path, split, dataflow) ---
results = {}
for hw in (FPGA_VU9P, TPU_V5E):
    results[hw.name] = res = explore_model([tn], hw, top_k=4)
    c = res.choices[0]
    print(f"{hw.name}: strategy={res.strategy} path={c.path_index} "
          f"partition={c.partitioning} dataflow={c.dataflow.value} "
          f"latency={c.latency_s * 1e6:.1f} us")

# 4. Execute the layer (the DSE-chosen path drives the contraction order) ---
params = linear_init(jax.random.PRNGKey(0), spec)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
y = jax.jit(lambda p, x: linear_apply(spec, p, x))(params, x)
print("forward:", x.shape, "->", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))

# 5. Compile the DSE result into an ExecutionPlan and execute *it* ----------
#    (the search -> compile -> install -> execute loop; docs/plan_format.md)
plan = compile_plan([(spec.name, tn)], results[FPGA_VU9P.name], FPGA_VU9P,
                    arch="quickstart", tokens=256)
lp = plan.layers[0]
print(f"plan: backend={lp.backend} dataflow={lp.dataflow} "
      f"path_steps={list(map(list, lp.path_steps))}")
assert ExecutionPlan.loads(plan.dumps()) == plan  # round-trips bit-equal

install_plan(plan)
y_planned = jax.jit(lambda p, x: linear_apply(spec, p, x))(params, x)
install_plan(None)
err = float(jnp.max(jnp.abs(y_planned - y)))
ran = [(r["name"], r["backend"]) for r in execution_log()]
print(f"planned execution {ran}: max |planned - default| = {err:.2e}")
assert err < 1e-4

# 6. Joint hardware co-search: re-shape the same silicon budget ------------
#    (every feasible PE shape / SRAM split / bandwidth tier of the FPGA)
space = ArchSpace(base=FPGA_VU9P)
co = explore_model([tn], hw_space=space.candidates())
fixed = results[FPGA_VU9P.name]
assert co.total_latency_s <= fixed.total_latency_s  # base is in the space
print(f"hw co-search over {len(co.hw_candidates)} candidates: "
      f"{fixed.total_latency_s * 1e6:.1f} us (fixed {FPGA_VU9P.name}) -> "
      f"{co.total_latency_s * 1e6:.1f} us on {co.hw.name}")
