"""Fully-streaming TT contraction kernel (paper 4.2), TPU-native form.

TT cores are tiny (KBs) — they are pinned whole in VMEM for the entire
kernel (BlockSpec index_map constant in the grid), while activations
stream through in token blocks.  Each grid step contracts one token block
against the full core chain along a DSE-searched path, entirely in VMEM:
one HBM read of X, one HBM write of Y, zero intermediate spills.  This is
the streaming data-reuse property of the paper's FPGA kernel, re-expressed
as a Pallas pipeline.

The contraction path is a *static* argument: the searched pairwise order
is unrolled at trace time inside the kernel body (the same executor as the
pure-jnp reference, applied to VMEM block values).
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.contraction import execute_path
from repro.core.paths import CandidatePath
from repro.core.tensor_network import TensorNetwork, tt_linear_network


def _kernel(
    *refs,
    tn: TensorNetwork,
    path: CandidatePath,
    in_modes: tuple[int, ...],
    out_dim: int,
    block_tokens: int,
):
    x_ref = refs[0]
    core_refs = refs[1:-1]
    o_ref = refs[-1]
    x = x_ref[...].reshape((block_tokens,) + in_modes)
    tensors = {"X": x}
    core_names = [n.name for n in tn.nodes if n.name != "X"]
    for name, ref in zip(core_names, core_refs):
        tensors[name] = ref[...]
    out_edges = ("b",) + tuple(
        f"i{t+1}" for t in range(len(tn.free_edges) - 1)
    )
    y = execute_path(tn, path, tensors, out_edges=out_edges,
                     preferred_dtype=jnp.float32)
    o_ref[...] = y.reshape(block_tokens, out_dim).astype(o_ref.dtype)


def streaming_tt_linear(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    *,
    block_tokens: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Apply a TT-linear layer to ``x`` (tokens, N_in) via the streaming
    kernel.  ``tn``/``path`` must describe a batch equal to ``block_tokens``
    (builders below handle this).  tokens must divide by ``block_tokens``.
    """
    tokens, n_in = x.shape
    if tokens % block_tokens:
        raise ValueError(f"tokens {tokens} not a multiple of {block_tokens}")
    in_modes = tuple(
        d for n in tn.nodes if n.name == "X" for e, d in zip(n.edges, n.dims)
        if e != "b"
    )
    if math.prod(in_modes) != n_in:
        raise ValueError("x inner dim does not match network input modes")
    out_dims = tn.output_dims()
    out_dim = math.prod(d for e, d in out_dims.items() if e != "b")
    out_dtype = out_dtype or x.dtype
    grid = (tokens // block_tokens,)

    x_spec = pl.BlockSpec((block_tokens, n_in), lambda i: (i, 0))
    core_specs = [
        pl.BlockSpec(c.shape, functools.partial(lambda i, nd=c.ndim: (0,) * nd))
        for c in cores
    ]
    o_spec = pl.BlockSpec((block_tokens, out_dim), lambda i: (i, 0))

    kernel = functools.partial(
        _kernel,
        tn=tn,
        path=path,
        in_modes=in_modes,
        out_dim=out_dim,
        block_tokens=block_tokens,
    )
    kwargs = {}
    if not interpret:
        from jax.experimental.pallas import tpu as pltpu

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec] + core_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((tokens, out_dim), out_dtype),
        interpret=interpret,
        **kwargs,
    )(x, *cores)


def build_block_network(
    block_tokens: int,
    in_modes: Sequence[int],
    out_modes: Sequence[int],
    ranks: Sequence[int],
) -> TensorNetwork:
    """The per-block tensor network the kernel contracts (batch = block)."""
    return tt_linear_network(block_tokens, tuple(in_modes), tuple(out_modes),
                             tuple(ranks))
