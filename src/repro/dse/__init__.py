"""``repro.dse`` — the DSE command-line entry point package.

``python -m repro.dse`` runs :func:`repro.dse_cli.main`; the core
algorithm lives in ``repro.core.dse`` (Algorithm 1) and the batched
cost-table engine in ``repro.core.cost_table``.
"""

from repro.dse_cli import main, run_dse

__all__ = ["main", "run_dse"]
