"""Minimal, dependency-free stand-in for the ``hypothesis`` API subset
this test suite uses (``given``, ``settings``, ``strategies.integers /
lists / sampled_from / booleans``).

``conftest.py`` installs it into ``sys.modules`` ONLY when the real
hypothesis is not importable (e.g. the offline container), so CI with
``requirements-dev.txt`` installed still gets real shrinking/replay.
The fallback draws ``max_examples`` pseudo-random examples from a fixed
seed — deterministic across runs, property coverage without the
machinery.
"""

from __future__ import annotations

import functools
import random
import sys
import types

_SEED = 0x5EED
_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5)


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements: _Strategy, min_size: int = 0, max_size: int | None = None) -> _Strategy:
    def draw(r):
        hi = max_size if max_size is not None else min_size + 10
        return [elements.draw(r) for _ in range(r.randint(min_size, hi))]

    return _Strategy(draw)


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Decorator recording ``max_examples``; composes with ``given`` in
    either order (attribute is looked up through the wrapper chain)."""

    def deco(fn):
        if max_examples is not None:
            fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # pytest must see a zero-arg signature (drawn params are not
        # fixtures); functools.wraps leaks the original via __wrapped__
        del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``.strategies``)."""
    mod = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "lists"):
        setattr(strategies, name, getattr(mod, name))
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.__fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
