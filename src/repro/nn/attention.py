"""GQA attention with memory-lean chunked softmax and KV-cache decode.

Training/prefill uses a q-chunked attention (lax.scan over query blocks)
so the materialised score tensor is (B, H, q_block, S) rather than
(B, H, S, S) — at 32k context the full score tensor would dominate the
per-device memory budget.  Decode attends one new token against the cache.

All projections go through ``repro.nn.linear`` and are therefore
tensorizable by the DSE.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import get_rules, shard
from .linear import LinearSpec, TTConfig, linear_apply, linear_init
from .rope import apply_rope, rope_for

_NEG_INF = -1e30


def _shard_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """Prefer HEAD-sharded attention internals over sequence sharding.

    With SP on, constraining q/k/v to the seq axis makes every attention
    einsum a cross-device contraction (measured: ~15 GB/layer/device of
    all-to-all at 4k train, tripled by remat).  When the head count
    divides the model axis, resharding seq->heads at the attention
    boundary costs two ~shard-sized all-to-alls per tensor and makes all
    attention math device-local — the Megatron-SP layout, ~100x less
    traffic.  Falls back to seq sharding when heads don't divide.
    """
    rules = get_rules()
    if rules is None:
        return x
    tp = rules.axis_sizes.get(rules.model_axis or "", 1)
    if tp > 1 and n_heads % tp == 0:
        return shard(x, "batch", None, "model", None)
    return shard(x, "batch", "seq", "model", None)


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope: str = "full"           # full | glm2d | none
    qkv_bias: bool = False
    causal: bool = True
    q_chunk: int = 512
    tt: Optional[TTConfig] = None

    @property
    def q_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wq", self.d_model,
                          self.n_heads * self.head_dim, self.qkv_bias, "attn", self.tt)

    @property
    def k_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wk", self.d_model,
                          self.n_kv_heads * self.head_dim, self.qkv_bias, "attn", self.tt)

    @property
    def v_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wv", self.d_model,
                          self.n_kv_heads * self.head_dim, self.qkv_bias, "attn", self.tt)

    @property
    def o_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wo", self.n_heads * self.head_dim,
                          self.d_model, False, "attn", self.tt)


class KVCache(NamedTuple):
    k: jax.Array     # (B, S_max, H_kv, Dh)
    v: jax.Array


def attention_init(rng: jax.Array, spec: AttentionSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, 4)
    return {
        "wq": linear_init(ks[0], spec.q_spec, dtype),
        "wk": linear_init(ks[1], spec.k_spec, dtype),
        "wv": linear_init(ks[2], spec.v_spec, dtype),
        "wo": linear_init(ks[3], spec.o_spec, dtype),
    }


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """INTERLEAVED kv repeat: repeated head j serves kv head j % hkv —
    the same convention as the grouped (g-major) einsum form, so flat
    and grouped attention paths are interchangeable."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, None, :, :], (b, s, n_rep, h, d)).reshape(
        b, s, n_rep * h, d
    )


def _chunked_attention(
    q: jax.Array,            # (B, Sq, H, Dh)
    k: jax.Array,            # (B, Sk, Hkv, Dh) — kv heads NOT repeated
    v: jax.Array,
    causal: bool,
    q_chunk: int,
    q_offset: int = 0,
) -> jax.Array:
    """Grouped-GQA attention: q heads are grouped per kv head and contract
    against the raw (un-repeated) K/V — the repeated-KV tensor (and its
    fp32 cast) never materialises.  Scores accumulate in fp32 via
    preferred_element_type; operands stay in model dtype.

    Grouping is INTERLEAVED (q head j serves kv head j % hkv): the head
    dim splits as (g major, hkv minor), so when the head dim is TP-sharded
    the 16-divisible group dim inherits the sharding and all attention
    math stays device-local.  (A (hkv, g)-major split would strand the
    sharding on the tiny kv dim — measured 40x collective regression.)
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    chunk = min(q_chunk, sq)
    if sq % chunk:
        chunk = sq  # fall back to a single chunk for ragged sizes
    n_chunks = sq // chunk
    kv_pos = jnp.arange(sk)

    # shardability decides the form: the grouped einsum's score tensor
    # can only head-shard when g divides TP (measured: with g=8 on a
    # 16-way axis the (b,g,hkv,q,k) scores replicate — 64 GiB/device
    # all-gathers).  Otherwise fall back to repeated-KV flat heads (h
    # itself usually divides TP), keeping the fp32-free accumulation.
    rules = get_rules()
    tp = rules.axis_sizes.get(rules.model_axis or "", 1) if rules else 1
    grouped = g > 1 and (tp <= 1 or g % tp == 0)
    if not grouped and g > 1:
        k = _repeat_kv(k, g)
        v = _repeat_kv(v, g)

    if grouped:
        qc = q.reshape(b, n_chunks, chunk, g, hkv, dh).transpose(
            1, 0, 2, 3, 4, 5)                 # (nc, B, chunk, g, Hkv, Dh)
    else:
        qc = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        qi, idx = inp
        if grouped:                        # (B, chunk, g, Hkv, Dh)
            scores = jnp.einsum("bqghd,bkhd->bghqk", qi, k,
                                preferred_element_type=jnp.float32) * scale
        else:                              # (B, chunk, H, Dh)
            scores = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_offset + idx * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]
            mask = mask[None, None, None] if grouped else mask[None, None]
            scores = jnp.where(mask, scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if grouped:
            out = jnp.einsum("bghqk,bkhd->bqghd", probs.astype(v.dtype), v)
        else:
            out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        return carry, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    if grouped:
        return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dh)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def attention_apply(
    spec: AttentionSpec,
    params: dict,
    x: jax.Array,                     # (B, S, D)
    positions: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jax.Array] = None,   # () or (B,): #tokens cached
) -> tuple[jax.Array, Optional[KVCache]]:
    """Returns (output, updated_cache).

    Prefill/train: ``cache is None`` — full-sequence chunked attention.
    Decode: ``cache`` given, ``x`` is (B, 1, D); new KV written at
    ``cache_pos`` and attention runs over the valid prefix.  A (B,)
    ``cache_pos`` gives each lane its own write position and valid
    horizon — the continuous-batching decode form, where every slot of
    the fixed-width batch sits at a different sequence offset.  Each
    lane's output depends only on that lane's (cache, token, position),
    so slot contents never leak across requests.
    """
    b, s, _ = x.shape
    if positions is None:
        base = cache_pos if cache_pos is not None else 0
        base = jnp.asarray(base)
        if base.ndim == 1:
            positions = base[:, None] + jnp.arange(s)[None, :]
        else:
            positions = base + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))

    q = linear_apply(spec.q_spec, params["wq"], x).reshape(b, s, spec.n_heads, spec.head_dim)
    k = linear_apply(spec.k_spec, params["wk"], x).reshape(b, s, spec.n_kv_heads, spec.head_dim)
    v = linear_apply(spec.v_spec, params["wv"], x).reshape(b, s, spec.n_kv_heads, spec.head_dim)

    rp = rope_for(spec.rope)
    if rp is not None:
        frac, base_f = rp
        q = apply_rope(q, positions, base=base_f, rotary_fraction=frac)
        k = apply_rope(k, positions, base=base_f, rotary_fraction=frac)

    q = _shard_heads(q, spec.n_heads)
    k = _shard_heads(k, spec.n_kv_heads)
    v = _shard_heads(v, spec.n_kv_heads)

    if cache is None:
        out = _chunked_attention(q, k, v, spec.causal, spec.q_chunk)
        new_cache = None
    elif s > 1:
        # prefill-with-cache: write the whole prompt's K/V at cache_pos and
        # attend over the local (just-computed) K/V — identical numerics,
        # no per-token cache round-trips
        idx = cache_pos if cache_pos is not None else 0
        if jnp.asarray(idx).ndim == 1:
            raise ValueError(
                "per-lane (B,) cache_pos is decode-only; prefill writes "
                "one contiguous prompt per call (the serve scheduler "
                "prefills each request at batch 1)")
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), idx, axis=1)
        new_cache = KVCache(ck, cv)
        out = _chunked_attention(q, k, v, spec.causal, spec.q_chunk)
    else:
        idx = cache_pos if cache_pos is not None else 0
        idx = jnp.asarray(idx)
        kv_pos = jnp.arange(cache.k.shape[1])
        if idx.ndim == 1:
            # per-lane positions: one-hot write (bit-exact equivalent of
            # a per-lane dynamic_update_slice) + per-lane valid horizon
            sel = kv_pos[None, :] == idx[:, None]                # (B, S)
            ck = jnp.where(sel[:, :, None, None], k.astype(cache.k.dtype),
                           cache.k)
            cv = jnp.where(sel[:, :, None, None], v.astype(cache.v.dtype),
                           cache.v)
            vmask = (kv_pos[None, :] <= idx[:, None])[:, None, None, None, :]
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), idx, axis=1)
            vmask = (kv_pos <= idx)[None, None, None, None, :]
        new_cache = KVCache(ck, cv)
        hkv = spec.n_kv_heads
        g = spec.n_heads // hkv
        scale = 1.0 / math.sqrt(spec.head_dim)
        qg = q.reshape(b, 1, g, hkv, spec.head_dim)   # interleaved grouping
        # grouped decode: raw cache contracted directly (no repeat, no
        # fp32 cache cast — fp32 lives only in the score accumulator)
        scores = jnp.einsum("bqghd,bkhd->bghqk", qg, ck,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(vmask, scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bghqk,bkhd->bqghd", probs.astype(cv.dtype), cv)
        out = out.reshape(b, 1, spec.n_heads, spec.head_dim)

    out = out.reshape(b, s, spec.n_heads * spec.head_dim)
    y = linear_apply(spec.o_spec, params["wo"], out)
    return shard(y, "batch", "seq", None), new_cache


def init_kv_cache(spec: AttentionSpec, batch: int, max_seq: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, spec.n_kv_heads, spec.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
