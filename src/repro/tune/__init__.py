"""Empirical autotuning: close the loop from analytic DSE to wall-clock.

The analytic cost model ranks configurations; this subsystem *measures*
them — real Pallas kernel variants, on the machine serving traffic —
and feeds the measurements back into the two consumers:

- **plan compilation**: ``compile_plan(..., tilings="measured",
  tuner=...)`` replaces the heuristic per-layer tilings with the
  measured argmin per unique (GEMM shape, dataflow) / streaming problem;
- **the DSE itself**: ``global_search(..., calibration=...)`` rescales
  the analytic table per dataflow by measured/analytic ratios, so the
  argmin can genuinely change when measurements disagree with the model.

Measurements live in a persistent, canonical-JSON cache keyed by
(problem, backend, device kind, interpret flag) — a warm cache replays
with zero measurements, making tuned plans reproducible bit-for-bit.

CLI: ``python -m repro.tune`` (warm the cache), ``python -m repro.dse
--tune {off,cache,measure}`` (calibrated search + measured plan tilings).
"""

from .autotune import (
    TUNE_MODES,
    Autotuner,
    analytic_gemm_seconds,
    gemm_work_items,
    heuristic_blocks,
    measured_calibration,
)
from .cache import (
    CACHE_FORMAT,
    CACHE_VERSION,
    DEFAULT_CACHE_PATH,
    KERNEL_MODULES,
    TuningCache,
    TuningEntry,
    entry_fingerprint,
    entry_shards,
    kernel_fingerprint,
    merge_caches,
    parse_variant,
    variant_key,
)
from .correction import (
    MIN_BUCKET_SAMPLES,
    SHAPE_BUCKET_LOG2_WIDTH,
    CostCorrection,
    fit_cost_correction,
    shape_bucket,
)
from .measure import (
    default_interpret,
    device_kind,
    measure_callable,
    measure_fused,
    measure_gemm,
    measure_per_step,
    measure_streaming,
)
from .variants import (
    GEMM_BLOCK_CAPS,
    STREAM_BLOCK_CAPS,
    block_candidates,
    dominant_gemm,
    fused_token_variants,
    gemm_variants,
    network_signature,
    streaming_variants,
)

__all__ = [
    "TUNE_MODES", "Autotuner", "analytic_gemm_seconds", "gemm_work_items",
    "heuristic_blocks", "measured_calibration",
    "CACHE_FORMAT", "CACHE_VERSION", "DEFAULT_CACHE_PATH", "KERNEL_MODULES",
    "TuningCache", "TuningEntry", "entry_fingerprint", "entry_shards",
    "kernel_fingerprint", "merge_caches", "parse_variant", "variant_key",
    "MIN_BUCKET_SAMPLES", "SHAPE_BUCKET_LOG2_WIDTH", "CostCorrection",
    "fit_cost_correction", "shape_bucket",
    "default_interpret", "device_kind", "measure_callable", "measure_fused",
    "measure_gemm", "measure_per_step", "measure_streaming",
    "GEMM_BLOCK_CAPS", "STREAM_BLOCK_CAPS", "block_candidates",
    "dominant_gemm", "fused_token_variants", "gemm_variants",
    "network_signature", "streaming_variants",
]
