"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2 backbone.

Assigned dims: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf].  The ViT frontend is a STUB: ``input_specs()``
supplies 256 precomputed patch embeddings (B, 256, D) prepended to the
text tokens.

vocab 92553 is odd (not shardable on the model axis) -> chunked loss.
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92_553,
    head_dim=128,
    frontend="patches",
    n_frontend_tokens=256,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="internvl2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=253,        # odd vocab: exercises the chunked loss
    head_dim=16,
    n_frontend_tokens=8,
    loss_chunk=8,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
