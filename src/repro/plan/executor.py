"""Planned execution: route a TT contraction through its LayerPlan backend.

Entry point is :func:`planned_tt_linear` — called by
``repro.nn.linear.linear_apply`` when a plan entry is installed for the
projection.  Three backends:

- ``jnp``        — the pure-jnp reference executor (``kernels/ref.py``)
                   along the plan's path steps: numerical ground truth.
- ``streaming_tt`` — the fused in-VMEM Pallas kernel: cores pinned whole
                   in VMEM, activations streamed in ``block_tokens``
                   blocks, the entire searched path unrolled inside the
                   kernel body (``kernels/streaming_tt.py``).
- ``tt_gemm``    — every pairwise contraction of the path lowered to the
                   dataflow-configurable Pallas GEMM
                   (``kernels/tt_gemm.py``) with the plan's IS/OS/WS grid
                   order and <T_M, T_K, T_N> block shapes.  Any pairwise
                   tensor contraction *is* a GEMM (free-edges x
                   shared-edges reshape), which is the paper's §3.1 view.

Every planned call appends a record to a trace-time execution log —
``execution_log()`` — so callers (tests, the serve driver) can assert
*which* path/dataflow/kernel actually executed.  Under ``jit`` the record
is appended once per trace, not per step.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.contraction import core_tensors, execute_path
from repro.core.paths import CandidatePath
from repro.core.tensor_network import TensorNetwork, tt_linear_network
from repro.kernels import ops, ref

from .schema import LayerPlan

# ---------------------------------------------------------------------------
# trace-time execution log
# ---------------------------------------------------------------------------

_EXEC_LOG: list[dict] = []


def reset_execution_log() -> None:
    _EXEC_LOG.clear()


def execution_log() -> tuple[dict, ...]:
    """Records of planned executions since the last reset (trace-time)."""
    return tuple(_EXEC_LOG)


def record_execution(lp: LayerPlan, tokens: int) -> None:
    """Append one planned-execution record (called at trace time)."""
    _EXEC_LOG.append({
        "name": lp.name,
        "backend": lp.backend,
        "dataflow": lp.dataflow,
        "path_index": lp.path_index,
        "path_steps": lp.path_steps,
        "tokens": tokens,
    })


# ---------------------------------------------------------------------------
# path plumbing
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _clamp_block(block: int, dim: int) -> int:
    """Shrink a compile-time block to the runtime dim (power of two, >= 8).

    The DSE tiles for its search-time token count; at execution time a
    decode step may carry only a handful of tokens, and padding it up to
    the full plan block would compute mostly zeros.  Clamping to the next
    power of two >= dim keeps a single (minimally padded) block.
    """
    return max(8, min(block, _next_pow2(dim)))


def as_candidate_path(tn: TensorNetwork, steps) -> CandidatePath:
    """Reconstruct a CandidatePath (with GEMM shapes) from raw plan steps."""
    steps = tuple(tuple(s) for s in steps)
    gemms = tuple(tn.gemm_sequence(steps))
    return CandidatePath(steps, sum(g.macs for g in gemms), gemms)


def _gemm_contract(lp: LayerPlan, interpret: Optional[bool]):
    """A per-step ``contract_fn`` for ``execute_path`` that lowers each
    pairwise contraction to the dataflow-configurable Pallas GEMM.

    Operands are transposed to (free..., shared...) / (shared..., free...)
    and flattened to (M, K) @ (K, N); the result keeps tensordot's axis
    order (A's free axes then B's), so all the edge bookkeeping stays in
    ``core.contraction.execute_path``.
    """

    def contract(ta: jax.Array, tb: jax.Array, axes) -> jax.Array:
        ax_a, ax_b = axes
        a_free = [i for i in range(ta.ndim) if i not in ax_a]
        b_free = [i for i in range(tb.ndim) if i not in ax_b]
        a_dims = [ta.shape[i] for i in a_free]
        b_dims = [tb.shape[i] for i in b_free]
        m = math.prod(a_dims) if a_dims else 1
        n = math.prod(b_dims) if b_dims else 1
        k = math.prod(ta.shape[i] for i in ax_a) if ax_a else 1
        a2 = jnp.transpose(ta, a_free + list(ax_a)).reshape(m, k)
        b2 = jnp.transpose(tb, list(ax_b) + b_free).reshape(k, n)
        c2 = ops.gemm(a2, b2, dataflow=lp.dataflow,
                      block_m=_clamp_block(lp.tiling.block_m, m),
                      block_k=_clamp_block(lp.tiling.block_k, k),
                      block_n=_clamp_block(lp.tiling.block_n, n),
                      interpret=interpret)
        return c2.reshape(tuple(a_dims) + tuple(b_dims))

    return contract


# ---------------------------------------------------------------------------
# the planned TT-linear entry point
# ---------------------------------------------------------------------------

def planned_tt_linear(
    lp: LayerPlan,
    x2d: jax.Array,
    cores: Sequence[jax.Array],
    in_modes: tuple[int, ...],
    out_modes: tuple[int, ...],
    ranks: tuple[int, ...],
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Apply one planned TT projection to ``x2d: (tokens, d_in)``.

    Returns ``(tokens, d_out)``.  The plan's ``path_steps`` are replayed
    verbatim; the backend decides *how* each step runs.
    """
    tokens = x2d.shape[0]
    record_execution(lp, tokens)

    if lp.backend == "streaming_tt":
        bt = _clamp_block(lp.tiling.block_tokens, tokens)
        tn_block = tt_linear_network(bt, in_modes, out_modes, ranks)
        path = as_candidate_path(tn_block, lp.path_steps)
        return ops.tt_linear(x2d, cores, tn_block, path,
                             block_tokens=bt, interpret=interpret)

    tn = tt_linear_network(tokens, in_modes, out_modes, ranks)
    if lp.backend == "tt_gemm":
        tensors = {"X": x2d.reshape((tokens,) + tuple(in_modes))}
        tensors.update(core_tensors(tn, cores))
        out_edges = ("b",) + tuple(f"i{t + 1}" for t in range(len(out_modes)))
        y = execute_path(tn, lp.path_steps, tensors, out_edges=out_edges,
                         contract_fn=_gemm_contract(lp, interpret))
        return y.reshape(tokens, -1)

    # "jnp": the reference executor along the planned steps
    path = as_candidate_path(tn, lp.path_steps)
    return ref.tt_linear_ref(x2d, cores, tn, path)
