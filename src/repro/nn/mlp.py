"""Feed-forward blocks: SwiGLU / GELU, dense or TT-factorized."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard
from .linear import LinearSpec, TTConfig, linear_apply, linear_init


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    name: str
    d_model: int
    d_ff: int
    kind: str = "swiglu"          # swiglu | gelu
    tt: Optional[TTConfig] = None

    @property
    def gate_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wg", self.d_model, self.d_ff, False, "mlp", self.tt)

    @property
    def up_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wu", self.d_model, self.d_ff, False, "mlp", self.tt)

    @property
    def down_spec(self) -> LinearSpec:
        return LinearSpec(f"{self.name}.wd", self.d_ff, self.d_model, False, "mlp", self.tt)


def mlp_init(rng: jax.Array, spec: MLPSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, 3)
    params = {
        "wu": linear_init(ks[1], spec.up_spec, dtype),
        "wd": linear_init(ks[2], spec.down_spec, dtype),
    }
    if spec.kind == "swiglu":
        params["wg"] = linear_init(ks[0], spec.gate_spec, dtype)
    return params


def mlp_apply(spec: MLPSpec, params: dict, x: jax.Array) -> jax.Array:
    up = linear_apply(spec.up_spec, params["wu"], x)
    up = shard(up, "batch", "seq", "model")
    if spec.kind == "swiglu":
        gate = linear_apply(spec.gate_spec, params["wg"], x)
        gate = shard(gate, "batch", "seq", "model")
        h = jax.nn.silu(gate) * up
    elif spec.kind == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(spec.kind)
    y = linear_apply(spec.down_spec, params["wd"], h)
    return shard(y, "batch", "seq", None)
