"""grok-1-314b [moe] — 8 experts, top-2 routing.

Assigned dims: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 (per expert)
vocab=131072, MoE 8e top-2  [hf:xai-org/grok-1; unverified].
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

_TT = TTConfig(enabled=True, d=3, rank=16, min_dim=512,
               targets=("attn", "mlp", "head", "moe", "embed"))

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131_072,
    head_dim=128,
    moe_experts=8,
    moe_top_k=2,
    loss_chunk=256,
    tt=_TT,
)

SMOKE = FULL.with_(
    name="grok-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    moe_experts=4,
    moe_top_k=2,
    dtype="float32",
    remat="none",
    q_chunk=16,
    tt=TTConfig(enabled=True, d=2, rank=4, min_dim=32,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
