"""End-to-end integration: real training loop on a tiny TT LM —
loss decreases, checkpoint/restart is bit-exact, serving works."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_pipeline
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import api
from repro.optim import adamw_init, linear_warmup_cosine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tt-lm-100m", smoke=True).with_(vocab=128, n_layers=2,
                                                     d_model=64, d_ff=128)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    pipe = make_pipeline(cfg.vocab, seq_len=32, global_batch=4, seed=0)
    step = jax.jit(make_train_step(cfg, lr=linear_warmup_cosine(1e-2, 5, 60)))
    return cfg, m, params, pipe, step


@pytest.mark.slow
def test_training_reduces_loss(setup):
    cfg, m, params, pipe, step = setup
    opt = adamw_init(params)
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


@pytest.mark.slow
def test_checkpoint_restart_bit_exact(setup):
    """Stateless data + checkpointing => restart reproduces the exact same
    trajectory (the fault-tolerance contract)."""
    cfg, m, params0, pipe, step = setup

    def run(start_params, start_opt, a, b):
        p, o = start_params, start_opt
        for i in range(a, b):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
            p, o, _ = step(p, o, batch)
        return p, o

    opt0 = adamw_init(params0)
    # straight run 0..8
    p_direct, _ = run(params0, opt0, 0, 8)
    # run 0..4, checkpoint, restore, run 4..8
    p_mid, o_mid = run(params0, opt0, 0, 4)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(4, {"params": p_mid, "opt": o_mid})
        _, restored = mgr.restore({"params": p_mid, "opt": o_mid})
    p_resumed, _ = run(restored["params"], restored["opt"], 4, 8)
    for a, b in zip(jax.tree.leaves(p_direct), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_prefill_decode_roundtrip(setup):
    cfg, m, params, pipe, step = setup
    prefill = jax.jit(make_prefill_step(cfg, max_seq=16))
    decode = jax.jit(make_decode_step(cfg))
    toks = jnp.asarray(np.arange(8)[None, :] % cfg.vocab, jnp.int32)
    logits, caches = prefill(params, {"tokens": toks})
    for i in range(4):
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(8 + i, jnp.int32))
    assert logits.shape == (1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
