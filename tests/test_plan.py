"""Execution-plan subsystem: schema round-trip, compiler, planned execution.

Covers the acceptance criteria of the plan PR: (1) serialize ->
deserialize -> re-serialize is byte-identical; (2) an installed plan
changes which path/dataflow/kernel executes (asserted via the trace-time
execution log); (3) planned outputs match the pure-jnp reference within
fp tolerance, per backend and at model level; (4) the emitted-plan ->
serve --plan loop works end to end (subprocess, slow).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FPGA_VU9P, find_topk_paths
from repro.core.dse import global_search
from repro.nn import (
    LinearSpec,
    TTConfig,
    install_plan,
    linear_apply,
    linear_init,
    planned_layer,
    planned_path_index,
)
from repro.plan import (
    BACKENDS,
    ExecutionPlan,
    LayerPlan,
    Tiling,
    compile_plan,
    execution_log,
    load_plan,
    reset_execution_log,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _clean_plan_state():
    install_plan(None)
    reset_execution_log()
    yield
    install_plan(None)
    reset_execution_log()


def _unit_problem(tokens=32):
    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P)
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P,
                        arch="unit", tokens=tokens)
    return spec, tn, res, plan


# ---------------------------------------------------------------------------
# schema round-trip
# ---------------------------------------------------------------------------

def test_plan_roundtrip_bit_equal(tmp_path):
    _, _, _, plan = _unit_problem()
    text = plan.dumps()
    again = ExecutionPlan.loads(text)
    assert again == plan
    assert again.dumps() == text  # canonical: re-serialization is byte-equal

    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = load_plan(path)
    assert loaded == plan
    assert loaded.dumps() == text


def test_v1_plan_migrates_to_current_bit_equal(tmp_path):
    """A v1 plan (no ``backward`` entries, no ``hardware``) loads,
    upgrades through every migration, and the migrated serialization
    round-trips byte-identically."""
    _, _, _, plan = _unit_problem()
    d = plan.to_json()
    d["version"] = 1
    d.pop("hardware")
    for layer in d["layers"]:
        layer.pop("backward")
        layer.pop("bwd_latency_s")
    v1_text = json.dumps(d, indent=2, sort_keys=True) + "\n"

    migrated = ExecutionPlan.loads(v1_text)
    from repro.plan import PLAN_FORMAT_VERSION

    assert migrated.version == PLAN_FORMAT_VERSION == 4
    assert all(lp.backward == () for lp in migrated.layers)
    # everything but the version/backward/hardware fields survives untouched
    assert migrated.names == plan.names
    assert [lp.path_steps for lp in migrated.layers] == [
        lp.path_steps for lp in plan.layers]

    text = migrated.dumps()
    assert ExecutionPlan.loads(text).dumps() == text  # bit-equal
    # migration is idempotent at the JSON level too
    from repro.plan import migrate_plan_json

    assert migrate_plan_json(json.loads(text)) == json.loads(text)


def test_v2_plan_migrates_to_v3_with_registry_hardware():
    """v2 -> v3 resolves ``hardware`` from the ``hw`` name through the
    repro.hw registry; unregistered names migrate with hardware=None.
    Either way the migrated serialization is bit-stable."""
    from repro.hw import get_target
    from repro.plan import migrate_plan_json

    _, _, _, plan = _unit_problem()
    d = plan.to_json()
    d["version"] = 2
    d.pop("hardware")
    v2_text = json.dumps(d, indent=2, sort_keys=True) + "\n"

    migrated = ExecutionPlan.loads(v2_text)
    assert migrated.version == 4
    assert migrated.hardware == get_target("fpga_vu9p")
    text = migrated.dumps()
    assert ExecutionPlan.loads(text).dumps() == text
    assert migrate_plan_json(json.loads(text)) == json.loads(text)

    # unregistered hw name: plan still loads, provenance is just absent
    d["hw"] = "asic_rev_b"
    orphan = ExecutionPlan.from_json(json.loads(json.dumps(d)))
    assert orphan.hardware is None
    assert ExecutionPlan.loads(orphan.dumps()).dumps() == orphan.dumps()


def test_v3_plan_embeds_searched_hardware():
    """A freshly compiled plan embeds the architecture it was compiled
    for, and the embedded config survives the canonical round-trip."""
    from repro.hw import FPGA_VU9P as BASE

    _, _, _, plan = _unit_problem()
    assert plan.version == 4
    assert plan.hardware == BASE
    again = ExecutionPlan.loads(plan.dumps())
    assert again.hardware == plan.hardware
    # non-HardwareConfig payloads are rejected at construction
    import dataclasses

    with pytest.raises(ValueError, match="hardware"):
        dataclasses.replace(plan, hardware={"pe_rows": 32})


def test_train_plan_backward_ops_roundtrip():
    from repro.core import memoised_layer_backwards
    from repro.plan import BackwardOp

    tokens = 32
    tt = TTConfig(enabled=True, d=2, rank=8, min_dim=64)
    spec = LinearSpec("demo", 128, 256, tag="mlp", tt=tt)
    tn = spec.network(tokens)
    res = global_search([find_topk_paths(tn, k=4)], FPGA_VU9P,
                        objective="train-latency",
                        layer_backwards=memoised_layer_backwards([tn], k=4))
    plan = compile_plan([("demo", tn)], res, FPGA_VU9P,
                        arch="unit", objective="train-latency", tokens=tokens)
    lp = plan.layers[0]
    assert [op.wrt for op in lp.backward] == ["dx", "G1", "G2", "G3", "G4"]
    assert all(op.backend in BACKENDS and op.path_steps
               for op in lp.backward)
    text = plan.dumps()
    again = ExecutionPlan.loads(text)
    assert again == plan and again.dumps() == text
    # BackwardOp validation: streaming is dx-only
    with pytest.raises(ValueError, match="streaming"):
        BackwardOp("G1", 0, ((0, 1),), "streaming_tt")


def test_plan_version_and_format_guard():
    _, _, _, plan = _unit_problem()
    d = plan.to_json()
    d["version"] = 999
    with pytest.raises(ValueError, match="version"):
        ExecutionPlan.from_json(d)
    d = plan.to_json()
    d["format"] = "something-else"
    with pytest.raises(ValueError, match="format"):
        ExecutionPlan.from_json(d)


def test_layer_plan_validation():
    with pytest.raises(ValueError, match="dataflow"):
        LayerPlan("x", 0, (), "XX", (1, 1), "jnp")
    with pytest.raises(ValueError, match="backend"):
        LayerPlan("x", 0, (), "OS", (1, 1), "cuda")
    with pytest.raises(ValueError, match="tiling"):
        Tiling(block_m=0)


def test_compiler_collapses_instances():
    from repro.dse_cli import run_dse_plan

    report, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=32)
    # instances attn.wq[0..1] etc. collapse to one plan per projection family
    assert all("[" not in n for n in plan.names)
    wq = plan.layer("attn.wq")
    assert wq is not None and wq.instances == 2
    assert plan.layer("head").instances == 1
    assert report["n_layers"] == sum(lp.instances for lp in plan.layers)
    # every plan carries executable steps and a known backend
    for lp in plan.layers:
        assert lp.path_steps and lp.backend in BACKENDS


# ---------------------------------------------------------------------------
# planned execution: routing + numerics
# ---------------------------------------------------------------------------

def test_install_plan_changes_execution_and_matches_reference():
    spec, _, res, plan = _unit_problem()
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, spec.d_in))

    y_ref = linear_apply(spec, params, x)     # no plan: default executor
    assert execution_log() == ()              # nothing planned executed

    for backend in BACKENDS:
        reset_execution_log()
        install_plan(plan.with_backend(backend))
        y = linear_apply(spec, params, x)
        log = execution_log()
        assert len(log) == 1, f"{backend}: planned execution not recorded"
        rec = log[0]
        assert rec["name"] == "demo"
        assert rec["backend"] == backend      # the plan changed the kernel
        assert rec["dataflow"] == res.choices[0].dataflow.value
        assert rec["path_steps"] == res.choices[0].path.steps
        tol = 0 if backend == "jnp" else 1e-5
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=tol, atol=tol,
                                   err_msg=f"backend {backend}")


def test_planned_execution_under_jit_and_3d_batch():
    spec, _, _, plan = _unit_problem()
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, spec.d_in))
    y_ref = linear_apply(spec, params, x)
    install_plan(plan.with_backend("tt_gemm"))
    y = jax.jit(lambda p, x: linear_apply(spec, p, x))(params, x)
    assert y.shape == (2, 16, spec.d_out)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_explicit_path_index_overrides_plan():
    spec, tn, _, plan = _unit_problem()
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, spec.d_in))
    install_plan(plan.with_backend("streaming_tt"))
    reset_execution_log()
    y = linear_apply(spec, params, x, path_index=0)
    assert execution_log() == ()  # explicit index bypasses the plan
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(linear_apply(spec, params, x,
                                                       path_index=0)))


def test_legacy_dict_install_still_works():
    spec, _, _, _ = _unit_problem()
    install_plan({"demo": 1})
    assert planned_path_index("demo") == 1
    lp = planned_layer("demo")
    assert lp is not None and lp.backend == "jnp" and lp.path_steps == ()
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, spec.d_in))
    y = linear_apply(spec, params, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(linear_apply(spec, params, x, path_index=1)),
        rtol=0, atol=0)


def test_model_prefill_planned_matches_unplanned():
    """Whole-model numerics: a planned smoke LM prefill == unplanned."""
    from repro.configs import get_config
    from repro.dse_cli import run_dse_plan
    from repro.models import api

    cfg = get_config("tt-lm-100m", smoke=True)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)), jnp.int32)}
    logits_ref, _ = m.prefill(params, batch, 8)

    _, plan = run_dse_plan("tt-lm-100m", smoke=True, top_k=2, tokens=16)
    reset_execution_log()
    m_planned = api(cfg, plan=plan)
    logits, _ = m_planned.prefill(params, batch, 8)
    assert len(execution_log()) > 0  # planned kernels actually ran
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-4, atol=2e-4)


def test_batch_dim_and_rebatch_handle_conv_networks():
    """The batch edge is the input node's free edge — trailing 'l' for
    conv networks, not dims[0] (which is an input-channel mode)."""
    from repro.core.tensor_network import tt_conv_network
    from repro.plan import batch_dim
    from repro.plan.compiler import rebatch

    tn = tt_conv_network(patches=64, in_modes=(4, 8), out_modes=(8, 4),
                         kernel=9, ranks=(4, 4, 4, 4))
    assert batch_dim(tn) == 64
    rb = rebatch(tn, 16)
    x = next(n for n in rb.nodes if n.kind == "input")
    assert x.dims[x.edges.index("l")] == 16     # batch rebinds
    assert x.dims[x.edges.index("i1")] == 4     # modes untouched


def test_validate_plan_catches_mismatches():
    from repro.configs import get_config
    from repro.plan import check_plan_for_config, validate_plan

    _, _, _, plan = _unit_problem()
    # wrong geometry: same name, but a d=3 network needs 6 steps, not 4
    tt3 = TTConfig(enabled=True, d=3, rank=8, min_dim=64)
    tn3 = LinearSpec("demo", 512, 512, tag="mlp", tt=tt3).network(32)
    problems = validate_plan(plan, [("demo", tn3)])
    assert problems and "contraction steps" in problems[0]
    # no name overlap at all
    problems = validate_plan(plan, [("other", tn3)])
    assert problems and "matches no tensorized projection" in problems[0]
    # driver guard: arch provenance + structure against a real config
    cfg = get_config("tt-lm-100m", smoke=True)
    problems = check_plan_for_config(plan, "tt-lm-100m", cfg)
    assert any("matches no tensorized projection" in p for p in problems)
    import dataclasses
    foreign = dataclasses.replace(plan, arch="glm4-9b")
    problems = check_plan_for_config(foreign, "tt-lm-100m", cfg)
    assert any("emitted for arch" in p for p in problems)
    # out-of-range step indices (right count, bogus values) are caught too
    _, tn, _, _ = _unit_problem()
    bad_lp = dataclasses.replace(
        plan.layers[0],
        path_steps=((9, 10),) + plan.layers[0].path_steps[1:])
    bad = dataclasses.replace(plan, layers=(bad_lp,))
    problems = validate_plan(bad, [("demo", tn)])
    assert problems and "step indices" in problems[0]
    # empty steps are only legitimate on jnp entries
    stepless = dataclasses.replace(
        plan, layers=(dataclasses.replace(
            plan.layers[0], path_steps=(), backend="streaming_tt"),))
    problems = validate_plan(stepless, [("demo", tn)])
    assert problems and "index-only" in problems[0]


def test_force_backend_rejected_on_stepless_entries():
    install_plan({"demo": 0}, force_backend="jnp")  # jnp is fine
    with pytest.raises(ValueError, match="path steps"):
        install_plan({"demo": 0}, force_backend="tt_gemm")


def test_api_plan_state_semantics():
    """api(cfg) leaves plan state untouched (internal dispatch safety);
    api(cfg, plan=None) explicitly clears; plan_backend needs a plan."""
    from repro.configs import get_config
    from repro.models import api

    cfg = get_config("tt-lm-100m", smoke=True)
    api(cfg, plan={"attn.wq": 1})
    assert planned_path_index("attn.wq") == 1
    api(cfg)  # plan omitted: the installed plan survives
    assert planned_path_index("attn.wq") == 1
    api(cfg, plan=None)  # explicit clear
    assert planned_layer("attn.wq") is None
    with pytest.raises(ValueError, match="plan_backend"):
        api(cfg, plan_backend="jnp")


def test_kernel_routing_restricted_to_single_device():
    """Planned kernels run locally only on a single-device mesh; with
    multi-device rules the dispatcher asks ``shard_decision`` for a
    shard_map route and takes the sharding-preserving jnp executor only
    when the mesh cannot take the problem (rules without a real mesh
    object here, so the decision declines — tests/test_shard_exec.py
    covers the accepting side)."""
    from repro.nn.linear import _single_device
    from repro.plan.sharded import shard_decision
    from repro.sharding import ShardingRules, use_rules

    assert _single_device()
    with use_rules(ShardingRules(axis_sizes={"data": 1, "model": 1})):
        assert _single_device()
    with use_rules(ShardingRules(axis_sizes={"data": 2, "model": 1})):
        assert not _single_device()
        from repro.sharding import get_rules

        # no mesh object installed -> no shard route -> jnp fallback
        assert shard_decision(get_rules(), 64, (8, 8)) is None


def test_tiling_clamped_to_runtime_shapes():
    from repro.kernels.ops import clamp_block

    assert clamp_block(256, 4) == 8      # decode-step batch: one tiny block
    assert clamp_block(256, 100) == 128  # next pow2 >= dim
    assert clamp_block(64, 1000) == 64   # plan block already smaller

    # behavioural: a plan compiled at 32 tokens executes correctly (and
    # without inflating to the plan block) on an 8-token batch
    spec, _, _, plan = _unit_problem(tokens=32)
    params = linear_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, spec.d_in))
    y_ref = linear_apply(spec, params, x)
    for backend in ("streaming_tt", "tt_gemm"):
        install_plan(plan.with_backend(backend))
        y = linear_apply(spec, params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"backend {backend}")
    install_plan(None)


# ---------------------------------------------------------------------------
# end-to-end: emit-plan CLI -> serve --plan (the acceptance loop)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_emit_plan_then_serve_subprocess(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # an earlier in-process import of repro.launch.dryrun exports
    # XLA_FLAGS=--xla_force_host_platform_device_count=N; serve would then
    # build a multi-device mesh and (correctly) fall back to the jnp
    # executor — this test wants the single-device kernel route
    env.pop("XLA_FLAGS", None)
    plan_path = str(tmp_path / "plan.json")
    res = subprocess.run(
        [sys.executable, "-m", "repro.dse", "--arch", "tt-lm-100m", "--smoke",
         "--top-k", "2", "--tokens", "32", "--emit-plan", plan_path,
         "--out", str(tmp_path / "report.json")],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    plan = load_plan(plan_path)
    assert len(plan.layers) > 0

    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "tt-lm-100m",
         "--smoke", "--plan", plan_path, "--batch", "2", "--prompt-len", "8",
         "--gen", "2"],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "installed plan" in res.stdout
    assert "planned executions" in res.stdout
    # the log line proves non-jnp kernels were selected by the plan
    assert "streaming_tt" in res.stdout or "tt_gemm" in res.stdout
