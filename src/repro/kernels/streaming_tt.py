"""Fully-streaming TT contraction kernel (paper 4.2), TPU-native form.

TT cores are tiny (KBs) — they are pinned whole in VMEM for the entire
kernel (BlockSpec index_map constant in the grid), while activations
stream through in token blocks.  Each grid step contracts one token block
against the full core chain along a DSE-searched path, entirely in VMEM:
one HBM read of X, one HBM write of Y, zero intermediate spills.  This is
the streaming data-reuse property of the paper's FPGA kernel, re-expressed
as a Pallas pipeline.

The contraction path is a *static* argument: the searched pairwise order
is unrolled at trace time inside the kernel body (the same executor as the
pure-jnp reference, applied to VMEM block values).

The streamed operand is whichever node has ``kind == "input"`` — the
forward activations ``X``, or the output gradient ``dY`` of a
``repro.core.backward`` dx-network (the backward pass of a TT layer is
itself a streaming TT contraction: same pinned cores, gradient streamed).
:func:`streaming_tt_linear_vjp` packages that into a ``jax.custom_vjp``
so the kernel composes with ``jax.grad``.
"""

from __future__ import annotations

import functools
import math
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.backward import GRAD_NODE, backward_networks
from repro.core.contraction import execute_path
from repro.core.paths import CandidatePath, find_topk_paths
from repro.core.tensor_network import Node, TensorNetwork, tt_linear_network


def _stream_node(tn: TensorNetwork) -> Node:
    """The single streamed (kind == "input") node of the network."""
    inputs = [n for n in tn.nodes if n.kind == "input"]
    if len(inputs) != 1:
        raise ValueError(
            "streaming kernel needs exactly one streamed node, found "
            f"{[n.name for n in inputs]}")
    return inputs[0]


def _stream_layout(tn: TensorNetwork):
    """(stream_name, batch_edge, in_modes, out_edges, out_dim).

    The streamed node must carry exactly one free (batch) edge, leading;
    its remaining (shared) edges are the flattened inner dim of the 2-d
    operand.  ``out_edges`` orders the result as batch edge first, then
    the weight-side free edges in node order — the row-major layout of
    the 2-d output.
    """
    x = _stream_node(tn)
    free = set(tn.free_edges)
    batch_edges = [e for e in x.edges if e in free]
    if len(batch_edges) != 1 or x.edges[0] != batch_edges[0]:
        raise ValueError(
            f"streamed node {x.name}: need a single leading batch edge, "
            f"got edges {x.edges} (free: {batch_edges})")
    batch = batch_edges[0]
    in_modes = tuple(d for e, d in zip(x.edges, x.dims) if e != batch)
    out = [
        (e, d)
        for n in tn.nodes if n.kind != "input"
        for e, d in zip(n.edges, n.dims) if e in free
    ]
    out_edges = (batch,) + tuple(e for e, _ in out)
    out_dim = math.prod((d for _, d in out), start=1)
    return x.name, batch, in_modes, out_edges, out_dim


def _kernel(
    *refs,
    tn: TensorNetwork,
    path: CandidatePath,
    stream_name: str,
    in_modes: tuple[int, ...],
    out_edges: tuple[str, ...],
    out_dim: int,
    block_tokens: int,
):
    x_ref = refs[0]
    core_refs = refs[1:-1]
    o_ref = refs[-1]
    x = x_ref[...].reshape((block_tokens,) + in_modes)
    tensors = {stream_name: x}
    core_names = [n.name for n in tn.nodes if n.kind != "input"]
    for name, ref in zip(core_names, core_refs):
        tensors[name] = ref[...]
    y = execute_path(tn, path, tensors, out_edges=out_edges,
                     preferred_dtype=jnp.float32)
    o_ref[...] = y.reshape(block_tokens, out_dim).astype(o_ref.dtype)


def streaming_tt_linear(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    *,
    block_tokens: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Apply a streaming TT contraction to ``x`` (tokens, N_in).

    ``tn``/``path`` must describe a batch equal to ``block_tokens``
    (builders below handle this); ``x`` is the network's streamed node
    flattened to 2-d.  tokens must divide by ``block_tokens``.
    """
    tokens, n_in = x.shape
    if tokens % block_tokens:
        raise ValueError(f"tokens {tokens} not a multiple of {block_tokens}")
    stream_name, _, in_modes, out_edges, out_dim = _stream_layout(tn)
    if math.prod(in_modes) != n_in:
        raise ValueError("x inner dim does not match network input modes")
    out_dtype = out_dtype or x.dtype
    grid = (tokens // block_tokens,)

    x_spec = pl.BlockSpec((block_tokens, n_in), lambda i: (i, 0))
    core_specs = [
        pl.BlockSpec(c.shape, functools.partial(lambda i, nd=c.ndim: (0,) * nd))
        for c in cores
    ]
    o_spec = pl.BlockSpec((block_tokens, out_dim), lambda i: (i, 0))

    kernel = functools.partial(
        _kernel,
        tn=tn,
        path=path,
        stream_name=stream_name,
        in_modes=in_modes,
        out_edges=out_edges,
        out_dim=out_dim,
        block_tokens=block_tokens,
    )
    kwargs = {}
    if not interpret:
        from jax.experimental.pallas import tpu as pltpu

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec] + core_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((tokens, out_dim), out_dtype),
        interpret=interpret,
        **kwargs,
    )(x, *cores)


def build_block_network(
    block_tokens: int,
    in_modes: Sequence[int],
    out_modes: Sequence[int],
    ranks: Sequence[int],
) -> TensorNetwork:
    """The per-block tensor network the kernel contracts (batch = block)."""
    return tt_linear_network(block_tokens, tuple(in_modes), tuple(out_modes),
                             tuple(ranks))


# ---------------------------------------------------------------------------
# differentiable wrapper: backward pass along searched gradient networks
# ---------------------------------------------------------------------------

def streaming_tt_linear_vjp(
    x: jax.Array,
    cores: Sequence[jax.Array],
    tn: TensorNetwork,
    path: CandidatePath,
    *,
    bwd_steps: Optional[Mapping[str, Sequence[tuple[int, int]]]] = None,
    block_tokens: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """:func:`streaming_tt_linear` under a ``jax.custom_vjp``.

    The backward pass contracts the layer's gradient networks
    (``repro.core.backward``) instead of transposing the kernel:

      * ``dL/dx`` streams ``dY`` through the *same* Pallas kernel against
        the pinned cores (the dx network is itself a streaming TT
        contraction);
      * each ``dL/dG_k`` is contracted by the jnp path executor over the
        whole batch (weight gradients reduce over tokens, so they stream
        two operands and do not fit the single-stream kernel; the plan
        executor routes them through the Pallas GEMM backend instead).

    ``bwd_steps`` optionally pins the DSE-searched backward path per
    gradient (keys ``"dx"`` / core node names); missing entries fall back
    to the MAC-optimal path of that gradient's network.  tokens must be a
    multiple of ``block_tokens`` (the plan executor's padded ``ops``
    wrappers handle ragged shapes).

    This is the *kernel-level* differentiable API (standalone use of the
    streaming kernel under ``jax.grad``).  Planned model execution goes
    through ``repro.plan.executor._backward_planned`` instead, which
    contracts the same ``repro.core.backward`` gradient networks but
    routes each one per the plan's BackwardOp backend/tiling — changes
    to the gradient-contraction contract (edge order, dtype casts,
    padding exactness) must be mirrored there.
    """
    tokens = x.shape[0]
    bwd_steps = dict(bwd_steps or {})
    x_node = _stream_node(tn)
    x_inner = tuple(d for e, d in zip(x_node.edges, x_node.dims)
                    if e != x_node.edges[0])
    core_names = [n.name for n in tn.nodes if n.kind != "input"]
    node_edges = {n.name: n.edges for n in tn.nodes}
    # dx streams per block -> derive from the block-batch network; weight
    # grads reduce over the whole batch -> derive from a full-batch rebind
    dx_net = dict(backward_networks(tn))["dx"]
    full_bnets = [(wrt, net)
                  for wrt, net in backward_networks(_rebatch(tn, tokens))
                  if wrt != "dx"]

    def _path_for(wrt: str, net: TensorNetwork) -> CandidatePath:
        steps = bwd_steps.get(wrt)
        if steps is None:
            return find_topk_paths(net, k=1)[0]
        steps = tuple(tuple(s) for s in steps)
        gemms = tuple(net.gemm_sequence(steps))
        return CandidatePath(steps, sum(g.macs for g in gemms), gemms)

    @jax.custom_vjp
    def f(x, cores):
        return streaming_tt_linear(
            x, list(cores), tn, path, block_tokens=block_tokens,
            out_dtype=out_dtype, interpret=interpret)

    def fwd(x, cores):
        return f(x, cores), (x, cores)

    def bwd(res, g):
        x, cores = res
        named = dict(zip(core_names, cores))
        # dL/dx: dY streamed against the pinned cores — the same kernel
        dx2d = streaming_tt_linear(
            g.astype(x.dtype), list(cores), dx_net, _path_for("dx", dx_net),
            block_tokens=block_tokens, interpret=interpret)
        dcores = {}
        for wrt, net in full_bnets:
            grad_node = next(n for n in net.nodes if n.name == GRAD_NODE)
            tensors = {n.name: named[n.name] for n in net.nodes
                       if n.name in named}
            tensors[x_node.name] = x.reshape((tokens,) + x_inner)
            tensors[grad_node.name] = g.reshape(grad_node.dims)
            dcores[wrt] = execute_path(
                net, _path_for(wrt, net), tensors,
                out_edges=node_edges[wrt], preferred_dtype=jnp.float32,
            ).astype(named[wrt].dtype)
        return dx2d.reshape(x.shape), tuple(dcores[n] for n in core_names)

    f.defvjp(fwd, bwd)
    return f(x, tuple(cores))


def _rebatch(tn: TensorNetwork, tokens: int) -> TensorNetwork:
    """Rebind the streamed node's leading batch edge to ``tokens``."""
    x = _stream_node(tn)
    nodes = [
        Node(n.name, n.edges, (tokens,) + n.dims[1:], n.kind)
        if n.name == x.name else n
        for n in tn.nodes
    ]
    return TensorNetwork(nodes)
