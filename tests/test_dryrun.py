"""Dry-run machinery: collective parser (unit) + an 8-device end-to-end
lower/compile in a subprocess (isolated XLA device-count flags)."""

import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import collective_bytes, extrapolate
from repro.configs import get_config

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_collective_parser_counts_output_shapes():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%a, %b)
  %cp = u32[2,2]{1,0} collective-permute(%c)
  %notacoll = f32[999]{0} add(%a, %b)
  %agsd = bf16[4]{0} all-gather-start(%q)
  %agsd2 = bf16[4]{0} all-gather-done(%agsd)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2 + 4 * 2   # start counted, done not
    assert out["all-reduce"] == 64 * 4
    assert out["reduce-scatter"] == 32 * 4 * 2
    assert out["collective-permute"] == 2 * 2 * 4
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))


def test_extrapolation_linear_families():
    cfg = get_config("glm4-9b")   # 40 layers
    vals = {"l1": 10.0, "l2": 13.0}
    # base 7 + 40 * 3
    assert abs(extrapolate(cfg, vals) - (7 + 40 * 3)) < 1e-9
    cfg_h = get_config("zamba2-1.2b")  # 38 layers, attn_every 6
    vals_h = {"m1": 8.0, "m2": 9.0, "g1": 7 + 6 * 1 + 2}
    # base 7, mamba 1, attn 2, 6 groups
    assert abs(extrapolate(cfg_h, vals_h) - (7 + 38 + 6 * 2)) < 1e-9
    cfg_e = get_config("seamless-m4t-medium")  # 12 enc + 12 dec
    vals_e = {"e1d1": 6.0, "e2d1": 8.0, "e1d2": 9.0}
    # base 1, enc 2, dec 3
    assert abs(extrapolate(cfg_e, vals_e) - (1 + 12 * 2 + 12 * 3)) < 1e-9


@pytest.mark.slow
def test_dryrun_8dev_smoke_cell(tmp_path):
    """End-to-end: 8 fake devices, smoke config, one train cell lowers,
    compiles, and reports memory/cost/collectives."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "chatglm3-6b", "--shape", "train_4k",
         "--test-mesh", "--smoke", "--force",
         "--out-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.load(open(tmp_path / "chatglm3-6b_train_4k_testpod_tt.json"))
    assert out["status"] == "ok"
    assert out["cost"]["flops_per_device"] > 0
    assert out["memory"]["temp_bytes"] > 0


@pytest.mark.slow
def test_dryrun_8dev_multipod_decode(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "decode_32k",
         "--test-mesh", "--multi-pod", "--smoke", "--force", "--no-cost",
         "--out-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.load(open(tmp_path / "rwkv6-7b_decode_32k_testmultipod_tt.json"))
    assert out["status"] == "ok"
    assert out["mesh"].get("pod") == 2
