"""NN substrate: every projection is dense-or-TT via ``linear.py``."""

from .linear import (
    LinearSpec,
    TTConfig,
    capture_activation_rms,
    install_plan,
    installed_factorizations,
    linear_apply,
    linear_flops,
    linear_init,
    plan_context,
    planned_layer,
    planned_path_index,
)
from .attention import (
    AttentionSpec,
    KVCache,
    attention_apply,
    attention_init,
    init_kv_cache,
)
from .embedding import EmbeddingSpec, embedding_apply, embedding_init, head_apply
from .mlp import MLPSpec, mlp_apply, mlp_init
from .moe import MoESpec, moe_apply, moe_init
from .norms import layernorm, layernorm_init, rmsnorm, rmsnorm_init
from .rope import apply_rope, rope_for, rope_frequencies
from .rwkv import (
    RWKVSpec,
    RWKVState,
    init_rwkv_state,
    rwkv_channel_mix,
    rwkv_init,
    rwkv_time_mix,
)
from .ssm import SSMSpec, SSMState, init_ssm_state, ssm_apply, ssm_init

__all__ = [
    "LinearSpec", "TTConfig", "capture_activation_rms", "install_plan",
    "installed_factorizations", "linear_apply", "linear_flops",
    "linear_init", "plan_context", "planned_layer", "planned_path_index",
    "AttentionSpec", "KVCache", "attention_apply", "attention_init",
    "init_kv_cache",
    "EmbeddingSpec", "embedding_apply", "embedding_init", "head_apply",
    "MLPSpec", "mlp_apply", "mlp_init",
    "MoESpec", "moe_apply", "moe_init",
    "layernorm", "layernorm_init", "rmsnorm", "rmsnorm_init",
    "apply_rope", "rope_for", "rope_frequencies",
    "RWKVSpec", "RWKVState", "init_rwkv_state", "rwkv_channel_mix",
    "rwkv_init", "rwkv_time_mix",
    "SSMSpec", "SSMState", "init_ssm_state", "ssm_apply", "ssm_init",
]
