"""End-to-end driver: train the ~100M-param TT LM for a few hundred steps.

Composes the full stack — TT layers with DSE-searched contraction paths,
deterministic data pipeline, AdamW + warmup-cosine, gradient clipping,
async checkpointing, fault-tolerant loop.  CPU-feasible (a few minutes);
the same driver scales to the production mesh via launch/train.py.

  PYTHONPATH=src python examples/train_tt_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_pipeline
from repro.launch.steps import make_train_step
from repro.models import api
from repro.models.lm import count_params
from repro.optim import adamw_init, linear_warmup_cosine
from repro.runtime import FaultTolerantLoop, StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/tt_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config("tt-lm-100m")
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    dense_equiv = (cfg.n_layers * (4 * cfg.d_model**2 + 3 * cfg.d_model * cfg.d_ff)
                   + cfg.vocab * cfg.d_model)
    print(f"arch {cfg.name}: {count_params(params):,} TT params "
          f"(dense-equivalent {dense_equiv:,})")

    pipe = make_pipeline(cfg.vocab, args.seq, args.batch)
    step_fn = jax.jit(make_train_step(
        cfg, lr=linear_warmup_cosine(3e-4, 30, args.steps)),
        donate_argnums=(0, 1))
    opt = adamw_init(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()
    t0 = time.time()
    losses = []

    def one(state, step):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 20 == 0:
            tok_s = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  {tok_s:,.0f} tok/s")
        return {"params": p, "opt": o}

    loop = FaultTolerantLoop(one, mgr, checkpoint_every=100, straggler=mon)
    state, done = loop.run({"params": params, "opt": opt}, 0, args.steps)
    print(f"done at step {done}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s); checkpoints at {args.ckpt_dir}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
