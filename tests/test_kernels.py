"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the assignment: sweep shapes/dtypes and assert_allclose against the
ref.py oracle for every kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import find_topk_paths
from repro.kernels import ops, ref
from repro.kernels.streaming_tt import build_block_network, streaming_tt_linear

DATAFLOWS = ("OS", "WS", "IS")


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("shape", [
    (32, 32, 32),        # single block
    (64, 96, 32),        # multi-block K
    (128, 64, 96),       # multi-block all dims
    (33, 47, 65),        # ragged -> padded path
    (1, 128, 128),       # degenerate M
])
def test_gemm_vs_ref_shapes(dataflow, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((dataflow, shape)) % 2**31)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = ops.gemm(a, b, dataflow=dataflow, block_m=32, block_k=32, block_n=32,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dataflow, dtype):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(64, 64)), dtype)
    b = jnp.asarray(rng.normal(size=(64, 64)), dtype)
    out = ops.gemm(a, b, dataflow=dataflow, block_m=32, block_k=32, block_n=32,
                   interpret=True)
    expect = ref.gemm_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_gemm_block_shape_independence(dataflow):
    """Different BlockSpec tilings (the DSE's <T_M,T_K,T_N> axis) must not
    change the numerics."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    outs = [
        np.asarray(ops.gemm(a, b, dataflow=dataflow, block_m=bm, block_k=bk,
                            block_n=bn, interpret=True))
        for bm, bk, bn in [(32, 32, 32), (64, 32, 128), (128, 128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("in_modes,out_modes,rank", [
    ((4, 4), (4, 4), 4),
    ((4, 8), (8, 4), 8),
    ((2, 4, 4), (4, 4, 2), 4),
])
def test_streaming_tt_vs_ref(in_modes, out_modes, rank):
    block = 8
    ranks = (rank,) * (len(in_modes) + len(out_modes) - 1)
    tn = build_block_network(block, in_modes, out_modes, ranks)
    path = find_topk_paths(tn, k=1)[0]
    rng = np.random.default_rng(11)
    cores = []
    for node in tn.nodes:
        if node.name == "X":
            continue
        cores.append(jnp.asarray(rng.normal(size=node.dims) * 0.3, jnp.float32))
    tokens = 24
    x = jnp.asarray(rng.normal(size=(tokens, int(np.prod(in_modes)))), jnp.float32)
    out = ops.tt_linear(x, cores, tn, path, block_tokens=block, interpret=True)
    expect = ref.tt_linear_ref(x, cores, tn, path)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_streaming_tt_all_topk_paths_agree():
    """Every candidate contraction path computes the same function."""
    block = 8
    tn = build_block_network(block, (4, 4), (4, 4), (4, 4, 4))
    paths = find_topk_paths(tn, k=4)
    rng = np.random.default_rng(5)
    cores = [jnp.asarray(rng.normal(size=n.dims) * 0.3, jnp.float32)
             for n in tn.nodes if n.name != "X"]
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    outs = [np.asarray(ops.tt_linear(x, cores, tn, p, block_tokens=block,
                                     interpret=True)) for p in paths]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_gemm_composes_with_jit_and_vmap_free_call():
    """Kernels are forward primitives (training uses the jnp executor, so
    autodiff never crosses pallas_call); they must compose with jit."""
    a = jnp.ones((32, 32))
    b = jnp.ones((32, 32))

    @jax.jit
    def f(a):
        return jnp.sum(ops.gemm(a, b, dataflow="OS", block_m=32, block_k=32,
                                block_n=32, interpret=True))

    np.testing.assert_allclose(float(f(a)), 32.0 * 32 * 32, rtol=1e-6)
