"""tt-lm-100m — the end-to-end training example model (~100M dense-equiv).

Not an assigned arch: a small dense GQA LM whose projections are
TT-factorized, used by ``examples/train_tt_lm.py`` to run a real training
loop (optimizer, data pipeline, checkpointing) on CPU within minutes.
"""

from repro.models.config import ModelConfig
from repro.nn.linear import TTConfig

FULL = ModelConfig(
    name="tt-lm-100m",
    family="dense",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32_000,
    head_dim=64,
    dtype="float32",
    remat="none",
    q_chunk=512,
    tt=TTConfig(enabled=True, d=3, rank=16, min_dim=512,
                targets=("attn", "mlp", "head", "moe", "embed")),
)

SMOKE = FULL.with_(
    name="tt-lm-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    q_chunk=32,
    tt=TTConfig(enabled=True, d=2, rank=8, min_dim=64,
                targets=("attn", "mlp", "head", "moe", "embed")),
)
