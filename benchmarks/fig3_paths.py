"""Fig. 3 — MACs vs latency across contraction orders on a ViT-Ti/4 layer.

Reproduces the paper's central observation: the reconstruction-based
order is worst; the MAC-optimal path is NOT the latency-optimal one when
the hardware (partitioning x dataflow) is in the loop — the DSE's
latency-optimal path trades a few extra MACs for lower execution time
(paper reports ~25%).
"""

from __future__ import annotations

from repro.core import (
    ALL_DATAFLOWS,
    FPGA_VU9P,
    STRATEGY_SPACE,
    find_topk_paths,
    layer_latency,
    reconstruction_path,
)
from repro.models.vision import vit_ti4_layers
from .common import emit


def best_latency(path, hw=FPGA_VU9P):
    parts = sorted({c for cs in STRATEGY_SPACE.values() for c in cs})
    return min(
        layer_latency(path, d, c, hw).seconds
        for c in parts for d in ALL_DATAFLOWS
    )


def run() -> list[dict]:
    rows = []
    # a mid-block MLP layer, batch 64 (training micro-batch on the FPGA)
    for layer in vit_ti4_layers(batch=64)[:8]:
        tn = layer.tt_network
        paths = find_topk_paths(tn, k=8)
        recon = reconstruction_path(tn)
        mac_opt = paths[0]
        lat_opt = min(paths, key=best_latency)
        rows.append({
            "layer": layer.name,
            "recon_macs": recon.macs,
            "recon_latency_us": best_latency(recon) * 1e6,
            "mac_opt_macs": mac_opt.macs,
            "mac_opt_latency_us": best_latency(mac_opt) * 1e6,
            "lat_opt_macs": lat_opt.macs,
            "lat_opt_latency_us": best_latency(lat_opt) * 1e6,
            "lat_opt_is_mac_opt": lat_opt.macs == mac_opt.macs,
            "latency_win_pct": 100.0 * (1 - best_latency(lat_opt) /
                                        best_latency(mac_opt)),
        })
    emit("fig3_paths", rows)
    return rows


if __name__ == "__main__":
    run()
