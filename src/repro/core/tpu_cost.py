"""TPU-v5e target — compatibility shim over ``repro.hw.targets``.

The TPU-v5e re-parameterization of the systolic latency model (and the
roofline interconnect constants) moved to :mod:`repro.hw.targets`, the
hardware-target registry.  This module re-exports them so existing
imports (``repro.core.tpu_cost.TPU_V5E``) keep working; new code should
use ``repro.hw.get_target("tpu_v5e")`` or ``repro.hw.TPU_V5E``.
"""

from __future__ import annotations

from ..hw.targets import (  # noqa: F401  (re-exports)
    HBM_BYTES_PER_S,
    HBM_CAPACITY_BYTES,
    ICI_BYTES_PER_S_PER_LINK,
    PEAK_FLOPS_BF16,
    TPU_V5E,
    VMEM_BYTES,
)
